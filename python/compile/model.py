"""L2: the JAX compute graph composing BinEm (lookup) with the L1 kernels.

Build-time only — lowered once by ``aot.py`` to HLO text and never imported
at runtime. psi and pi are baked as HLO constants (psi is c+1 bytes, pi is
n int32s — both tiny in text form; the n x d one-hot is *never*
materialised, see kernels/binsketch.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import binsketch as binsketch_k
from .kernels import cham as cham_k
from . import prng


class CabinModel:
    """Holds the baked mappings for one (n, c, d, seed) configuration."""

    def __init__(self, n: int, c: int, d: int, seed: int) -> None:
        self.n = n
        self.c = c
        self.d = d
        self.seed = seed
        # Per-attribute psi (the library default — see rust sketch::binem
        # for why the paper's shared table breaks Lemma 2's independence).
        self.psi = prng.derive_psi_matrix(seed, n, c)  # (n, c+1) u8
        self.pi = prng.derive_pi(seed, n, d)  # (n,) u32

    # ---- L2 graph pieces -------------------------------------------------

    def binem(self, u: jnp.ndarray) -> jnp.ndarray:
        """(m, n) int32 categorical -> (m, n) f32 binary.

        u'[m, i] = psi[i, u[m, i]];  psi[:, 0] = 0 keeps missing at 0.
        """
        table = jnp.asarray(self.psi, dtype=jnp.float32)  # (n, c+1)
        n = table.shape[0]
        return table[jnp.arange(n)[None, :], u]

    def cabin_sketch(self, u: jnp.ndarray) -> jnp.ndarray:
        """Full Cabin: (m, n) int32 -> (m, d) f32 0/1 sketches."""
        u_bin = self.binem(u)
        pi = jnp.asarray(self.pi.astype("int32"))
        return binsketch_k.binsketch(u_bin, pi, d=self.d)

    @staticmethod
    def cham_allpairs(s: jnp.ndarray) -> jnp.ndarray:
        """(m, d) f32 sketches -> (m, m) f32 estimated categorical HDs."""
        w = jnp.sum(s, axis=1, keepdims=True)
        return cham_k.cham_allpairs(s, w)

    @staticmethod
    def cham_cross(sq: jnp.ndarray, sc: jnp.ndarray) -> jnp.ndarray:
        """(mq, d) x (mc, d) -> (mq, mc) estimated categorical HDs."""
        wq = jnp.sum(sq, axis=1, keepdims=True)
        wc = jnp.sum(sc, axis=1, keepdims=True)
        return cham_k.cham_cross(sq, sc, wq, wc)

    def sketch_and_allpairs(self, u: jnp.ndarray) -> jnp.ndarray:
        """End-to-end: categorical batch -> all-pairs HD estimates.

        The fully fused artifact: both Pallas kernels lower into one HLO
        module; XLA keeps the intermediate sketch on-device.
        """
        return self.cham_allpairs(self.cabin_sketch(u))
