"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Everything here is straight-line jnp with no tiling tricks; pytest compares
the kernels (and the AOT artifacts, via rust integration tests) against
these functions.
"""

from __future__ import annotations

import jax.numpy as jnp


def binem_ref(u: jnp.ndarray, psi_matrix: jnp.ndarray) -> jnp.ndarray:
    """BinEm (per-attribute psi): categorical (m, n) int32 -> (m, n) f32.

    psi_matrix is (n, c+1) with psi[:, 0] = 0, so missing features stay 0.
    """
    table = psi_matrix.astype(jnp.float32)
    n = table.shape[0]
    return table[jnp.arange(n)[None, :], u]


def binsketch_ref(u_bin: jnp.ndarray, p_onehot: jnp.ndarray) -> jnp.ndarray:
    """BinSketch as a clamped matmul: (m, n) f32 x (n, d) f32 -> (m, d) f32.

    S[m, j] = min(1, sum_i u'[m, i] * P[i, j]) == OR over the pi-preimage.
    """
    return jnp.minimum(u_bin @ p_onehot, 1.0)


def cabin_ref(u: jnp.ndarray, psi_matrix: jnp.ndarray, p_onehot: jnp.ndarray) -> jnp.ndarray:
    """Full Cabin pipeline reference."""
    return binsketch_ref(binem_ref(u, psi_matrix), p_onehot)


def binhamming_stats_ref(wu, wv, ip, d: int):
    """Occupancy-inversion BinHamming from scalar/array stats.

    est(x) = ln(1 - x/d) / ln(1 - 1/d);  h = 2*est(union) - est(wu) - est(wv)
    Mirrors rust `sketch::cham::binhamming_from_stats`.
    """
    df = jnp.float32(d)
    ln_ratio = jnp.log1p(-1.0 / df)

    def est(x):
        x = jnp.clip(x, 0.0, df - 1.0)
        return jnp.log1p(-x / df) / ln_ratio

    union = wu + wv - ip
    h = 2.0 * est(union) - est(wu) - est(wv)
    return jnp.maximum(h, 0.0)


def cham_allpairs_ref(s: jnp.ndarray) -> jnp.ndarray:
    """All-pairs categorical Hamming estimates from a sketch matrix.

    s: (m, d) f32 0/1. Returns (m, m) f32 with entry (i, j) =
    2 * BinHamming(s_i, s_j)  (the x2 undoes BinEm's halving).
    """
    m, d = s.shape
    w = jnp.sum(s, axis=1)  # (m,)
    g = s @ s.T  # (m, m) bitwise inner products
    h = binhamming_stats_ref(w[:, None], w[None, :], g, d)
    return 2.0 * h


def cham_cross_ref(sq: jnp.ndarray, sc: jnp.ndarray) -> jnp.ndarray:
    """Query x corpus Hamming estimates: (mq, d), (mc, d) -> (mq, mc)."""
    d = sq.shape[1]
    wq = jnp.sum(sq, axis=1)
    wc = jnp.sum(sc, axis=1)
    g = sq @ sc.T
    return 2.0 * binhamming_stats_ref(wq[:, None], wc[None, :], g, d)
