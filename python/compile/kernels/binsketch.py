"""L1 Pallas kernel: BinSketch as a blocked masked matmul.

The sketch is ``S = min(1, U' @ P)`` where ``P[i, j] = [pi(i) == j]`` is the
one-hot of the attribute mapping. Materialising ``P`` (n x d f32) in HBM
would cost n*d*4 bytes (16 MiB at n=4096, d=1024; 5 TiB at BrainCell scale)
— so the kernel *generates each (bk x bd) one-hot tile in VMEM on the fly*
from the integer pi vector (n x 4 bytes total), turning the stage-2
compression into a pure MXU workload with O(n) index traffic instead of
O(n*d) matrix traffic. See DESIGN.md §Hardware-Adaptation.

Grid: (m/bm, d/bd, n/bk); the f32 accumulator tile lives in the output
VMEM block across the k-loop (revisiting semantics), clamped on the last
k-step. interpret=True everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; on a real TPU the same BlockSpecs drive the MXU with
bf16 inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binsketch_kernel(u_ref, pi_ref, o_ref, *, bd: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Generate the one-hot tile of P for this (k, j) block in VMEM:
    # mask[i, j] = 1.0 iff pi[i] == column j (global).
    j0 = pl.program_id(1) * bd
    cols = j0 + jax.lax.broadcasted_iota(jnp.int32, (1, bd), 1)
    mask = (pi_ref[...].reshape(-1, 1) == cols).astype(jnp.float32)

    o_ref[...] += jnp.dot(u_ref[...], mask, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = jnp.minimum(o_ref[...], 1.0)


@functools.partial(jax.jit, static_argnames=("d", "bm", "bd", "bk"))
def binsketch(
    u_bin: jnp.ndarray,
    pi: jnp.ndarray,
    *,
    d: int,
    bm: int = 32,
    bd: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """Compress a binary batch (m, n) f32 into sketches (m, d) f32.

    ``pi``: (n,) int32 attribute mapping with values in [0, d).
    Shapes must tile exactly (m % bm == n % bk == d % bd == 0); the AOT
    pipeline pads batches to the artifact's fixed shape.
    """
    m, n = u_bin.shape
    bm = min(bm, m)
    bd = min(bd, d)
    bk = min(bk, n)
    assert m % bm == 0 and d % bd == 0 and n % bk == 0, (m, n, d, bm, bd, bk)
    nk = n // bk
    grid = (m // bm, d // bd, nk)
    return pl.pallas_call(
        functools.partial(_binsketch_kernel, bd=bd, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(u_bin, pi)
