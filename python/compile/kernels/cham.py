"""L1 Pallas kernel: fused all-pairs Cham estimator.

The paper's heaviest workload (heatmaps, all-pair similarity, the 136x
speedup claim) is: given a sketch matrix S (m x d, 0/1), estimate every
pairwise Hamming distance. That is a gram matrix G = S S^T — on TPU the MXU
*is* the popcount engine — followed by a cheap elementwise estimator
epilogue.

The kernel fuses both: each (bm x bm) output tile accumulates its gram
block over the d/bk k-loop in VMEM, then applies the occupancy-inversion
estimator on the VPU before the single HBM writeback. The gram matrix never
round-trips to HBM (FlashAttention-style epilogue fusion).

Row weights w = |s_i| are precomputed in L2 (one cheap reduction) and fed
as (m, 1) so the BlockSpec machinery can tile them alongside the rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _estimator(wi, wj, g, d: float, scale: float):
    """Occupancy-inversion BinHamming on a tile + the Cham x2.

    est(x) = log1p(-x/d)/log1p(-1/d);  h = 2 est(wi+wj-g) - est(wi) - est(wj)
    """
    ln_ratio = jnp.log1p(jnp.float32(-1.0 / d))

    def est(x):
        x = jnp.clip(x, 0.0, d - 1.0)
        return jnp.log1p(-x / d) / ln_ratio

    union = wi + wj - g
    h = 2.0 * est(union) - est(wi) - est(wj)
    return scale * jnp.maximum(h, 0.0)


def _cham_kernel(si_ref, sj_ref, wi_ref, wj_ref, o_ref, *, d: int, nk: int, scale: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        si_ref[...], sj_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        g = o_ref[...]
        wi = wi_ref[...]  # (bm, 1)
        wj = wj_ref[...]  # (bn, 1) -> transpose to broadcast over columns
        o_ref[...] = _estimator(wi, wj.T, g, float(d), scale)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "scale"))
def cham_allpairs(
    s: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 64,
    bk: int = 256,
    scale: float = 2.0,
) -> jnp.ndarray:
    """All-pairs estimated categorical Hamming matrix.

    s: (m, d) f32 0/1 sketch matrix; w: (m, 1) f32 row weights.
    Returns (m, m) f32. `scale=2.0` is Cham's BinEm-halving correction;
    use 1.0 to estimate binary Hamming distances directly.
    """
    m, d = s.shape
    bm = min(bm, m)
    bk = min(bk, d)
    assert m % bm == 0 and d % bk == 0, (m, d, bm, bk)
    nk = d // bk
    grid = (m // bm, m // bm, nk)
    return pl.pallas_call(
        functools.partial(_cham_kernel, d=d, nk=nk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=True,
    )(s, s, w, w)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "scale"))
def cham_cross(
    sq: jnp.ndarray,
    sc: jnp.ndarray,
    wq: jnp.ndarray,
    wc: jnp.ndarray,
    *,
    bm: int = 32,
    bn: int = 128,
    bk: int = 256,
    scale: float = 2.0,
) -> jnp.ndarray:
    """Query x corpus estimates: (mq, d) x (mc, d) -> (mq, mc).

    The serving-path kernel: a batch of query sketches against a corpus
    shard resident in device memory.
    """
    mq, d = sq.shape
    mc, _ = sc.shape
    bm = min(bm, mq)
    bn = min(bn, mc)
    bk = min(bk, d)
    assert mq % bm == 0 and mc % bn == 0 and d % bk == 0
    nk = d // bk

    def kernel(q_ref, c_ref, wq_ref, wc_ref, o_ref):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            q_ref[...], c_ref[...].T, preferred_element_type=jnp.float32
        )

        @pl.when(k == nk - 1)
        def _epilogue():
            o_ref[...] = _estimator(
                wq_ref[...], wc_ref[...].T, o_ref[...], float(d), scale
            )

    grid = (mq // bm, mc // bn, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mq, mc), jnp.float32),
        interpret=True,
    )(sq, sc, wq, wc)
