"""SplitMix64 — bit-identical port of ``rust/src/util/rng.rs``.

The attribute mapping pi and the category mapping psi are derived from
splitmix64 streams with fixed stream tags. The SAME derivation runs in rust
(`sketch::mappings`) and here, so the AOT-baked constants in the HLO
artifacts agree exactly with the rust native path. ``python/tests/
test_prng.py`` pins the shared vectors; ``rust/src/util/rng.rs`` pins them
on the rust side.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1

# Stream tags — keep in sync with rust/src/sketch/mappings.rs.
PSI_STREAM = 0x5049_5053_4954_0001
PI_STREAM = 0x5049_5F4D_4150_0002


class SplitMix64:
    """Steele–Lea–Flood splittable PRNG finalizer."""

    def __init__(self, seed: int) -> None:
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64


def derive_psi(seed: int, num_categories: int) -> np.ndarray:
    """psi table over {0..c}: psi[0] = 0, psi[v] in {0,1}."""
    sm = SplitMix64(seed ^ PSI_STREAM)
    table = np.zeros(num_categories + 1, dtype=np.uint8)
    for v in range(1, num_categories + 1):
        table[v] = sm.next_u64() & 1
    return table


def mix64_np(z: np.ndarray) -> np.ndarray:
    """Vectorised stateless mix64 — port of rust ``util::rng::mix64``."""
    with np.errstate(over="ignore"):
        z = (z.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(MASK64)
        z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(MASK64)
        z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(MASK64)
        return z ^ (z >> np.uint64(31))


def derive_psi_matrix(seed: int, n: int, c: int) -> np.ndarray:
    """Per-attribute psi table, the default BinEm mode (see rust
    ``sketch::binem``): psi[i, v] = bit(mix64(seed ^ (i << 20) ^ v)) for
    v >= 1, psi[i, 0] = 0. Shape (n, c+1) uint8 — bit-identical to the rust
    ``BinEm::psi`` PerAttribute path."""
    i = np.arange(n, dtype=np.uint64)[:, None]
    v = np.arange(c + 1, dtype=np.uint64)[None, :]
    keys = np.uint64(seed) ^ (i << np.uint64(20)) ^ v
    bits = (mix64_np(keys) & np.uint64(1)).astype(np.uint8)
    bits[:, 0] = 0
    return bits


def derive_pi(seed: int, n: int, d: int) -> np.ndarray:
    """pi table over {0..n-1} with values in {0..d-1}."""
    assert d > 0
    sm = SplitMix64(seed ^ PI_STREAM)
    return np.array([sm.next_u64() % d for _ in range(n)], dtype=np.uint32)


def pi_one_hot(pi: np.ndarray, d: int, dtype=np.float32) -> np.ndarray:
    """pi as a one-hot matrix P in {0,1}^{n x d}: P[i, pi[i]] = 1."""
    n = pi.shape[0]
    p = np.zeros((n, d), dtype=dtype)
    p[np.arange(n), pi] = 1
    return p
