"""AOT pipeline: lower the L2 graphs to HLO **text** + sidecar tables.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (behind the rust `xla` crate) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs under --out-dir (default ../artifacts):
    cabin_sketch.hlo.txt      (m, n) i32           -> (m, d) f32
    cham_allpairs.hlo.txt     (mp, d) f32          -> (mp, mp) f32
    cham_cross.hlo.txt        (mq, d), (mc, d) f32 -> (mq, mc) f32
    sketch_allpairs.hlo.txt   (m, n) i32           -> (m, m) f32
    pi_<n>_<d>.u32            little-endian u32 pi table (sidecar)
    psi_<c>.u8                psi table (sidecar)
    manifest.json             shapes/dtypes/seed for the rust loader

Run via `make artifacts`; python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import prng
from .model import CabinModel

# Default artifact configuration — mirrored by rust runtime::artifacts.
DEFAULTS = dict(
    n=4096,  # input dimension
    c=64,  # categories
    d=1024,  # sketch dimension (MXU-aligned; paper uses 1000 natively)
    m=64,  # sketch batch
    mp=256,  # all-pairs batch
    mq=64,  # query batch
    mc=512,  # corpus shard batch
    seed=42,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path).

    `as_hlo_text(True)` = print_large_constants: without it the printer
    elides the baked psi/pi tables as `{...}` and the text parser on the
    rust side silently zero-fills them (all-zero sketches). Pinned by
    tests/test_aot.py::test_constants_are_printed_in_full.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_all(cfg: dict) -> dict:
    """Lower every artifact; returns {name: hlo_text}."""
    model = CabinModel(cfg["n"], cfg["c"], cfg["d"], cfg["seed"])
    i32 = jnp.int32
    f32 = jnp.float32
    u_spec = jax.ShapeDtypeStruct((cfg["m"], cfg["n"]), i32)
    s_spec = jax.ShapeDtypeStruct((cfg["mp"], cfg["d"]), f32)
    q_spec = jax.ShapeDtypeStruct((cfg["mq"], cfg["d"]), f32)
    c_spec = jax.ShapeDtypeStruct((cfg["mc"], cfg["d"]), f32)

    def tup(fn):
        # return_tuple=True at the XlaComputation level expects the jax fn
        # output pytree; wrap to a 1-tuple for a stable calling convention.
        return lambda *a: (fn(*a),)

    arts = {}
    arts["cabin_sketch"] = to_hlo_text(
        jax.jit(tup(model.cabin_sketch)).lower(u_spec)
    )
    arts["cham_allpairs"] = to_hlo_text(
        jax.jit(tup(CabinModel.cham_allpairs)).lower(s_spec)
    )
    arts["cham_cross"] = to_hlo_text(
        jax.jit(tup(CabinModel.cham_cross)).lower(q_spec, c_spec)
    )
    arts["sketch_allpairs"] = to_hlo_text(
        jax.jit(tup(model.sketch_and_allpairs)).lower(u_spec)
    )
    return arts


def write_sidecars(cfg: dict, out_dir: str) -> dict:
    pi = prng.derive_pi(cfg["seed"], cfg["n"], cfg["d"])
    psi = prng.derive_psi_matrix(cfg["seed"], cfg["n"], cfg["c"])
    pi_name = f"pi_{cfg['n']}_{cfg['d']}.u32"
    psi_name = f"psi_{cfg['n']}_{cfg['c']}.u8"
    with open(os.path.join(out_dir, pi_name), "wb") as f:
        f.write(pi.astype("<u4").tobytes())
    with open(os.path.join(out_dir, psi_name), "wb") as f:
        f.write(psi.astype("u1").tobytes())  # row-major (n, c+1)
    return {"pi": pi_name, "psi": psi_name}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    for k, v in DEFAULTS.items():
        ap.add_argument(f"--{k}", type=int, default=v)
    args = ap.parse_args()
    cfg = {k: getattr(args, k) for k in DEFAULTS}
    os.makedirs(args.out_dir, exist_ok=True)

    arts = lower_all(cfg)
    entries = {}
    for name, text in arts.items():
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        entries[name] = {"hlo": path, "bytes": len(text)}
        print(f"wrote {path} ({len(text)} chars)")

    sidecars = write_sidecars(cfg, args.out_dir)
    manifest = {
        "config": cfg,
        "sidecars": sidecars,
        "artifacts": {
            "cabin_sketch": {
                **entries["cabin_sketch"],
                "inputs": [["i32", [cfg["m"], cfg["n"]]]],
                "outputs": [["f32", [cfg["m"], cfg["d"]]]],
            },
            "cham_allpairs": {
                **entries["cham_allpairs"],
                "inputs": [["f32", [cfg["mp"], cfg["d"]]]],
                "outputs": [["f32", [cfg["mp"], cfg["mp"]]]],
            },
            "cham_cross": {
                **entries["cham_cross"],
                "inputs": [
                    ["f32", [cfg["mq"], cfg["d"]]],
                    ["f32", [cfg["mc"], cfg["d"]]],
                ],
                "outputs": [["f32", [cfg["mq"], cfg["mc"]]]],
            },
            "sketch_allpairs": {
                **entries["sketch_allpairs"],
                "inputs": [["i32", [cfg["m"], cfg["n"]]]],
                "outputs": [["f32", [cfg["m"], cfg["m"]]]],
            },
        },
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json (seed={cfg['seed']}, n={cfg['n']}, d={cfg['d']})")


if __name__ == "__main__":
    main()
