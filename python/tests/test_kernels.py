"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/densities/seeds; the CORE correctness signal of
the python side."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import prng
from compile.kernels import ref
from compile.kernels.binsketch import binsketch
from compile.kernels.cham import cham_allpairs, cham_cross


def random_binary(rng, m, n, density):
    x = (rng.random((m, n)) < density).astype(np.float32)
    return x


def random_sketch(rng, m, d, density):
    return (rng.random((m, d)) < density).astype(np.float32)


# ---------------------------------------------------------------- binsketch


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    m=st.sampled_from([8, 16, 32]),
    n=st.sampled_from([256, 512, 1024]),
    d=st.sampled_from([64, 128, 256]),
    density=st.floats(0.001, 0.2),
)
def test_binsketch_matches_ref(seed, m, n, d, density):
    rng = np.random.default_rng(seed)
    u = random_binary(rng, m, n, density)
    pi = prng.derive_pi(seed, n, d).astype(np.int32)
    out = np.asarray(binsketch(jnp.asarray(u), jnp.asarray(pi), d=d))
    p = prng.pi_one_hot(pi, d)
    expect = np.asarray(ref.binsketch_ref(jnp.asarray(u), jnp.asarray(p)))
    np.testing.assert_allclose(out, expect, atol=1e-6)


def test_binsketch_is_binary_and_or_semantics():
    rng = np.random.default_rng(0)
    u = random_binary(rng, 8, 512, 0.1)
    pi = prng.derive_pi(1, 512, 128).astype(np.int32)
    out = np.asarray(binsketch(jnp.asarray(u), jnp.asarray(pi), d=128))
    assert set(np.unique(out)).issubset({0.0, 1.0})
    # OR semantics: bin j set iff some i with pi[i]=j has u[i]=1
    for row in range(8):
        for j in range(128):
            expect = np.any(u[row, pi == j] > 0)
            assert bool(out[row, j]) == bool(expect)


def test_binsketch_block_shapes_dont_matter():
    rng = np.random.default_rng(3)
    u = random_binary(rng, 16, 1024, 0.05)
    pi = prng.derive_pi(9, 1024, 256).astype(np.int32)
    a = np.asarray(binsketch(jnp.asarray(u), jnp.asarray(pi), d=256, bm=8, bd=64, bk=128))
    b = np.asarray(binsketch(jnp.asarray(u), jnp.asarray(pi), d=256, bm=16, bd=256, bk=512))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------- cham


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    m=st.sampled_from([64, 128]),
    d=st.sampled_from([256, 512]),
    density=st.floats(0.01, 0.4),
)
def test_cham_allpairs_matches_ref(seed, m, d, density):
    rng = np.random.default_rng(seed)
    s = random_sketch(rng, m, d, density)
    w = s.sum(axis=1, keepdims=True).astype(np.float32)
    out = np.asarray(cham_allpairs(jnp.asarray(s), jnp.asarray(w)))
    expect = np.asarray(ref.cham_allpairs_ref(jnp.asarray(s)))
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-3)


def test_cham_allpairs_diagonal_zero_symmetric():
    rng = np.random.default_rng(5)
    s = random_sketch(rng, 64, 256, 0.1)
    w = s.sum(axis=1, keepdims=True).astype(np.float32)
    out = np.asarray(cham_allpairs(jnp.asarray(s), jnp.asarray(w)))
    np.testing.assert_allclose(np.diag(out), 0.0, atol=1e-3)
    np.testing.assert_allclose(out, out.T, rtol=1e-5, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    mq=st.sampled_from([32, 64]),
    mc=st.sampled_from([128, 256]),
    d=st.sampled_from([256, 512]),
)
def test_cham_cross_matches_ref(seed, mq, mc, d):
    rng = np.random.default_rng(seed)
    sq = random_sketch(rng, mq, d, 0.08)
    sc = random_sketch(rng, mc, d, 0.08)
    wq = sq.sum(axis=1, keepdims=True).astype(np.float32)
    wc = sc.sum(axis=1, keepdims=True).astype(np.float32)
    out = np.asarray(cham_cross(jnp.asarray(sq), jnp.asarray(sc), jnp.asarray(wq), jnp.asarray(wc)))
    expect = np.asarray(ref.cham_cross_ref(jnp.asarray(sq), jnp.asarray(sc)))
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-3)


def test_cham_estimates_true_hamming_end_to_end():
    """Statistical end-to-end check mirroring Theorem 2: estimate of the
    binary Hamming distance from BinSketch sketches is close to truth."""
    rng = np.random.default_rng(11)
    n, d, density, m = 8192, 1024, 0.02, 16
    u = random_binary(rng, m, n, density)
    pi = prng.derive_pi(4, n, d).astype(np.int32)
    s = np.asarray(binsketch(jnp.asarray(u), jnp.asarray(pi), d=d))
    w = s.sum(axis=1, keepdims=True).astype(np.float32)
    # scale=1.0: estimate binary HD directly (no BinEm halving here)
    from compile.kernels.cham import cham_allpairs as cap

    est = np.asarray(cap(jnp.asarray(s), jnp.asarray(w), scale=1.0))
    for i in range(m):
        for j in range(i + 1, m):
            truth = np.sum(u[i] != u[j])
            tol = 11 * np.sqrt(max(u[i].sum(), u[j].sum()) * np.log(6 / 0.01))
            assert abs(est[i, j] - truth) < tol, (i, j, est[i, j], truth)


def test_saturated_sketch_is_finite():
    s = np.ones((8, 64), dtype=np.float32)
    w = s.sum(axis=1, keepdims=True)
    out = np.asarray(cham_allpairs(jnp.asarray(s), jnp.asarray(w), bm=8, bk=64))
    assert np.all(np.isfinite(out))


@pytest.mark.parametrize("bad_m", [7, 9])
def test_shape_mismatch_raises(bad_m):
    s = np.ones((bad_m, 64), dtype=np.float32)
    w = s.sum(axis=1, keepdims=True)
    with pytest.raises(AssertionError):
        cham_allpairs(jnp.asarray(s), jnp.asarray(w), bm=4, bk=64)
