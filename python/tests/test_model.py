"""L2 model correctness: CabinModel graphs vs pure-jnp oracles, and the
statistical contracts (Lemma 1/2/4 shapes) of the baked mappings."""

import numpy as np
import jax.numpy as jnp

from compile import prng
from compile.model import CabinModel
from compile.kernels import ref


def random_categorical(rng, m, n, c, density):
    u = np.zeros((m, n), dtype=np.int32)
    for r in range(m):
        idx = rng.choice(n, size=density, replace=False)
        u[r, idx] = rng.integers(1, c + 1, size=density)
    return u


def test_binem_matches_ref_and_preserves_missing():
    rng = np.random.default_rng(0)
    model = CabinModel(n=512, c=16, d=128, seed=42)
    u = random_categorical(rng, 8, 512, 16, 40)
    out = np.asarray(model.binem(jnp.asarray(u)))
    expect = np.asarray(ref.binem_ref(jnp.asarray(u), jnp.asarray(model.psi)))
    np.testing.assert_array_equal(out, expect)
    # missing stays zero
    assert np.all(out[u == 0] == 0)
    # set bits only where psi[i, value] == 1
    m, n = u.shape
    for r in range(m):
        for i in np.nonzero(u[r])[0]:
            assert out[r, i] == model.psi[i, u[r, i]]


def test_cabin_sketch_matches_ref():
    rng = np.random.default_rng(1)
    model = CabinModel(n=1024, c=8, d=256, seed=7)
    u = random_categorical(rng, 16, 1024, 8, 60)
    out = np.asarray(model.cabin_sketch(jnp.asarray(u)))
    p = prng.pi_one_hot(model.pi, 256)
    expect = np.asarray(
        ref.cabin_ref(jnp.asarray(u), jnp.asarray(model.psi), jnp.asarray(p))
    )
    np.testing.assert_allclose(out, expect, atol=1e-6)
    assert set(np.unique(out)).issubset({0.0, 1.0})


def test_sketch_weight_bounded_lemma1():
    rng = np.random.default_rng(2)
    model = CabinModel(n=2048, c=32, d=512, seed=3)
    density = 100
    u = random_categorical(rng, 8, 2048, 32, density)
    s = np.asarray(model.cabin_sketch(jnp.asarray(u)))
    weights = s.sum(axis=1)
    assert np.all(weights <= density)
    # E[weight] ≈ density/2 (Lemma 1b + few collisions at d=512)
    assert 0.3 * density < weights.mean() < 0.7 * density


def test_sketch_and_allpairs_consistent_with_stages():
    rng = np.random.default_rng(3)
    model = CabinModel(n=1024, c=8, d=256, seed=9)
    u = random_categorical(rng, 16, 1024, 8, 50)
    fused = np.asarray(model.sketch_and_allpairs(jnp.asarray(u)))
    s = model.cabin_sketch(jnp.asarray(u))
    staged = np.asarray(CabinModel.cham_allpairs(s))
    np.testing.assert_allclose(fused, staged, rtol=1e-5, atol=1e-3)


def test_allpairs_estimates_track_truth():
    rng = np.random.default_rng(4)
    n, c, d = 4096, 16, 1024
    model = CabinModel(n=n, c=c, d=d, seed=11)
    density = 120
    u = random_categorical(rng, 8, n, c, density)
    est = np.asarray(model.sketch_and_allpairs(jnp.asarray(u)))
    for i in range(8):
        for j in range(i + 1, 8):
            truth = np.sum(u[i] != u[j])
            assert abs(est[i, j] - truth) < 0.3 * truth + 40, (i, j, est[i, j], truth)


def test_cham_cross_matches_allpairs_blocks():
    rng = np.random.default_rng(5)
    model = CabinModel(n=512, c=8, d=128, seed=13)
    u = random_categorical(rng, 32, 512, 8, 30)
    s = model.cabin_sketch(jnp.asarray(u))
    ap = np.asarray(CabinModel.cham_allpairs(s))
    cross = np.asarray(CabinModel.cham_cross(s[:8], s))
    np.testing.assert_allclose(cross, ap[:8], rtol=1e-5, atol=1e-3)
