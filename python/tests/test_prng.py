"""Cross-language PRNG contract: these exact values are also pinned in
rust/src/util/rng.rs and rust/src/sketch/mappings.rs. If either side
changes, the AOT artifacts and the rust native path silently diverge —
these tests are the tripwire."""

import numpy as np

from compile import prng


def test_splitmix_known_vectors_seed0():
    sm = prng.SplitMix64(0)
    assert sm.next_u64() == 0xE220A8397B1DCDAF
    assert sm.next_u64() == 0x6E789E6AA1B965F4
    assert sm.next_u64() == 0x06C45D188009454F


def test_splitmix_known_vector_seed42():
    sm = prng.SplitMix64(42)
    assert sm.next_u64() == 0xBDD732262FEB6E95


def test_psi_structure():
    t = prng.derive_psi(42, 8)
    assert t.shape == (9,)
    assert t[0] == 0
    assert set(np.unique(t)).issubset({0, 1})
    # deterministic
    assert np.array_equal(t, prng.derive_psi(42, 8))


def test_psi_matches_stream():
    t = prng.derive_psi(7, 16)
    sm = prng.SplitMix64(7 ^ prng.PSI_STREAM)
    for v in t[1:]:
        assert v == (sm.next_u64() & 1)


def test_pi_structure_and_determinism():
    pi = prng.derive_pi(7, 1000, 64)
    assert pi.shape == (1000,)
    assert pi.max() < 64
    assert np.array_equal(pi, prng.derive_pi(7, 1000, 64))
    assert not np.array_equal(pi, prng.derive_pi(8, 1000, 64))


def test_pi_matches_stream():
    pi = prng.derive_pi(3, 50, 17)
    sm = prng.SplitMix64(3 ^ prng.PI_STREAM)
    for v in pi:
        assert v == sm.next_u64() % 17


def test_pi_roughly_uniform():
    pi = prng.derive_pi(1, 10000, 100)
    counts = np.bincount(pi, minlength=100)
    assert counts.min() > 50 and counts.max() < 170


def test_psi_matrix_pinned_cross_language():
    """Same matrix is pinned in rust sketch::binem tests."""
    m = prng.derive_psi_matrix(42, 8, 5)
    expect = np.array(
        [
            [0, 0, 0, 1, 1, 1],
            [0, 1, 0, 1, 0, 0],
            [0, 1, 1, 0, 0, 0],
            [0, 0, 0, 1, 1, 0],
            [0, 0, 1, 0, 1, 1],
            [0, 1, 1, 0, 0, 1],
            [0, 1, 0, 0, 1, 0],
            [0, 1, 1, 1, 0, 1],
        ],
        dtype=np.uint8,
    )
    assert np.array_equal(m, expect)


def test_psi_matrix_missing_column_zero():
    m = prng.derive_psi_matrix(7, 100, 12)
    assert m.shape == (100, 13)
    assert np.all(m[:, 0] == 0)
    # roughly balanced bits elsewhere
    frac = m[:, 1:].mean()
    assert 0.4 < frac < 0.6


def test_one_hot():
    pi = np.array([2, 0, 2], dtype=np.uint32)
    p = prng.pi_one_hot(pi, 3)
    expect = np.array([[0, 0, 1], [1, 0, 0], [0, 0, 1]], dtype=np.float32)
    assert np.array_equal(p, expect)
