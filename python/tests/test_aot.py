"""AOT pipeline smoke: every artifact lowers to parseable HLO text with the
right parameter shapes, and sidecars round-trip."""

import json
import os
import re

import numpy as np
import pytest

from compile import aot, prng

SMALL = dict(n=256, c=8, d=64, m=4, mp=8, mq=4, mc=8, seed=5)


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_all(SMALL)


def test_all_artifacts_lower(lowered):
    assert set(lowered) == {
        "cabin_sketch",
        "cham_allpairs",
        "cham_cross",
        "sketch_allpairs",
    }
    for name, text in lowered.items():
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name


def test_hlo_parameter_shapes(lowered):
    # cabin_sketch takes s32[4,256], yields a tuple with f32[4,64]
    text = lowered["cabin_sketch"]
    assert re.search(r"parameter\(0\)", text)
    assert "s32[4,256]" in text
    assert "f32[4,64]" in text
    text = lowered["cham_cross"]
    assert "f32[4,64]" in text and "f32[8,64]" in text


def test_constants_are_printed_in_full(lowered):
    # regression: the default printer elides large constants as `{...}`
    # and the rust-side text parser zero-fills them — every constant must
    # be materialised in the text.
    for name, text in lowered.items():
        assert "constant({...})" not in text, name


def test_constants_are_compact(lowered):
    # the π constant is n ints and ψ is n×(c+1) bits — HLO text must stay
    # manageable (the design avoids baking the n×d one-hot, which would be
    # n·d floats).
    for name, text in lowered.items():
        assert len(text) < 4_000_000, (name, len(text))


def test_sidecars_roundtrip(tmp_path):
    d = str(tmp_path)
    names = aot.write_sidecars(SMALL, d)
    pi = np.fromfile(os.path.join(d, names["pi"]), dtype="<u4")
    assert pi.shape == (SMALL["n"],)
    assert np.array_equal(pi, prng.derive_pi(SMALL["seed"], SMALL["n"], SMALL["d"]))
    psi = np.fromfile(os.path.join(d, names["psi"]), dtype="u1").reshape(
        SMALL["n"], SMALL["c"] + 1
    )
    assert np.array_equal(
        psi, prng.derive_psi_matrix(SMALL["seed"], SMALL["n"], SMALL["c"])
    )


def test_manifest_written_by_default_build():
    # `make artifacts` must have produced a coherent manifest (skip if the
    # artifacts haven't been built in this checkout yet).
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts/ not built")
    with open(path) as f:
        m = json.load(f)
    assert set(m["artifacts"]) == {
        "cabin_sketch",
        "cham_allpairs",
        "cham_cross",
        "sketch_allpairs",
    }
    cfg = m["config"]
    for a in m["artifacts"].values():
        assert os.path.exists(os.path.join(os.path.dirname(path), a["hlo"]))
    assert cfg["d"] % 256 == 0  # MXU-aligned artifact dimension
