//! Clustering pipeline (the paper's Section 5.4 workflow): generate the
//! NYTimes twin, produce ground truth with k-mode on the full data, then
//! cluster 1000-dimensional Cabin sketches and report quality + speedup.
//!
//! ```bash
//! cargo run --release --example clustering_pipeline [-- --points 400 --k 5]
//! ```

use cabin::baselines::by_key;
use cabin::cluster::{
    adjusted_rand_index, kmode, kmode_binary, normalized_mutual_information, purity,
};
use cabin::data::registry::DatasetSpec;
use cabin::util::cli::Args;
use cabin::util::timer::Stopwatch;

fn main() {
    let args = Args::from_env();
    let points = args.usize_or("points", 300);
    let k = args.usize_or("k", 5);
    let d = args.usize_or("dim", 1000);
    let iters = args.usize_or("iters", 25);
    let seed = args.u64_or("seed", 42);

    let spec = DatasetSpec::by_key("nytimes").unwrap();
    let ds = spec.load_or_synth("data/uci", points, seed);
    println!(
        "NYTimes twin: {} points, dim {}, sparsity {:.2}%",
        ds.len(),
        ds.dim(),
        100.0 * ds.sparsity()
    );

    // Ground truth: k-mode on the full-dimensional data.
    let sw = Stopwatch::start();
    let truth = kmode(&ds, k, iters, seed);
    let t_full = sw.elapsed_secs();
    println!(
        "full-dim k-mode: {:.3}s ({} iters, cost {:.0})",
        t_full, truth.iterations, truth.cost
    );

    // Reduce with Cabin, cluster the sketches.
    let sw = Stopwatch::start();
    let red = by_key("cabin").unwrap().reduce(&ds, d, seed);
    let t_reduce = sw.elapsed_secs();
    let sw = Stopwatch::start();
    let sketch_clust = kmode_binary(red.as_bits().unwrap(), k, iters, seed);
    let t_cluster = sw.elapsed_secs();

    let p = purity(&truth.assignments, &sketch_clust.assignments);
    let nmi = normalized_mutual_information(&truth.assignments, &sketch_clust.assignments);
    let ari = adjusted_rand_index(&truth.assignments, &sketch_clust.assignments);
    println!(
        "cabin d={d}: reduce {:.3}s + cluster {:.3}s  (clustering speedup {:.1}x)",
        t_reduce,
        t_cluster,
        t_full / t_cluster.max(1e-9)
    );
    println!("quality vs ground truth: purity {p:.3}  NMI {nmi:.3}  ARI {ari:.3}");

    // Same protocol through a real-valued baseline for contrast.
    let sw = Stopwatch::start();
    let lsa = by_key("lsa").unwrap().reduce(&ds, d.min(ds.len() - 1), seed);
    let t_lsa = sw.elapsed_secs();
    let km = cabin::cluster::kmeans(&lsa.to_matrix(), k, iters, seed);
    let p2 = purity(&truth.assignments, &km.assignments);
    println!(
        "lsa  d={}: reduce {:.3}s, purity {:.3} (k-means on real embedding)",
        d.min(ds.len() - 1),
        t_lsa,
        p2
    );
}
