//! End-to-end system driver (the EXPERIMENTS.md validation run): start the
//! coordinator as a real TCP service, drive it with concurrent clients
//! over the wire — batched inserts, single and batched top-k queries — and
//! report throughput,
//! latency percentiles, batching efficiency, and backend (XLA artifacts
//! when present and matching, else native).
//!
//! ```bash
//! make artifacts   # optional: enables the XLA sketching backend
//! cargo run --release --example e2e_service [-- --corpus 2000 --queries 200 --clients 8]
//! ```

use cabin::coordinator::client::Client;
use cabin::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, IndexConfig};
use cabin::data::synth::SynthSpec;
use cabin::util::cli::Args;
use cabin::util::timer::{LatencyStats, Stopwatch};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let corpus_n = args.usize_or("corpus", 2000);
    let queries_n = args.usize_or("queries", 200);
    let clients = args.usize_or("clients", 8);
    let k = args.usize_or("k", 10);

    // Corpus matches the AOT artifact configuration (n=4096, c=64,
    // d=1024, seed=42) so the XLA backend engages when artifacts exist.
    let mut spec = SynthSpec::small_demo();
    spec.dim = 4096;
    spec.num_categories = 64;
    spec.num_points = corpus_n;
    let ds = spec.generate(5);
    let mut qspec = spec.clone();
    qspec.num_points = queries_n;
    let queries = qspec.generate(6);

    let config = CoordinatorConfig {
        input_dim: 4096,
        num_categories: 64,
        sketch_dim: 1024,
        seed: 42,
        num_shards: 4,
        batcher: BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 4096,
        },
        use_xla: !args.flag("no-xla"),
        heatmap_limit: 4096,
        // --index on|off|auto (default auto: the LSH candidate path kicks
        // in once shards outgrow the exact-scan sweet spot)
        index: IndexConfig {
            mode: IndexConfig::mode_from_str_or_warn(&args.str_or("index", "auto"), "e2e"),
            ..Default::default()
        },
        persist: Default::default(),
        ..Default::default()
    };
    println!("[e2e] index mode: {:?}", config.index.mode);
    let coordinator = Arc::new(Coordinator::new(config));
    let server = Arc::clone(&coordinator);
    let (addr_tx, addr_rx) = std::sync::mpsc::sync_channel(1);
    let server_thread = std::thread::spawn(move || {
        server
            .serve("127.0.0.1:0", |addr| {
                let _ = addr_tx.send(addr);
            })
            .unwrap();
    });
    let addr = addr_rx.recv().expect("server bound");
    println!("[e2e] coordinator listening on {addr}");

    // ---- phase 1: concurrent ingest over TCP ----
    // ids are assigned by the coordinator in *arrival* order (interleaved
    // across clients), so keep the dataset-index → id mapping per insert.
    let sw = Stopwatch::start();
    let chunk = ds.len().div_ceil(clients);
    let insert_lat = std::sync::Mutex::new(LatencyStats::new());
    let id_pairs: Vec<(usize, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = ds
            .points
            .chunks(chunk)
            .enumerate()
            .map(|(ci, part)| {
                let insert_lat = &insert_lat;
                s.spawn(move || {
                    let mut c = Client::connect(&addr.to_string()).unwrap();
                    let mut out = Vec::with_capacity(part.len());
                    for (off, p) in part.iter().enumerate() {
                        let t = Stopwatch::start();
                        let id = c.insert(p.clone()).unwrap();
                        insert_lat.lock().unwrap().record(t.elapsed_secs());
                        out.push((ci * chunk + off, id));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let mut id_of = vec![usize::MAX; ds.len()];
    for (idx, id) in id_pairs {
        id_of[idx] = id;
    }
    let ingest_secs = sw.elapsed_secs();
    let ins = insert_lat.lock().unwrap().summary();
    println!(
        "[e2e] ingest: {} vectors, {} clients, {:.3}s → {:.0} inserts/s  (p50 {:.2} ms, p99 {:.2} ms)",
        ds.len(),
        clients,
        ingest_secs,
        ds.len() as f64 / ingest_secs,
        ins.p50 * 1e3,
        ins.p99 * 1e3
    );
    println!(
        "[e2e] batching: mean flushed batch = {:.1}",
        coordinator.metrics.mean_batch_size()
    );

    // ---- phase 2: concurrent queries + recall vs brute force ----
    let sw = Stopwatch::start();
    let qchunk = queries.len().div_ceil(clients);
    let results: Vec<(usize, Vec<usize>)> = std::thread::scope(|s| {
        let handles: Vec<_> = queries
            .points
            .chunks(qchunk)
            .enumerate()
            .map(|(ci, part)| {
                s.spawn(move || {
                    let mut c = Client::connect(&addr.to_string()).unwrap();
                    let mut out = Vec::new();
                    for (qi, p) in part.iter().enumerate() {
                        let hits = c.query(p.clone(), k).unwrap();
                        out.push((ci * qchunk + qi, hits.iter().map(|h| h.id).collect()));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let query_secs = sw.elapsed_secs();
    println!(
        "[e2e] queries: {} in {:.3}s → {:.0} queries/s ({:.2} ms mean)",
        queries.len(),
        query_secs,
        queries.len() as f64 / query_secs,
        1e3 * query_secs / queries.len() as f64
    );

    let mut hits_at_k = 0usize;
    for (qi, ids) in &results {
        let best = (0..ds.len())
            .min_by_key(|&i| queries.points[*qi].hamming(&ds.points[i]))
            .unwrap();
        if ids.contains(&id_of[best]) {
            hits_at_k += 1;
        }
    }
    println!(
        "[e2e] recall@{k} of true nearest neighbour: {}/{} = {:.1}%",
        hits_at_k,
        queries.len(),
        100.0 * hits_at_k as f64 / queries.len() as f64
    );

    // ---- phase 2b: the same queries, one batched round-trip per client ----
    let sw = Stopwatch::start();
    let batched: Vec<(usize, Vec<usize>)> = std::thread::scope(|s| {
        let handles: Vec<_> = queries
            .points
            .chunks(qchunk)
            .enumerate()
            .map(|(ci, part)| {
                s.spawn(move || {
                    let mut c = Client::connect(&addr.to_string()).unwrap();
                    c.query_batch(part.to_vec(), k)
                        .unwrap()
                        .into_iter()
                        .enumerate()
                        .map(|(qi, hits)| {
                            (ci * qchunk + qi, hits.iter().map(|h| h.id).collect())
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let batch_secs = sw.elapsed_secs();
    println!(
        "[e2e] batched queries: {} in {:.3}s → {:.0} queries/s ({:.2}× the single-query path)",
        queries.len(),
        batch_secs,
        queries.len() as f64 / batch_secs,
        query_secs / batch_secs
    );
    // the batched path must return exactly what the single-query path did
    let mut single_sorted = results.clone();
    single_sorted.sort_by_key(|r| r.0);
    let mut batch_sorted = batched;
    batch_sorted.sort_by_key(|r| r.0);
    assert_eq!(single_sorted, batch_sorted, "batched ≠ single results");
    println!("[e2e] batched results identical to single-query results — OK");

    // ---- phase 3: service stats + shutdown ----
    let mut admin = Client::connect(&addr.to_string()).unwrap();
    for (name, v) in admin.stats().unwrap() {
        println!("[e2e] stat {name} = {v:.2}");
    }
    admin.shutdown().unwrap();
    server_thread.join().unwrap();
    println!("[e2e] clean shutdown — OK");
}
