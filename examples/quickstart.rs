//! Quickstart: sketch categorical vectors with Cabin, estimate Hamming
//! distances with Cham, compare against ground truth.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cabin::data::synth::SynthSpec;
use cabin::sketch::{cham, recommended_dim, CabinSketcher};

fn main() {
    // A synthetic categorical dataset: 10k dimensions, ≤64 categories,
    // ~99% sparse — the regime the paper targets.
    let mut spec = SynthSpec::small_demo();
    spec.num_points = 200;
    let ds = spec.generate(7);
    println!(
        "dataset: {} points, dim {}, sparsity {:.2}%, max density s = {}",
        ds.len(),
        ds.dim(),
        100.0 * ds.sparsity(),
        ds.max_density()
    );

    // Theorem 2's dimension for δ=0.1 — and the much smaller d that works
    // in practice (the paper's own observation).
    let d_theory = recommended_dim(ds.max_density(), 0.1);
    let d = 512;
    println!("sketch dim: theory suggests {d_theory}, using {d} (practical)");

    let sketcher = CabinSketcher::new(ds.dim(), ds.num_categories(), d, 42);
    let sketches = sketcher.sketch_dataset(&ds, 4);

    // Memory: label-encoded sparse vs packed binary sketches.
    let orig_bytes: usize = ds.points.iter().map(|p| p.nnz() * 6).sum();
    let sketch_bytes: usize = sketches.iter().map(|s| s.memory_bytes()).sum();
    println!(
        "memory: {} original → {} sketched ({:.1}x smaller)",
        cabin::util::human_bytes(orig_bytes),
        cabin::util::human_bytes(sketch_bytes),
        orig_bytes as f64 / sketch_bytes as f64
    );

    // Estimate a few pairwise distances and compare with the truth.
    println!("\n pair     truth   Cham estimate   |error|");
    let mut total_rel = 0.0;
    let mut count = 0;
    for i in 0..6 {
        for j in (i + 1)..6 {
            let truth = ds.points[i].hamming(&ds.points[j]) as f64;
            let est = cham::estimate_hamming(&sketches[i], &sketches[j], sketcher.config());
            println!(
                " ({i},{j})   {truth:>6.0}   {est:>12.1}   {:>7.1}",
                (est - truth).abs()
            );
            if truth > 0.0 {
                total_rel += (est - truth).abs() / truth;
                count += 1;
            }
        }
    }
    println!(
        "\nmean relative error over {count} pairs: {:.1}%",
        100.0 * total_rel / count as f64
    );

    // The sketches also estimate binary-level similarity measures.
    let (a, b) = (&sketches[0], &sketches[1]);
    println!(
        "bonus estimators — inner product: {:.1}, cosine: {:.3}, jaccard: {:.3}",
        cham::estimate_inner_product(a, b),
        cham::estimate_cosine(a, b),
        cham::estimate_jaccard(a, b)
    );
}
