//! Similarity search through the coordinator (in-process): build a corpus,
//! insert it through the dynamic batcher, run top-k queries, and check the
//! results against brute-force categorical Hamming distance.
//!
//! ```bash
//! cargo run --release --example similarity_search
//! ```

use cabin::coordinator::{Coordinator, CoordinatorConfig, Request, Response};
use cabin::data::synth::SynthSpec;
use cabin::util::timer::Stopwatch;

fn stats(coordinator: &Coordinator) -> Vec<(String, f64)> {
    match coordinator.handle_request(Request::Stats) {
        Response::Stats { fields } => fields,
        _ => Vec::new(),
    }
}

fn main() {
    let mut spec = SynthSpec::small_demo();
    spec.num_points = 500;
    spec.dim = 4096;
    spec.num_categories = 64;
    let ds = spec.generate(11);

    let config = CoordinatorConfig {
        input_dim: ds.dim(),
        num_categories: ds.num_categories(),
        sketch_dim: 1024,
        seed: 42,
        num_shards: 4,
        ..Default::default()
    };
    let coordinator = Coordinator::new(config);

    // Ingest the corpus through the batcher.
    let sw = Stopwatch::start();
    for p in &ds.points {
        match coordinator.handle_request(Request::Insert { vec: p.clone() }) {
            Response::Inserted { .. } => {}
            other => panic!("insert failed: {other:?}"),
        }
    }
    let ingest = sw.elapsed_secs();
    println!(
        "ingested {} vectors in {:.3}s ({:.0}/s), mean batch {:.1}",
        ds.len(),
        ingest,
        ds.len() as f64 / ingest,
        coordinator.metrics.mean_batch_size()
    );

    // Query: for held-out probes, compare coordinator top-k with brute force.
    let mut spec2 = spec.clone();
    spec2.num_points = 20;
    let probes = spec2.generate(99);
    let mut agree = 0;
    let k = 5;
    let sw = Stopwatch::start();
    for probe in &probes.points {
        let hits = match coordinator.handle_request(Request::Query {
            vec: probe.clone(),
            k,
        }) {
            Response::Hits { hits } => hits,
            other => panic!("query failed: {other:?}"),
        };
        // brute force over the original corpus
        let best = (0..ds.len())
            .min_by_key(|&i| probe.hamming(&ds.points[i]))
            .unwrap();
        // estimated top-k containing the true best counts as agreement
        if hits.iter().any(|h| h.id == best) {
            agree += 1;
        }
    }
    let qtime = sw.elapsed_secs();
    println!(
        "queries: {} in {:.3}s ({:.1} ms each); true-NN in estimated top-{k}: {}/{}",
        probes.len(),
        qtime,
        1e3 * qtime / probes.len() as f64,
        agree,
        probes.len()
    );
    for (name, v) in stats(&coordinator) {
        println!("  stat {name} = {v:.2}");
    }
}
