//! Lloyd's k-means with k-means++ seeding — run on the *real-valued*
//! baselines' sketches (LSA/LDA/PCA/MCA/NNMF/VAE), exactly as the paper
//! does (Section 5.4: "instead of k-mode, we ran k-means using k-means++
//! sampling").

use super::kmode::{kpp_indices, Clustering};
use crate::linalg::Matrix;
use crate::util::parallel;
use crate::util::rng::Xoshiro256;

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// k-means over the rows of `x`.
pub fn kmeans(x: &Matrix, k: usize, max_iters: usize, seed: u64) -> Clustering {
    let n = x.rows;
    assert!(n >= k && k >= 1);
    let dim = x.cols;
    let mut rng = Xoshiro256::new(seed);
    let init = kpp_indices(n, k, |i, j| sq_dist(x.row(i), x.row(j)).sqrt(), &mut rng);
    let mut centres: Vec<Vec<f64>> = init.iter().map(|&i| x.row(i).to_vec()).collect();
    let mut assign = vec![usize::MAX; n];
    let threads = parallel::default_threads();
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        let new_assign: Vec<usize> = {
            let centres = &centres;
            parallel::par_map(n, threads, |i| {
                let mut best = (f64::INFINITY, 0usize);
                for (c, centre) in centres.iter().enumerate() {
                    let d = sq_dist(x.row(i), centre);
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                best.1
            })
        };
        let changed = new_assign
            .iter()
            .zip(&assign)
            .filter(|(a, b)| a != b)
            .count();
        assign = new_assign;
        if changed == 0 && it > 0 {
            break;
        }
        let mut sums = vec![vec![0.0; dim]; k];
        let mut sizes = vec![0usize; k];
        for (i, &c) in assign.iter().enumerate() {
            sizes[c] += 1;
            for (s, &v) in sums[c].iter_mut().zip(x.row(i)) {
                *s += v;
            }
        }
        for c in 0..k {
            if sizes[c] == 0 {
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sq_dist(x.row(a), &centres[assign[a]])
                            .total_cmp(&sq_dist(x.row(b), &centres[assign[b]]))
                    })
                    .unwrap();
                centres[c] = x.row(far).to_vec();
                continue;
            }
            let inv = 1.0 / sizes[c] as f64;
            for s in sums[c].iter_mut() {
                *s *= inv;
            }
            centres[c] = std::mem::take(&mut sums[c]);
        }
    }
    let cost = (0..n)
        .map(|i| sq_dist(x.row(i), &centres[assign[i]]))
        .sum();
    Clustering {
        assignments: assign,
        iterations,
        cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::metrics::purity;

    #[test]
    fn recovers_gaussian_blobs() {
        let mut rng = Xoshiro256::new(5);
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        let centres = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        for i in 0..120 {
            let c = i % 3;
            truth.push(c);
            rows.push(vec![
                centres[c][0] + rng.normal() * 0.5,
                centres[c][1] + rng.normal() * 0.5,
            ]);
        }
        let x = Matrix::from_rows(rows);
        let res = kmeans(&x, 3, 50, 9);
        assert!(purity(&truth, &res.assignments) > 0.97);
    }

    #[test]
    fn cost_decreases_with_k() {
        let mut rng = Xoshiro256::new(6);
        let x = Matrix::randn(60, 4, &mut rng);
        let c2 = kmeans(&x, 2, 30, 1).cost;
        let c8 = kmeans(&x, 8, 30, 1).cost;
        assert!(c8 < c2, "c8 {} c2 {}", c8, c2);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Xoshiro256::new(7);
        let x = Matrix::randn(40, 3, &mut rng);
        let a = kmeans(&x, 4, 20, 42).assignments;
        let b = kmeans(&x, 4, 20, 42).assignments;
        assert_eq!(a, b);
    }
}
