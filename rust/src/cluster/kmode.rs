//! k-mode clustering (Huang, DMKD 1998) — k-means analogue under Hamming
//! distance for categorical data. Used twice in the reproduction:
//!
//! * on the **full-dimensional** dataset to produce the ground-truth
//!   clustering (the paper's protocol), and
//! * on **binary sketches** ([`kmode_binary`]) where the mode is the
//!   majority bit per position.
//!
//! Both use k-means++-style seeding driven by a shared seed so every
//! method is initialised from the same points (paper Section 5.4).

use crate::data::{CatVector, CategoricalDataset};
use crate::sketch::BitVec;
use crate::util::parallel;
use crate::util::rng::Xoshiro256;
use std::collections::HashMap;

/// Result of a clustering run.
#[derive(Clone, Debug)]
pub struct Clustering {
    pub assignments: Vec<usize>,
    pub iterations: usize,
    /// Sum of point-to-centre Hamming distances at convergence.
    pub cost: f64,
}

/// k-means++ seeding under an arbitrary distance oracle: picks `k` point
/// indices. Shared by the categorical and binary variants (and by k-means,
/// so every method sees the same initial centres for the same seed).
pub fn kpp_indices<D: Fn(usize, usize) -> f64>(
    n: usize,
    k: usize,
    dist: D,
    rng: &mut Xoshiro256,
) -> Vec<usize> {
    assert!(k >= 1 && n >= k);
    let mut centres = Vec::with_capacity(k);
    centres.push(rng.usize_in(0, n));
    let mut d2: Vec<f64> = (0..n).map(|i| dist(i, centres[0]).powi(2)).collect();
    while centres.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.usize_in(0, n)
        } else {
            let mut r = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                r -= w;
                if r <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centres.push(next);
        for i in 0..n {
            let nd = dist(i, next).powi(2);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centres
}

/// k-mode over categorical vectors. Lloyd-style alternation:
/// assign → recompute per-attribute modes → repeat.
pub fn kmode(ds: &CategoricalDataset, k: usize, max_iters: usize, seed: u64) -> Clustering {
    let n = ds.len();
    assert!(n >= k && k >= 1);
    let mut rng = Xoshiro256::new(seed);
    let init = kpp_indices(
        n,
        k,
        |i, j| ds.points[i].hamming(&ds.points[j]) as f64,
        &mut rng,
    );
    let mut centres: Vec<CatVector> = init.iter().map(|&i| ds.points[i].clone()).collect();
    let mut assign = vec![usize::MAX; n];
    let threads = parallel::default_threads();
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // assignment step (parallel)
        let new_assign: Vec<usize> = {
            let centres = &centres;
            parallel::par_map(n, threads, |i| {
                let mut best = (usize::MAX, 0usize);
                for (c, centre) in centres.iter().enumerate() {
                    let d = ds.points[i].hamming(centre);
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                best.1
            })
        };
        let changed = new_assign
            .iter()
            .zip(&assign)
            .filter(|(a, b)| a != b)
            .count();
        assign = new_assign;
        if changed == 0 && it > 0 {
            break;
        }
        // update step: per-cluster per-attribute mode over *present* values;
        // an attribute goes into the mode only if its most frequent value
        // (counting "missing" as a value) is non-missing.
        let mut counts: Vec<HashMap<(u32, u16), usize>> = vec![HashMap::new(); k];
        let mut sizes = vec![0usize; k];
        for (i, &c) in assign.iter().enumerate() {
            sizes[c] += 1;
            for &(attr, val) in ds.points[i].entries() {
                *counts[c].entry((attr, val)).or_insert(0) += 1;
            }
        }
        for c in 0..k {
            if sizes[c] == 0 {
                // empty cluster: reseed from the farthest point
                let far = (0..n)
                    .max_by_key(|&i| ds.points[i].hamming(&centres[assign[i]]))
                    .unwrap();
                centres[c] = ds.points[far].clone();
                continue;
            }
            // best value per attribute
            let mut best: HashMap<u32, (u16, usize)> = HashMap::new();
            for (&(attr, val), &cnt) in &counts[c] {
                let e = best.entry(attr).or_insert((val, cnt));
                if cnt > e.1 || (cnt == e.1 && val < e.0) {
                    *e = (val, cnt);
                }
            }
            let mut pairs: Vec<(u32, u16)> = best
                .into_iter()
                // value wins over "missing" iff present in > half the pts
                .filter(|&(_, (_, cnt))| 2 * cnt > sizes[c])
                .map(|(attr, (val, _))| (attr, val))
                .collect();
            pairs.sort_unstable_by_key(|&(a, _)| a);
            centres[c] = CatVector::from_pairs(ds.dim(), pairs);
        }
    }
    let cost = (0..n)
        .map(|i| ds.points[i].hamming(&centres[assign[i]]) as f64)
        .sum();
    Clustering {
        assignments: assign,
        iterations,
        cost,
    }
}

/// k-mode over binary sketches: distance = Hamming on bits, mode = majority
/// bit. This is what "clustering the Cabin sketches" means.
pub fn kmode_binary(points: &[BitVec], k: usize, max_iters: usize, seed: u64) -> Clustering {
    let n = points.len();
    assert!(n >= k && k >= 1);
    let d = points[0].len();
    let mut rng = Xoshiro256::new(seed);
    let init = kpp_indices(n, k, |i, j| points[i].xor_count(&points[j]) as f64, &mut rng);
    let mut centres: Vec<BitVec> = init.iter().map(|&i| points[i].clone()).collect();
    let mut assign = vec![usize::MAX; n];
    let threads = parallel::default_threads();
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        let new_assign: Vec<usize> = {
            let centres = &centres;
            parallel::par_map(n, threads, |i| {
                let mut best = (usize::MAX, 0usize);
                for (c, centre) in centres.iter().enumerate() {
                    let dist = points[i].xor_count(centre);
                    if dist < best.0 {
                        best = (dist, c);
                    }
                }
                best.1
            })
        };
        let changed = new_assign
            .iter()
            .zip(&assign)
            .filter(|(a, b)| a != b)
            .count();
        assign = new_assign;
        if changed == 0 && it > 0 {
            break;
        }
        // majority bit per position
        let mut ones = vec![vec![0usize; d]; k];
        let mut sizes = vec![0usize; k];
        for (i, &c) in assign.iter().enumerate() {
            sizes[c] += 1;
            for b in points[i].iter_ones() {
                ones[c][b] += 1;
            }
        }
        for c in 0..k {
            if sizes[c] == 0 {
                let far = (0..n)
                    .max_by_key(|&i| points[i].xor_count(&centres[assign[i]]))
                    .unwrap();
                centres[c] = points[far].clone();
                continue;
            }
            let mut centre = BitVec::zeros(d);
            for (b, &cnt) in ones[c].iter().enumerate() {
                if 2 * cnt > sizes[c] {
                    centre.set(b);
                }
            }
            centres[c] = centre;
        }
    }
    let cost = (0..n)
        .map(|i| points[i].xor_count(&centres[assign[i]]) as f64)
        .sum();
    Clustering {
        assignments: assign,
        iterations,
        cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::metrics::purity;
    use crate::data::synth::SynthSpec;

    #[test]
    fn kpp_returns_distinct_indices_mostly() {
        let mut rng = Xoshiro256::new(1);
        let pts: Vec<f64> = vec![0.0, 0.1, 5.0, 5.1, 10.0, 10.1];
        let idx = kpp_indices(6, 3, |i, j| (pts[i] - pts[j]).abs(), &mut rng);
        assert_eq!(idx.len(), 3);
        // should pick one from each well-separated pair
        let mut groups: Vec<usize> = idx.iter().map(|&i| i / 2).collect();
        groups.sort_unstable();
        groups.dedup();
        assert_eq!(groups.len(), 3, "idx {:?}", idx);
    }

    #[test]
    fn kmode_recovers_planted_clusters() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 90;
        spec.topics = 3;
        spec.topic_sharpness = 0.95;
        spec.dim = 2000;
        let (ds, labels) = spec.generate_labeled(11);
        let res = kmode(&ds, 3, 30, 7);
        let p = purity(&labels, &res.assignments);
        assert!(p > 0.8, "purity {}", p);
        assert!(res.iterations >= 2);
    }

    #[test]
    fn kmode_binary_recovers_planted_bits() {
        // three bit-prototypes with small noise
        let mut rng = Xoshiro256::new(3);
        let d = 256;
        let protos: Vec<BitVec> = (0..3)
            .map(|_| BitVec::from_indices(d, rng.sample_indices(d, 60)))
            .collect();
        let mut pts = Vec::new();
        let mut truth = Vec::new();
        for i in 0..90 {
            let c = i % 3;
            truth.push(c);
            let mut p = protos[c].clone();
            for _ in 0..6 {
                let b = rng.usize_in(0, d);
                if p.get(b) {
                    p.clear(b);
                } else {
                    p.set(b);
                }
            }
            pts.push(p);
        }
        let res = kmode_binary(&pts, 3, 30, 5);
        let p = purity(&truth, &res.assignments);
        assert!(p > 0.9, "purity {}", p);
    }

    #[test]
    fn cost_is_consistent() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 40;
        let ds = spec.generate(2);
        let res = kmode(&ds, 4, 10, 1);
        assert!(res.cost >= 0.0);
        assert_eq!(res.assignments.len(), 40);
        assert!(res.assignments.iter().all(|&a| a < 4));
    }

    #[test]
    fn k_equals_n_perfect() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 5;
        let ds = spec.generate(4);
        let res = kmode(&ds, 5, 10, 3);
        let mut sorted = res.assignments.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5); // every point its own cluster
        assert_eq!(res.cost, 0.0);
    }
}
