//! Clustering substrate for the paper's evaluation (Figures 6–10).
//!
//! * [`kmode`] — Huang's k-mode for categorical / Hamming data (the paper's
//!   ground-truth producer and the algorithm run on discrete sketches).
//! * [`kmeans`] — Lloyd's k-means with k-means++ seeding (run on the
//!   real-valued baselines' sketches, exactly as the paper does).
//! * [`metrics`] — purity index, NMI, ARI (Subsection 3.2).
//!
//! Both algorithms accept a shared seed so all methods start from the same
//! initial centre *indices*, mirroring the paper's "same random seed for all
//! baselines" protocol.

pub mod kmeans;
pub mod kmode;
pub mod metrics;

pub use kmeans::kmeans;
pub use kmode::{kmode, kmode_binary};
pub use metrics::{adjusted_rand_index, normalized_mutual_information, purity};
