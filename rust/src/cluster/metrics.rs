//! Clustering quality metrics (paper Subsection 3.2): purity index,
//! normalised mutual information, adjusted Rand index.

/// Contingency table between two labelings.
fn contingency(truth: &[usize], pred: &[usize]) -> (Vec<Vec<usize>>, Vec<usize>, Vec<usize>) {
    assert_eq!(truth.len(), pred.len());
    let kt = truth.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    let kp = pred.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    let mut table = vec![vec![0usize; kp]; kt];
    for (&t, &p) in truth.iter().zip(pred) {
        table[t][p] += 1;
    }
    let a: Vec<usize> = table.iter().map(|row| row.iter().sum()).collect();
    let mut b = vec![0usize; kp];
    for row in &table {
        for (j, &v) in row.iter().enumerate() {
            b[j] += v;
        }
    }
    (table, a, b)
}

/// Purity index: `1/m · Σ_j max_i |ω_i ∩ c_j|` ∈ [0,1].
pub fn purity(truth: &[usize], pred: &[usize]) -> f64 {
    if truth.is_empty() {
        return 0.0;
    }
    let (table, _, b) = contingency(truth, pred);
    let kp = b.len();
    let mut total = 0usize;
    for j in 0..kp {
        let best = table.iter().map(|row| row[j]).max().unwrap_or(0);
        total += best;
    }
    total as f64 / truth.len() as f64
}

/// Normalised mutual information: `I(Ω;C) / √(H(Ω)·H(C))` ∈ [0,1].
/// (The paper prints the un-normalised MI formula but calls it NMI and
/// reports values in [0,1]; we use the standard √-normalised variant.)
pub fn normalized_mutual_information(truth: &[usize], pred: &[usize]) -> f64 {
    let m = truth.len();
    if m == 0 {
        return 0.0;
    }
    let (table, a, b) = contingency(truth, pred);
    let mf = m as f64;
    let mut mi = 0.0;
    for (i, row) in table.iter().enumerate() {
        for (j, &nij) in row.iter().enumerate() {
            if nij == 0 {
                continue;
            }
            let nij = nij as f64;
            mi += nij / mf * ((mf * nij) / (a[i] as f64 * b[j] as f64)).ln();
        }
    }
    let h = |counts: &[usize]| -> f64 {
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / mf;
                -p * p.ln()
            })
            .sum()
    };
    let (ht, hp) = (h(&a), h(&b));
    if ht <= 0.0 || hp <= 0.0 {
        // one side is a single cluster: MI is 0; conventionally NMI = 1 if
        // both are single identical clusters, else 0.
        return if ht == hp { 1.0 } else { 0.0 };
    }
    (mi / (ht * hp).sqrt()).clamp(0.0, 1.0)
}

fn comb2(n: usize) -> f64 {
    let n = n as f64;
    n * (n - 1.0) / 2.0
}

/// Adjusted Rand index ∈ [-1,1].
pub fn adjusted_rand_index(truth: &[usize], pred: &[usize]) -> f64 {
    let m = truth.len();
    if m < 2 {
        return 1.0;
    }
    let (table, a, b) = contingency(truth, pred);
    let sum_ij: f64 = table
        .iter()
        .flat_map(|row| row.iter())
        .map(|&v| comb2(v))
        .sum();
    let sum_a: f64 = a.iter().map(|&v| comb2(v)).sum();
    let sum_b: f64 = b.iter().map(|&v| comb2(v)).sum();
    let total = comb2(m);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // degenerate: both labelings trivial
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering_scores_one() {
        let t = vec![0, 0, 1, 1, 2, 2];
        assert!((purity(&t, &t) - 1.0).abs() < 1e-12);
        assert!((normalized_mutual_information(&t, &t) - 1.0).abs() < 1e-9);
        assert!((adjusted_rand_index(&t, &t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn permuted_labels_still_perfect() {
        let t = vec![0, 0, 1, 1, 2, 2];
        let p = vec![2, 2, 0, 0, 1, 1];
        assert!((purity(&t, &p) - 1.0).abs() < 1e-12);
        assert!((normalized_mutual_information(&t, &p) - 1.0).abs() < 1e-9);
        assert!((adjusted_rand_index(&t, &p) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_labels_score_low() {
        // alternating truth vs "split in half" pred
        let t: Vec<usize> = (0..100).map(|i| i % 2).collect();
        let p: Vec<usize> = (0..100).map(|i| (i >= 50) as usize).collect();
        let ari = adjusted_rand_index(&t, &p);
        assert!(ari.abs() < 0.05, "ari {}", ari);
        let nmi = normalized_mutual_information(&t, &p);
        assert!(nmi < 0.05, "nmi {}", nmi);
    }

    #[test]
    fn purity_hand_example() {
        // Manning IR book example: clusters x=[A A A A A B], o=[A B B B B C],
        // d=[A A C C C C] → purity = (5+4+3)/17
        let truth = vec![
            0, 0, 0, 0, 0, 1, // cluster 0
            0, 1, 1, 1, 1, 2, // cluster 1
            0, 0, 2, 2, 2, // cluster 2 (5 items)
        ];
        let pred = vec![0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2];
        let p = purity(&truth, &pred);
        assert!((p - 12.0 / 17.0).abs() < 1e-9, "purity {}", p);
    }

    #[test]
    fn ari_known_value() {
        // sklearn doc example: ARI([0,0,1,1],[0,0,1,2]) = 0.5714285714
        let ari = adjusted_rand_index(&[0, 0, 1, 1], &[0, 0, 1, 2]);
        assert!((ari - 0.5714285714285714).abs() < 1e-9, "ari {}", ari);
    }

    #[test]
    fn nmi_symmetry() {
        let t = vec![0, 0, 1, 1, 2, 2, 2];
        let p = vec![0, 1, 1, 1, 0, 2, 2];
        let a = normalized_mutual_information(&t, &p);
        let b = normalized_mutual_information(&p, &t);
        assert!((a - b).abs() < 1e-12);
        assert!(a > 0.0 && a < 1.0);
    }

    #[test]
    fn single_cluster_degenerate() {
        let t = vec![0, 0, 0];
        let p = vec![0, 0, 0];
        assert_eq!(normalized_mutual_information(&t, &p), 1.0);
        assert_eq!(adjusted_rand_index(&t, &p), 1.0);
    }
}
