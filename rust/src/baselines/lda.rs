//! Latent Dirichlet Allocation [Blei–Ng–Jordan 2003] via collapsed Gibbs
//! sampling [Griffiths & Steyvers 2004]. The embedding of a document is its
//! smoothed topic proportion vector θ̂ (m × k).
//!
//! Cost per sweep is Θ(total tokens × k) — with the paper's d up to 3000
//! topics this is the "441× slower than Cabin on NYTimes" row of Table 3.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256;

pub struct Lda {
    pub sweeps: usize,
    pub alpha: f64,
    pub beta: f64,
}

impl Default for Lda {
    fn default() -> Self {
        Self {
            sweeps: 30,
            alpha: 0.1,
            beta: 0.01,
        }
    }
}

impl DimReducer for Lda {
    fn key(&self) -> &'static str {
        "lda"
    }

    fn name(&self) -> &'static str {
        "LDA [6]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let k = dim.max(1);
        let vocab = ds.dim();
        let m = ds.len();
        let mut rng = Xoshiro256::new(seed ^ 0x1da);

        // token stream: (doc, word) with multiplicity = categorical value
        // capped (BoW counts are the categories).
        let mut doc_of = Vec::new();
        let mut word_of = Vec::new();
        for (d, p) in ds.points.iter().enumerate() {
            for &(w, v) in p.entries() {
                for _ in 0..(v as usize).min(4) {
                    doc_of.push(d as u32);
                    word_of.push(w);
                }
            }
        }
        let tokens = doc_of.len();
        let mut z: Vec<u32> = (0..tokens).map(|_| rng.gen_range(k as u64) as u32).collect();

        let mut n_dk = vec![0u32; m * k];
        let mut n_kw = vec![0u32; k * vocab];
        let mut n_k = vec![0u32; k];
        for t in 0..tokens {
            let (d, w, topic) = (doc_of[t] as usize, word_of[t] as usize, z[t] as usize);
            n_dk[d * k + topic] += 1;
            n_kw[topic * vocab + w] += 1;
            n_k[topic] += 1;
        }

        let vb = vocab as f64 * self.beta;
        let mut probs = vec![0.0f64; k];
        for _sweep in 0..self.sweeps {
            for t in 0..tokens {
                let (d, w) = (doc_of[t] as usize, word_of[t] as usize);
                let old = z[t] as usize;
                n_dk[d * k + old] -= 1;
                n_kw[old * vocab + w] -= 1;
                n_k[old] -= 1;
                for (topic, p) in probs.iter_mut().enumerate() {
                    *p = (n_dk[d * k + topic] as f64 + self.alpha)
                        * (n_kw[topic * vocab + w] as f64 + self.beta)
                        / (n_k[topic] as f64 + vb);
                }
                let new = rng.discrete(&probs);
                z[t] = new as u32;
                n_dk[d * k + new] += 1;
                n_kw[new * vocab + w] += 1;
                n_k[new] += 1;
            }
        }

        // θ̂_dk = (n_dk + α) / (n_d + kα)
        let mut emb = Matrix::zeros(m, k);
        for d in 0..m {
            let nd: f64 = (0..k).map(|t| n_dk[d * k + t] as f64).sum();
            for t in 0..k {
                emb.set(
                    d,
                    t,
                    (n_dk[d * k + t] as f64 + self.alpha) / (nd + k as f64 * self.alpha),
                );
            }
        }
        Reduced::Real { embedding: emb }
    }

    fn is_discrete(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{kmeans, metrics::purity};
    use crate::data::synth::SynthSpec;

    #[test]
    fn theta_rows_are_distributions() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 20;
        spec.dim = 300;
        let ds = spec.generate(3);
        let red = Lda { sweeps: 5, ..Default::default() }.reduce(&ds, 4, 1);
        let m = red.to_matrix();
        for r in 0..m.rows {
            let s: f64 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "row {r} sums {s}");
            assert!(m.row(r).iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn recovers_topic_structure() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 60;
        spec.topics = 3;
        spec.topic_sharpness = 0.95;
        spec.dim = 600;
        let (ds, labels) = spec.generate_labeled(17);
        let red = Lda { sweeps: 40, ..Default::default() }.reduce(&ds, 3, 5);
        let res = kmeans(&red.to_matrix(), 3, 40, 7);
        let p = purity(&labels, &res.assignments);
        assert!(p > 0.65, "purity {p}");
    }
}
