//! Variational auto-encoder [Kingma & Welling, ICLR 2014] — a single-hidden-
//! layer MLP encoder/decoder with the reparameterisation trick, Bernoulli
//! reconstruction on the BinEm-binarised data, trained with manual backprop
//! + Adam (no autodiff framework offline).
//!
//! Architecture: `x ∈ {0,1}^n → h (tanh) → (μ, logσ²) ∈ R^k → z → h' (tanh)
//! → x̂ (sigmoid)`. The embedding is μ(x).
//!
//! The dense n×h input layer is exactly the memory profile that makes the
//! paper report VAE OOM on every dataset but KOS — at n = 1.3M and h = 256
//! the encoder alone is ~2.7 GB of f64, before activations.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::linalg::opt::Adam;
use crate::linalg::Matrix;
use crate::sketch::{BinEm, PsiMode};
use crate::util::rng::Xoshiro256;

pub struct Vae {
    pub hidden: usize,
    pub epochs: usize,
    pub batch: usize,
    pub lr: f64,
}

impl Default for Vae {
    fn default() -> Self {
        Self {
            hidden: 64,
            epochs: 15,
            batch: 16,
            lr: 1e-3,
        }
    }
}

struct Params {
    /// encoder: W1 (n×h), b1 (h), Wmu (h×k), bmu (k), Wlv (h×k), blv (k)
    /// decoder: W2 (k×h), b2 (h), W3 (h×n), b3 (n)
    data: Vec<f64>,
    n: usize,
    h: usize,
    k: usize,
}

impl Params {
    fn new(n: usize, h: usize, k: usize, rng: &mut Xoshiro256) -> Self {
        let total = n * h + h + h * k + k + h * k + k + k * h + h + h * n + n;
        let mut data = Vec::with_capacity(total);
        let scales = [
            (n * h, (1.0 / n as f64).sqrt()),
            (h, 0.0),
            (h * k, (1.0 / h as f64).sqrt()),
            (k, 0.0),
            (h * k, (1.0 / h as f64).sqrt()),
            (k, 0.0),
            (k * h, (1.0 / k as f64).sqrt()),
            (h, 0.0),
            (h * n, (1.0 / h as f64).sqrt()),
            (n, 0.0),
        ];
        for (cnt, s) in scales {
            for _ in 0..cnt {
                data.push(if s == 0.0 { 0.0 } else { rng.normal() * s });
            }
        }
        Self { data, n, h, k }
    }

    // offsets
    fn off(&self) -> [usize; 10] {
        let (n, h, k) = (self.n, self.h, self.k);
        let mut o = [0usize; 10];
        let sizes = [n * h, h, h * k, k, h * k, k, k * h, h, h * n, n];
        let mut acc = 0;
        for i in 0..10 {
            o[i] = acc;
            acc += sizes[i];
        }
        o
    }
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl DimReducer for Vae {
    fn key(&self) -> &'static str {
        "vae"
    }

    fn name(&self) -> &'static str {
        "VAE [21]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let n = ds.dim();
        let h = self.hidden;
        let k = dim.max(1);
        let mut rng = Xoshiro256::new(seed ^ 0xae);
        let binem = BinEm::new(n, ds.num_categories(), PsiMode::PerAttribute, seed);
        // binarised sparse inputs: nonzero index lists
        let xs: Vec<Vec<usize>> = ds
            .points
            .iter()
            .map(|p| binem.encode_ones(p).collect())
            .collect();

        let mut params = Params::new(n, h, k, &mut rng);
        let o = params.off();
        let mut adam = Adam::new(params.data.len(), self.lr);
        let mut grads = vec![0.0f64; params.data.len()];

        let m = ds.len();
        for _epoch in 0..self.epochs {
            let mut order: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut order);
            for chunk in order.chunks(self.batch) {
                grads.iter_mut().for_each(|g| *g = 0.0);
                for &idx in chunk {
                    let x = &xs[idx];
                    let p = &params.data;
                    // ---- forward ----
                    // h1 = tanh(W1ᵀ 1_x + b1)  (sparse input: sum rows of W1)
                    let mut a1 = vec![0.0f64; h];
                    for &i in x {
                        let row = &p[o[0] + i * h..o[0] + (i + 1) * h];
                        for (a, &w) in a1.iter_mut().zip(row) {
                            *a += w;
                        }
                    }
                    for (j, a) in a1.iter_mut().enumerate() {
                        *a = (*a + p[o[1] + j]).tanh();
                    }
                    // mu, logvar
                    let mut mu = vec![0.0f64; k];
                    let mut lv = vec![0.0f64; k];
                    for j in 0..h {
                        let aj = a1[j];
                        for t in 0..k {
                            mu[t] += aj * p[o[2] + j * k + t];
                            lv[t] += aj * p[o[4] + j * k + t];
                        }
                    }
                    for t in 0..k {
                        mu[t] += p[o[3] + t];
                        lv[t] = (lv[t] + p[o[5] + t]).clamp(-6.0, 6.0);
                    }
                    // z = mu + eps*sigma
                    let eps: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
                    let sigma: Vec<f64> = lv.iter().map(|&l| (0.5 * l).exp()).collect();
                    let z: Vec<f64> = (0..k).map(|t| mu[t] + eps[t] * sigma[t]).collect();
                    // h2 = tanh(W2ᵀ z + b2)
                    let mut a2 = vec![0.0f64; h];
                    for t in 0..k {
                        let zt = z[t];
                        let row = &p[o[6] + t * h..o[6] + (t + 1) * h];
                        for (a, &w) in a2.iter_mut().zip(row) {
                            *a += zt * w;
                        }
                    }
                    for (j, a) in a2.iter_mut().enumerate() {
                        *a = (*a + p[o[7] + j]).tanh();
                    }
                    // logits = W3ᵀ h2 + b3 — only evaluate dense for grad
                    // purposes on the positive set + a negative sample
                    // (full-n backprop per example is the honest-but-OOM
                    // path; we subsample negatives 4:1 which preserves the
                    // gradient direction in expectation).
                    let mut neg: Vec<usize> = Vec::with_capacity(4 * x.len().max(4));
                    let pos: std::collections::HashSet<usize> = x.iter().copied().collect();
                    while neg.len() < 4 * x.len().max(4) {
                        let c = rng.usize_in(0, n);
                        if !pos.contains(&c) {
                            neg.push(c);
                        }
                    }
                    let eval_set: Vec<(usize, f64)> = x
                        .iter()
                        .map(|&i| (i, 1.0))
                        .chain(neg.iter().map(|&i| (i, 0.0)))
                        .collect();
                    // ---- backward (manual) ----
                    // d_logit = sigmoid(logit) − target  (BCE w/ logits)
                    let mut d_a2 = vec![0.0f64; h];
                    for &(i, target) in &eval_set {
                        let wrow = &p[o[8]..]; // W3 is h×n: w3[j*n + i]
                        let mut logit = p[o[9] + i];
                        for j in 0..h {
                            logit += a2[j] * wrow[j * n + i];
                        }
                        let dl = sigmoid(logit) - target;
                        // grads for W3 col i and b3
                        for j in 0..h {
                            grads[o[8] + j * n + i] += dl * a2[j];
                            d_a2[j] += dl * wrow[j * n + i];
                        }
                        grads[o[9] + i] += dl;
                    }
                    // through tanh h2
                    let d_pre2: Vec<f64> = (0..h).map(|j| d_a2[j] * (1.0 - a2[j] * a2[j])).collect();
                    let mut d_z = vec![0.0f64; k];
                    for t in 0..k {
                        for j in 0..h {
                            grads[o[6] + t * h + j] += d_pre2[j] * z[t];
                            d_z[t] += d_pre2[j] * p[o[6] + t * h + j];
                        }
                    }
                    for j in 0..h {
                        grads[o[7] + j] += d_pre2[j];
                    }
                    // KL grads + reparam: dμ = dz + μ ; dlogvar = dz·ε·σ/2 + (σ²−1)/2
                    let mut d_mu = vec![0.0f64; k];
                    let mut d_lv = vec![0.0f64; k];
                    for t in 0..k {
                        d_mu[t] = d_z[t] + mu[t];
                        d_lv[t] = d_z[t] * eps[t] * sigma[t] * 0.5 + 0.5 * (sigma[t] * sigma[t] - 1.0);
                    }
                    // back into encoder head
                    let mut d_a1 = vec![0.0f64; h];
                    for j in 0..h {
                        for t in 0..k {
                            grads[o[2] + j * k + t] += d_mu[t] * a1[j];
                            grads[o[4] + j * k + t] += d_lv[t] * a1[j];
                            d_a1[j] += d_mu[t] * p[o[2] + j * k + t] + d_lv[t] * p[o[4] + j * k + t];
                        }
                    }
                    for t in 0..k {
                        grads[o[3] + t] += d_mu[t];
                        grads[o[5] + t] += d_lv[t];
                    }
                    // through tanh h1 into sparse W1 rows
                    for j in 0..h {
                        let d_pre1 = d_a1[j] * (1.0 - a1[j] * a1[j]);
                        grads[o[1] + j] += d_pre1;
                        for &i in x {
                            grads[o[0] + i * h + j] += d_pre1;
                        }
                    }
                }
                let inv = 1.0 / chunk.len() as f64;
                grads.iter_mut().for_each(|g| *g *= inv);
                adam.step(&mut params.data, &grads);
            }
        }

        // ---- embed: μ(x) ----
        let p = &params.data;
        let mut emb = Matrix::zeros(m, k);
        for (r, x) in xs.iter().enumerate() {
            let mut a1 = vec![0.0f64; h];
            for &i in x {
                let row = &p[o[0] + i * h..o[0] + (i + 1) * h];
                for (a, &w) in a1.iter_mut().zip(row) {
                    *a += w;
                }
            }
            for (j, a) in a1.iter_mut().enumerate() {
                *a = (*a + p[o[1] + j]).tanh();
            }
            for t in 0..k {
                let mut mu = p[o[3] + t];
                for j in 0..h {
                    mu += a1[j] * p[o[2] + j * k + t];
                }
                emb.set(r, t, mu);
            }
        }
        Reduced::Real { embedding: emb }
    }

    fn is_discrete(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tiny_ds() -> CategoricalDataset {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 24;
        spec.dim = 120;
        spec.mean_density = 15.0;
        spec.max_density = 25;
        spec.generate(31)
    }

    #[test]
    fn produces_finite_embedding() {
        let ds = tiny_ds();
        let red = Vae {
            hidden: 16,
            epochs: 3,
            batch: 8,
            lr: 1e-3,
        }
        .reduce(&ds, 4, 1);
        let m = red.to_matrix();
        assert_eq!(m.rows, 24);
        assert_eq!(m.cols, 4);
        assert!(m.data.iter().all(|v| v.is_finite()));
        // embeddings are not all identical
        let first = m.row(0).to_vec();
        assert!((1..m.rows).any(|r| m.row(r) != first.as_slice()));
    }

    #[test]
    fn similar_points_embed_closer_than_dissimilar() {
        // weak sanity: embedding of a point is closer to itself re-encoded
        // (deterministic μ) than to a random other point on average.
        let ds = tiny_ds();
        let red = Vae {
            hidden: 16,
            epochs: 6,
            batch: 8,
            lr: 2e-3,
        }
        .reduce(&ds, 4, 2);
        let m = red.to_matrix();
        // deterministic μ path ⇒ identical rows for identical inputs
        assert!(red.estimate_hamming(0, 0) < 1e-12);
        let _ = m;
    }
}
