//! Hamming-LSH [Gionis–Indyk–Motwani, VLDB'99] as the paper implements it
//! (Section 5, "Reproducibility details"): randomly sample `d` coordinates
//! of the BinEm embedding, compute the Hamming distance restricted to the
//! sample, and scale by `n/d` (then ×2 to undo BinEm's halving).
//!
//! This is the fastest method in Figure 2/Table 3 (it touches only `d`
//! coordinates) but the highest-variance estimator at high sparsity — most
//! sampled coordinates are zero in both vectors, carrying no signal —
//! which is exactly the RMSE behaviour Figure 3 reports.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::index::SortedSample;
use crate::sketch::{BinEm, BitVec, PsiMode};
use crate::util::parallel;
use crate::util::rng::Xoshiro256;

pub struct HammingLsh;

impl DimReducer for HammingLsh {
    fn key(&self) -> &'static str {
        "hlsh"
    }

    fn name(&self) -> &'static str {
        "Hamming-LSH [12]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let n = ds.dim();
        let dim = dim.min(n);
        let binem = BinEm::new(n, ds.num_categories(), PsiMode::PerAttribute, seed);
        let mut rng = Xoshiro256::new(seed ^ 0x1f5a);
        // shared bit-sampling helper (also the LSH index's band primitive)
        let sample = SortedSample::draw(&mut rng, n, dim);
        let mut sketches: Vec<BitVec> = vec![BitVec::zeros(dim); ds.len()];
        let sample_ref = &sample;
        parallel::par_chunks_mut(&mut sketches, parallel::default_threads(), |start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                let p = &ds.points[start + off];
                // walk the sorted nonzeros against the sorted sample
                for &(idx, val) in p.entries() {
                    if let Some(pos) = sample_ref.rank_of(idx as usize) {
                        if binem.psi(idx as usize, val) == 1 {
                            slot.set(pos);
                        }
                    }
                }
            }
        });
        let scale = n as f64 / dim as f64;
        Reduced::Binary {
            sketches,
            estimator: Box::new(move |a, b| 2.0 * scale * a.xor_count(b) as f64),
        }
    }

    fn is_discrete(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn unbiased_but_high_variance() {
        // Average over many seeds ≈ truth (unbiasedness of coordinate
        // sampling), which is all the paper's implementation promises.
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 2;
        spec.dim = 2000;
        spec.mean_density = 150.0;
        spec.max_density = 200;
        let ds = spec.generate(4);
        let truth = ds.points[0].hamming(&ds.points[1]) as f64;
        let mut sum = 0.0;
        let trials = 300;
        for s in 0..trials {
            let red = HammingLsh.reduce(&ds, 200, s);
            sum += red.estimate_hamming(0, 1);
        }
        let mean = sum / trials as f64;
        assert!(
            (mean - truth).abs() < 0.15 * truth,
            "mean {} truth {}",
            mean,
            truth
        );
    }

    #[test]
    fn full_sample_has_only_binem_noise() {
        // dim = n ⇒ the only error is BinEm's (×2 halving noise).
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 2;
        spec.dim = 500;
        spec.mean_density = 60.0;
        spec.max_density = 80;
        let ds = spec.generate(6);
        let truth = ds.points[0].hamming(&ds.points[1]) as f64;
        let mut sum = 0.0;
        let trials = 200;
        for s in 0..trials {
            sum += HammingLsh.reduce(&ds, 500, s).estimate_hamming(0, 1);
        }
        let mean = sum / trials as f64;
        assert!((mean - truth).abs() < 0.1 * truth, "mean {} truth {}", mean, truth);
    }
}
