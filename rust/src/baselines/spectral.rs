//! Spectral baselines: PCA, LSA [11], and MCA [5].
//!
//! * **LSA** — truncated SVD of the raw (label-encoded) data matrix; sketch
//!   = `U_k Σ_k` row scores. Runs on the CSR path so high-dimensional twins
//!   don't densify.
//! * **PCA** — same but column-centered first (centering densifies, which
//!   is the paper's observed OOM driver for PCA at BrainCell scale; we
//!   center implicitly to keep memory honest but the FLOPs equivalent).
//! * **MCA** — correspondence analysis of the one-hot indicator matrix
//!   `Z ∈ {0,1}^{m × n·c}`: row-profile normalisation then truncated SVD.
//!   The `n·c` blow-up is the reason Table 3 reports MCA OOM on the three
//!   big datasets.
//!
//! None of these estimate Hamming distance (the paper's point); they
//! participate in the clustering and timing experiments.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::linalg::sparse::{sparse_randomized_svd, Csr};
use crate::linalg::Matrix;

fn scores_from_svd(svd: crate::linalg::Svd) -> Matrix {
    // embedding = U_k Σ_k
    let mut u = svd.u;
    for c in 0..svd.s.len().min(u.cols) {
        for r in 0..u.rows {
            let v = u.get(r, c) * svd.s[c];
            u.set(r, c, v);
        }
    }
    u
}

pub struct Lsa;

impl DimReducer for Lsa {
    fn key(&self) -> &'static str {
        "lsa"
    }

    fn name(&self) -> &'static str {
        "LSA [11]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let a = Csr::from_dataset(ds);
        let k = dim.min(ds.len().saturating_sub(1)).max(1);
        let svd = sparse_randomized_svd(&a, k, 8, 2, seed);
        Reduced::Real {
            embedding: scores_from_svd(svd),
        }
    }

    fn is_discrete(&self) -> bool {
        false
    }
}

pub struct Pca;

impl DimReducer for Pca {
    fn key(&self) -> &'static str {
        "pca"
    }

    fn name(&self) -> &'static str {
        "PCA"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        // Implicit centering: run the randomized range finder on (A − 1μᵀ)
        // by operating densely on the *projected* side only. For the repro
        // scales (≤ a few thousand points) we densify the sample matrix —
        // faithful to sklearn's PCA which densifies too (its OOM mode).
        let a = Csr::from_dataset(ds).to_dense();
        let mut centered = a;
        let mu = centered.col_means();
        centered.sub_row_vector(&mu);
        let k = dim.min(ds.len().saturating_sub(1)).max(1);
        let svd = crate::linalg::randomized_svd(&centered, k, 8, 2, seed);
        Reduced::Real {
            embedding: scores_from_svd(svd),
        }
    }

    fn is_discrete(&self) -> bool {
        false
    }
}

pub struct Mca;

impl DimReducer for Mca {
    fn key(&self) -> &'static str {
        "mca"
    }

    fn name(&self) -> &'static str {
        "MCA [5]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        // Indicator matrix Z (m × n·c), row-normalised to profiles, then
        // truncated SVD. Column masses are folded in approximately via
        // 1/√(colsum) scaling (full CA weighting without densifying).
        let z = Csr::one_hot_from_dataset(ds);
        let mut colsum = vec![0.0f64; z.cols];
        for r in 0..z.rows {
            let rg = z.row_range(r);
            for (&c, &v) in z.indices[rg.clone()].iter().zip(&z.values[rg]) {
                colsum[c as usize] += v;
            }
        }
        // scale values: v / (rowlen · √colsum)
        let mut scaled = z.clone();
        for r in 0..scaled.rows {
            let rg = scaled.row_range(r);
            let rowlen: f64 = scaled.values[rg.clone()].iter().sum();
            let rg2 = scaled.row_range(r);
            let inv_row = if rowlen > 0.0 { 1.0 / rowlen } else { 0.0 };
            for k in rg2 {
                let c = scaled.indices[k] as usize;
                let cs = colsum[c];
                scaled.values[k] *= inv_row / cs.max(1e-12).sqrt();
            }
        }
        let k = dim.min(ds.len().saturating_sub(1)).max(1);
        let svd = sparse_randomized_svd(&scaled, k, 8, 2, seed);
        Reduced::Real {
            embedding: scores_from_svd(svd),
        }
    }

    fn is_discrete(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{kmeans, metrics::purity};
    use crate::data::synth::SynthSpec;

    fn topic_ds() -> (CategoricalDataset, Vec<usize>) {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 60;
        spec.topics = 3;
        spec.topic_sharpness = 0.95;
        spec.dim = 800;
        spec.generate_labeled(21)
    }

    #[test]
    fn lsa_embedding_clusters_topics() {
        let (ds, labels) = topic_ds();
        let red = Lsa.reduce(&ds, 8, 3);
        let m = red.to_matrix();
        let res = kmeans(&m, 3, 40, 7);
        let p = purity(&labels, &res.assignments);
        assert!(p > 0.7, "purity {p}");
    }

    #[test]
    fn pca_embedding_shape() {
        let (ds, _) = topic_ds();
        let red = Pca.reduce(&ds, 5, 1);
        let m = red.to_matrix();
        assert_eq!(m.rows, 60);
        assert_eq!(m.cols, 5);
        // components carry decreasing variance
        let var = |c: usize| -> f64 {
            let mean: f64 = (0..m.rows).map(|r| m.get(r, c)).sum::<f64>() / m.rows as f64;
            (0..m.rows)
                .map(|r| (m.get(r, c) - mean).powi(2))
                .sum::<f64>()
        };
        assert!(var(0) >= var(4));
    }

    #[test]
    fn mca_runs_on_one_hot() {
        let (ds, labels) = topic_ds();
        let red = Mca.reduce(&ds, 6, 5);
        let m = red.to_matrix();
        assert_eq!(m.rows, 60);
        let res = kmeans(&m, 3, 40, 7);
        let p = purity(&labels, &res.assignments);
        assert!(p > 0.55, "purity {p}");
    }
}
