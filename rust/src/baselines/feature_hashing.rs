//! Feature Hashing [Weinberger et al., ICML 2009] on the BinEm embedding.
//!
//! `FH(u')_j = Σ_{i : h(i)=j} σ(i)·u'_i` with a sign hash σ ∈ {±1}. FH is
//! an unbiased inner-product/ℓ₂ sketch, so the natural Hamming estimator on
//! binary inputs is `ĥ' = ‖FH(u') − FH(v')‖²` (since `‖u'−v'‖² = HD(u',v')`
//! for binary vectors), then ×2 for BinEm. The estimator is unbiased but
//! its variance at small `d` is what Figure 3's FH curves show.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::sketch::{BinEm, PsiMode};
use crate::util::parallel;
use crate::util::rng::mix64;

pub struct FeatureHashing;

impl DimReducer for FeatureHashing {
    fn key(&self) -> &'static str {
        "fh"
    }

    fn name(&self) -> &'static str {
        "Feature Hashing [41]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let binem = BinEm::new(ds.dim(), ds.num_categories(), PsiMode::PerAttribute, seed);
        let hash_seed = seed ^ 0xFEA7;
        let mut sketches: Vec<Vec<f64>> = vec![vec![0.0; dim]; ds.len()];
        parallel::par_chunks_mut(&mut sketches, parallel::default_threads(), |start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                let p = &ds.points[start + off];
                for i in binem.encode_ones(p) {
                    let h = mix64(hash_seed ^ (i as u64));
                    let bucket = (h % dim as u64) as usize;
                    let sign = if (h >> 63) == 1 { 1.0 } else { -1.0 };
                    slot[bucket] += sign;
                }
            }
        });
        Reduced::Discrete {
            sketches,
            estimator: Box::new(|a, b| {
                let l2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                2.0 * l2
            }),
        }
    }

    fn is_discrete(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn unbiased_over_seeds() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 2;
        spec.dim = 1500;
        spec.mean_density = 100.0;
        spec.max_density = 150;
        let ds = spec.generate(9);
        let truth = ds.points[0].hamming(&ds.points[1]) as f64;
        let trials = 300;
        let mut sum = 0.0;
        for s in 0..trials {
            sum += FeatureHashing.reduce(&ds, 256, s).estimate_hamming(0, 1);
        }
        let mean = sum / trials as f64;
        assert!(
            (mean - truth).abs() < 0.12 * truth,
            "mean {} truth {}",
            mean,
            truth
        );
    }

    #[test]
    fn sketch_entries_are_integers() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 5;
        let ds = spec.generate(3);
        if let Reduced::Discrete { sketches, .. } = FeatureHashing.reduce(&ds, 64, 1) {
            for s in &sketches {
                for &v in s {
                    assert_eq!(v, v.round(), "non-integer FH entry {v}");
                }
            }
        } else {
            panic!("FH must be Discrete");
        }
    }

    #[test]
    fn identical_points_zero_distance() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 3;
        let ds = spec.generate(4);
        let red = FeatureHashing.reduce(&ds, 128, 2);
        assert_eq!(red.estimate_hamming(1, 1), 0.0);
    }
}
