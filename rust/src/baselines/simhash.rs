//! SimHash / signed random projection [Charikar, STOC 2002] on the BinEm
//! embedding.
//!
//! Each sketch bit is `sign(⟨r_j, u'⟩)` for a Gaussian direction `r_j`. The
//! sketch Hamming fraction estimates the angle:
//! `θ̂ = π·hs/d`, hence `côs = cos θ̂` and with stored densities `a, b`
//! (one integer per point — the paper's SH sketches also carry norms
//! implicitly) the Hamming estimate is
//! `ĥ' = a + b − 2√(ab)·côs`, then ×2 for BinEm.
//!
//! SimHash preserves *angles*, not distances, so the estimator inherits a
//! √(ab) amplification of angle noise — the Figure 3 behaviour.
//!
//! Implementation note: we draw `r_j` entries lazily per nonzero via a
//! counter-based hash (Box–Muller over mix64 streams) so the projection
//! never materialises the `n×d` Gaussian matrix — same trick the paper's
//! numpy implementation plays with seeded generators, and the reason SH
//! stays feasible at n = 1.3M.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::sketch::{BinEm, BitVec, PsiMode};
use crate::util::parallel;
use crate::util::rng::mix64;

pub struct SimHash;

/// Standard normal from two counter-hashed uniforms.
#[inline]
fn gaussian(seed: u64, i: u64, j: u64) -> f64 {
    let h1 = mix64(seed ^ i.wrapping_mul(0x9E37_79B9) ^ j.wrapping_mul(0x85EB_CA6B));
    let h2 = mix64(h1 ^ 0xC2B2_AE35);
    let u1 = ((h1 >> 11) as f64 + 1.0) / (1u64 << 53) as f64;
    let u2 = (h2 >> 11) as f64 / (1u64 << 53) as f64;
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

impl DimReducer for SimHash {
    fn key(&self) -> &'static str {
        "sh"
    }

    fn name(&self) -> &'static str {
        "SimHash [9]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let binem = BinEm::new(ds.dim(), ds.num_categories(), PsiMode::PerAttribute, seed);
        let gseed = seed ^ 0x51A4;
        let mut results: Vec<(BitVec, f64)> = vec![(BitVec::zeros(dim), 0.0); ds.len()];
        parallel::par_chunks_mut(&mut results, parallel::default_threads(), |start, chunk| {
            let mut acc = vec![0.0f64; dim];
            for (off, slot) in chunk.iter_mut().enumerate() {
                let p = &ds.points[start + off];
                acc.iter_mut().for_each(|x| *x = 0.0);
                let mut density = 0usize;
                for i in binem.encode_ones(p) {
                    density += 1;
                    for (j, a) in acc.iter_mut().enumerate() {
                        *a += gaussian(gseed, i as u64, j as u64);
                    }
                }
                let mut bits = BitVec::zeros(dim);
                for (j, &a) in acc.iter().enumerate() {
                    if a >= 0.0 {
                        bits.set(j);
                    }
                }
                *slot = (bits, density as f64);
            }
        });
        // store density in a side table captured by the estimator
        let densities: Vec<f64> = results.iter().map(|(_, d)| *d).collect();
        let sketches: Vec<BitVec> = results.into_iter().map(|(b, _)| b).collect();
        let sketch_index: std::collections::HashMap<BitVec, Vec<usize>> = {
            let mut m: std::collections::HashMap<BitVec, Vec<usize>> = Default::default();
            for (i, s) in sketches.iter().enumerate() {
                m.entry(s.clone()).or_default().push(i);
            }
            m
        };
        let d = dim as f64;
        // The estimator closure receives sketches by reference; densities
        // are recovered through the index (sketch → point ids). When two
        // points share a sketch we use their mean density — a benign
        // approximation for an already-lossy baseline.
        Reduced::Binary {
            sketches,
            estimator: Box::new(move |sa, sb| {
                let da = lookup_density(&sketch_index, &densities, sa);
                let db = lookup_density(&sketch_index, &densities, sb);
                let theta = std::f64::consts::PI * sa.xor_count(sb) as f64 / d;
                let cos = theta.cos().clamp(-1.0, 1.0);
                let h_prime = da + db - 2.0 * (da * db).sqrt() * cos;
                2.0 * h_prime.max(0.0)
            }),
        }
    }

    fn is_discrete(&self) -> bool {
        true
    }
}

fn lookup_density(
    index: &std::collections::HashMap<BitVec, Vec<usize>>,
    densities: &[f64],
    s: &BitVec,
) -> f64 {
    match index.get(s) {
        Some(ids) if !ids.is_empty() => {
            ids.iter().map(|&i| densities[i]).sum::<f64>() / ids.len() as f64
        }
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn gaussian_hash_moments() {
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for i in 0..n {
            let g = gaussian(42, i, i % 64);
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn identical_points_near_zero() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 4;
        let ds = spec.generate(5);
        let red = SimHash.reduce(&ds, 128, 3);
        // same sketch, same density → θ=0 → ĥ = 2(a+b−2a) = 0
        let e = red.estimate_hamming(2, 2);
        assert!(e.abs() < 1e-9, "self estimate {e}");
    }

    #[test]
    fn orthogonalish_points_large_estimate() {
        // two documents with disjoint vocabularies → angle near 90°
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 2;
        spec.topics = 2;
        spec.topic_sharpness = 1.0;
        spec.dim = 4000;
        spec.mean_density = 80.0;
        spec.max_density = 100;
        let ds = spec.generate(12);
        let truth = ds.points[0].hamming(&ds.points[1]) as f64;
        let mut sum = 0.0;
        let trials = 40;
        for s in 0..trials {
            sum += SimHash.reduce(&ds, 256, s).estimate_hamming(0, 1);
        }
        let mean = sum / trials as f64;
        // crude estimator: within 40% of truth on disjoint supports
        assert!(
            (mean - truth).abs() < 0.4 * truth,
            "mean {mean} truth {truth}"
        );
    }
}
