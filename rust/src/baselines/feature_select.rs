//! Supervised feature selection — χ² [Liu & Setiono 1995] and mutual
//! information [Peng–Long–Ding 2005]. The paper cites these as the
//! *labelled* alternatives its unsupervised method replaces (Section 1,
//! "Unsupervised" bullet); we include them for the ablation comparing
//! supervised selection against Cabin when labels happen to exist.
//!
//! Both score each feature against a label vector and keep the top `d`.

use crate::data::CategoricalDataset;

/// χ² statistic of feature `f` (binarised: present/absent) vs labels.
pub fn chi2_scores(ds: &CategoricalDataset, labels: &[usize]) -> Vec<f64> {
    assert_eq!(labels.len(), ds.len());
    let num_classes = labels.iter().copied().max().map(|m| m + 1).unwrap_or(1);
    let m = ds.len() as f64;
    let mut class_sizes = vec![0usize; num_classes];
    for &l in labels {
        class_sizes[l] += 1;
    }
    // observed present-count per (feature,class)
    let mut present: std::collections::HashMap<u32, Vec<usize>> = Default::default();
    for (i, p) in ds.points.iter().enumerate() {
        for &(f, _) in p.entries() {
            present.entry(f).or_insert_with(|| vec![0; num_classes])[labels[i]] += 1;
        }
    }
    let mut scores = vec![0.0f64; ds.dim()];
    for (&f, counts) in &present {
        let total_present: usize = counts.iter().sum();
        let mut chi = 0.0;
        for c in 0..num_classes {
            let expected_p = total_present as f64 * class_sizes[c] as f64 / m;
            let expected_a = (m - total_present as f64) * class_sizes[c] as f64 / m;
            let obs_p = counts[c] as f64;
            let obs_a = class_sizes[c] as f64 - obs_p;
            if expected_p > 0.0 {
                chi += (obs_p - expected_p).powi(2) / expected_p;
            }
            if expected_a > 0.0 {
                chi += (obs_a - expected_a).powi(2) / expected_a;
            }
        }
        scores[f as usize] = chi;
    }
    scores
}

/// Mutual information of feature presence vs labels (nats).
pub fn mutual_info_scores(ds: &CategoricalDataset, labels: &[usize]) -> Vec<f64> {
    assert_eq!(labels.len(), ds.len());
    let num_classes = labels.iter().copied().max().map(|m| m + 1).unwrap_or(1);
    let m = ds.len() as f64;
    let mut class_sizes = vec![0usize; num_classes];
    for &l in labels {
        class_sizes[l] += 1;
    }
    let mut present: std::collections::HashMap<u32, Vec<usize>> = Default::default();
    for (i, p) in ds.points.iter().enumerate() {
        for &(f, _) in p.entries() {
            present.entry(f).or_insert_with(|| vec![0; num_classes])[labels[i]] += 1;
        }
    }
    let mut scores = vec![0.0f64; ds.dim()];
    for (&f, counts) in &present {
        let total_present: usize = counts.iter().sum();
        let p_x1 = total_present as f64 / m;
        let p_x0 = 1.0 - p_x1;
        let mut mi = 0.0;
        for c in 0..num_classes {
            let p_c = class_sizes[c] as f64 / m;
            let p_1c = counts[c] as f64 / m;
            let p_0c = p_c - p_1c;
            if p_1c > 0.0 && p_x1 > 0.0 {
                mi += p_1c * (p_1c / (p_x1 * p_c)).ln();
            }
            if p_0c > 0.0 && p_x0 > 0.0 {
                mi += p_0c * (p_0c / (p_x0 * p_c)).ln();
            }
        }
        scores[f as usize] = mi.max(0.0);
    }
    scores
}

/// Keep the `d` best-scoring features; returns sorted feature ids.
pub fn select_top(scores: &[f64], d: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    idx.truncate(d);
    idx.sort_unstable();
    idx
}

/// Project a dataset onto selected features (relabelled 0..d).
pub fn project(ds: &CategoricalDataset, selected: &[usize]) -> CategoricalDataset {
    let pos: std::collections::HashMap<u32, u32> = selected
        .iter()
        .enumerate()
        .map(|(new, &old)| (old as u32, new as u32))
        .collect();
    let points = ds
        .points
        .iter()
        .map(|p| {
            let pairs = p
                .entries()
                .iter()
                .filter_map(|&(i, v)| pos.get(&i).map(|&ni| (ni, v)))
                .collect();
            crate::data::CatVector::from_pairs(selected.len(), pairs)
        })
        .collect();
    CategoricalDataset::new(
        &format!("{}-sel{}", ds.name, selected.len()),
        selected.len(),
        ds.num_categories(),
        points,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn discriminative_features_score_high() {
        // Build a dataset where feature 0 is present exactly for class 1.
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 40;
        spec.dim = 100;
        let (mut ds, labels) = spec.generate_labeled(3);
        for (i, p) in ds.points.iter_mut().enumerate() {
            let mut pairs: Vec<(u32, u16)> = p.entries().to_vec();
            pairs.retain(|&(f, _)| f != 0);
            if labels[i] == 1 {
                pairs.push((0, 1));
            }
            *p = crate::data::CatVector::from_pairs(100, pairs);
        }
        let chi = chi2_scores(&ds, &labels);
        let mi = mutual_info_scores(&ds, &labels);
        // feature 0 should be at/near the top in both
        let rank = |scores: &[f64]| {
            let mut better = 0;
            for (f, &s) in scores.iter().enumerate() {
                if f != 0 && s > scores[0] {
                    better += 1;
                }
            }
            better
        };
        assert!(rank(&chi) <= 3, "chi2 rank {}", rank(&chi));
        assert!(rank(&mi) <= 3, "mi rank {}", rank(&mi));
    }

    #[test]
    fn select_and_project() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 10;
        spec.dim = 50;
        let (ds, labels) = spec.generate_labeled(5);
        let scores = chi2_scores(&ds, &labels);
        let sel = select_top(&scores, 8);
        assert_eq!(sel.len(), 8);
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
        let proj = project(&ds, &sel);
        assert_eq!(proj.dim(), 8);
        assert_eq!(proj.len(), 10);
    }

    #[test]
    fn uninformative_labels_give_flat_scores() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 30;
        spec.dim = 60;
        let ds = spec.generate(8);
        let labels = vec![0usize; 30]; // single class: no information
        let mi = mutual_info_scores(&ds, &labels);
        assert!(mi.iter().all(|&s| s.abs() < 1e-9));
    }
}
