//! Non-negative matrix factorisation [Lee & Seung, NIPS 2000] with
//! multiplicative updates minimising ‖A − WH‖²_F.
//!
//! `W ← W ⊙ (A Hᵀ) ⊘ (W H Hᵀ)`, `H ← H ⊙ (Wᵀ A) ⊘ (Wᵀ W H)`.
//!
//! The embedding is `W` (m × k). NNMF is the slowest baseline in Table 3
//! (10⁴× slower than Cabin on PubMed) — each iteration costs two dense
//! m×n×k products; our implementation keeps `A` sparse but the iteration
//! count × density still dominates, faithfully reproducing the gap's shape.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::linalg::sparse::Csr;
use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256;

pub struct Nnmf {
    pub iters: usize,
}

impl Default for Nnmf {
    fn default() -> Self {
        Self { iters: 60 }
    }
}

impl DimReducer for Nnmf {
    fn key(&self) -> &'static str {
        "nnmf"
    }

    fn name(&self) -> &'static str {
        "NNMF [24]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let a = Csr::from_dataset(ds);
        let (m, n) = (a.rows, a.cols);
        let k = dim.min(m.min(n)).max(1);
        let mut rng = Xoshiro256::new(seed ^ 0x27f);
        // |randn| init, scaled to the data magnitude
        let scale = (a.values.iter().sum::<f64>() / (m * n) as f64 / k as f64)
            .sqrt()
            .max(1e-3);
        let mut w = Matrix::randn(m, k, &mut rng);
        let mut h = Matrix::randn(k, n, &mut rng);
        for v in w.data.iter_mut() {
            *v = v.abs() * scale + 1e-6;
        }
        for v in h.data.iter_mut() {
            *v = v.abs() * scale + 1e-6;
        }
        const EPS: f64 = 1e-9;
        for _ in 0..self.iters {
            // H update: H ⊙ (Wᵀ A) ⊘ (Wᵀ W H)
            let wta = a.matmul_t_dense(&w).transpose(); // k × n  (AᵀW)ᵀ
            let wtw = w.transpose().matmul(&w); // k × k
            let wtwh = wtw.matmul(&h); // k × n
            for i in 0..h.data.len() {
                h.data[i] *= wta.data[i] / (wtwh.data[i] + EPS);
            }
            // W update: W ⊙ (A Hᵀ) ⊘ (W H Hᵀ)
            let aht = a.matmul_dense(&h.transpose()); // m × k
            let hht = h.matmul(&h.transpose()); // k × k
            let whht = w.matmul(&hht); // m × k
            for i in 0..w.data.len() {
                w.data[i] *= aht.data[i] / (whht.data[i] + EPS);
            }
        }
        Reduced::Real { embedding: w }
    }

    fn is_discrete(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn small_ds() -> CategoricalDataset {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 30;
        spec.dim = 200;
        spec.mean_density = 20.0;
        spec.max_density = 30;
        spec.generate(13)
    }

    #[test]
    fn factors_are_nonnegative() {
        let ds = small_ds();
        let red = Nnmf { iters: 20 }.reduce(&ds, 6, 2);
        let m = red.to_matrix();
        assert!(m.data.iter().all(|&v| v >= 0.0));
        assert_eq!(m.rows, 30);
        assert_eq!(m.cols, 6);
    }

    #[test]
    fn objective_decreases() {
        let ds = small_ds();
        let a = Csr::from_dataset(&ds).to_dense();
        let err = |iters: usize| -> f64 {
            // reconstruct with the H refit implicitly by running the whole
            // factorisation; monotonicity of MU guarantees less error for
            // more iterations given identical init (same seed).
            let red = Nnmf { iters }.reduce(&ds, 6, 4);
            let w = red.to_matrix();
            // refit H by one least-squares-ish MU pass is overkill; instead
            // compare via projection residual ‖A‖² − ‖Wᵀ A‖²/‖W‖² proxy.
            // Simpler: measure clustering-free reconstruction via
            // col-space proxy: sum of squared row norms of A − W (W⁺A).
            // For the test, use the fact that MU monotonically decreases
            // ‖A − WH‖; we re-derive H for this W with 5 MU steps.
            let mut rng = Xoshiro256::new(99);
            let mut h = Matrix::randn(6, a.cols, &mut rng);
            for v in h.data.iter_mut() {
                *v = v.abs() * 0.1 + 1e-6;
            }
            for _ in 0..30 {
                let wta = w.transpose().matmul(&a);
                let wtwh = w.transpose().matmul(&w).matmul(&h);
                for i in 0..h.data.len() {
                    h.data[i] *= wta.data[i] / (wtwh.data[i] + 1e-9);
                }
            }
            let recon = w.matmul(&h);
            let mut e = 0.0;
            for i in 0..a.data.len() {
                e += (a.data[i] - recon.data[i]).powi(2);
            }
            e
        };
        let e5 = err(5);
        let e50 = err(50);
        assert!(e50 <= e5 * 1.05, "e5 {e5} e50 {e50}");
    }
}
