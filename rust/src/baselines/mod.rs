//! The paper's eleven baseline dimensionality-reduction methods (Table 2),
//! implemented from scratch, plus Cabin itself wrapped in the same
//! interface so every analysis harness (RMSE, clustering, heatmaps, timing)
//! treats all methods uniformly.
//!
//! | Key       | Method                                   | Output   |
//! |-----------|------------------------------------------|----------|
//! | `cabin`   | Cabin (ours)                             | binary   |
//! | `bcs`     | BinEm + Binary Compression Scheme [34]   | binary   |
//! | `hlsh`    | BinEm + Hamming-LSH (coordinate sample)  | binary   |
//! | `fh`      | BinEm + Feature Hashing [41]             | discrete |
//! | `sh`      | SimHash / signed random projection [9]   | binary   |
//! | `kt`      | Kendall-tau feature selection [19]       | discrete |
//! | `pca`     | PCA (centered randomized SVD)            | real     |
//! | `lsa`     | LSA (randomized SVD, no centering) [11]  | real     |
//! | `mca`     | MCA (CA of the one-hot indicator) [5]    | real     |
//! | `nnmf`    | NMF, multiplicative updates [24]         | real     |
//! | `lda`     | LDA, collapsed Gibbs [6]                 | real     |
//! | `vae`     | VAE, manual-backprop MLP [21]            | real     |
//!
//! Supervised feature selection (χ², mutual information — mentioned in the
//! paper as the labelled alternative) lives in [`feature_select`].
//!
//! Estimating Hamming distances from sketches: the discrete methods define
//! a per-method estimator (documented in each module — the paper measures
//! them through the same RMSE lens even when Hamming is not what they
//! preserve, which is exactly the point of Figure 3). Real-valued methods
//! participate only in clustering/timing, as in the paper.

pub mod bcs;
pub mod cabin_reducer;
pub mod feature_hashing;
pub mod feature_select;
pub mod hamming_lsh;
pub mod kendall;
pub mod lda;
pub mod nnmf;
pub mod simhash;
pub mod spectral;
pub mod vae;

use crate::data::CategoricalDataset;
use crate::linalg::Matrix;
use crate::sketch::BitVec;

/// What a reducer produces.
pub enum Reduced {
    /// Binary sketches + a Hamming estimator context.
    Binary {
        sketches: Vec<BitVec>,
        estimator: Box<dyn Fn(&BitVec, &BitVec) -> f64 + Send + Sync>,
    },
    /// Integer-valued sketches (feature hashing, Kendall selection).
    Discrete {
        sketches: Vec<Vec<f64>>,
        estimator: Box<dyn Fn(&[f64], &[f64]) -> f64 + Send + Sync>,
    },
    /// Real-valued embeddings (spectral / factorisation / neural).
    Real { embedding: Matrix },
}

impl Reduced {
    /// Estimated original Hamming distance between points `i` and `j`.
    /// Real-valued methods estimate via squared Euclidean distance (the
    /// natural reading of "Hamming distance defined on the sketch").
    pub fn estimate_hamming(&self, i: usize, j: usize) -> f64 {
        match self {
            Reduced::Binary { sketches, estimator } => estimator(&sketches[i], &sketches[j]),
            Reduced::Discrete { sketches, estimator } => estimator(&sketches[i], &sketches[j]),
            Reduced::Real { embedding } => {
                let (a, b) = (embedding.row(i), embedding.row(j));
                a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Reduced::Binary { sketches, .. } => sketches.len(),
            Reduced::Discrete { sketches, .. } => sketches.len(),
            Reduced::Real { embedding } => embedding.rows,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Binary sketches if this method produces them (k-mode clustering path).
    pub fn as_bits(&self) -> Option<&[BitVec]> {
        match self {
            Reduced::Binary { sketches, .. } => Some(sketches),
            _ => None,
        }
    }

    /// Dense matrix view for k-means (real + discrete methods).
    pub fn to_matrix(&self) -> Matrix {
        match self {
            Reduced::Real { embedding } => embedding.clone(),
            Reduced::Discrete { sketches, .. } => {
                Matrix::from_rows(sketches.clone())
            }
            Reduced::Binary { sketches, .. } => {
                let rows = sketches
                    .iter()
                    .map(|s| s.to_f32s().iter().map(|&x| x as f64).collect())
                    .collect();
                Matrix::from_rows(rows)
            }
        }
    }

    /// Sketch memory footprint (paper Section 1's space argument).
    pub fn memory_bytes(&self) -> usize {
        match self {
            Reduced::Binary { sketches, .. } => sketches.iter().map(|s| s.memory_bytes()).sum(),
            Reduced::Discrete { sketches, .. } => sketches.iter().map(|s| s.len() * 8).sum(),
            Reduced::Real { embedding } => embedding.data.len() * 8,
        }
    }
}

/// A dimensionality-reduction method under test.
pub trait DimReducer: Send + Sync {
    /// Short key (`cabin`, `bcs`, …).
    fn key(&self) -> &'static str;
    /// Display name for tables.
    fn name(&self) -> &'static str;
    /// Reduce the dataset to `dim` dimensions.
    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced;
    /// Whether the output is discrete (participates in RMSE experiments).
    fn is_discrete(&self) -> bool;
}

/// Construct a reducer by key. `None` for unknown keys.
pub fn by_key(key: &str) -> Option<Box<dyn DimReducer>> {
    Some(match key {
        "cabin" => Box::new(cabin_reducer::CabinReducer::default()),
        "cabin-lit" => Box::new(cabin_reducer::CabinReducer::literal()),
        "bcs" => Box::new(bcs::Bcs),
        "hlsh" => Box::new(hamming_lsh::HammingLsh),
        "fh" => Box::new(feature_hashing::FeatureHashing),
        "sh" => Box::new(simhash::SimHash),
        "kt" => Box::new(kendall::KendallTau::default()),
        "pca" => Box::new(spectral::Pca),
        "lsa" => Box::new(spectral::Lsa),
        "mca" => Box::new(spectral::Mca),
        "nnmf" => Box::new(nnmf::Nnmf::default()),
        "lda" => Box::new(lda::Lda::default()),
        "vae" => Box::new(vae::Vae::default()),
        _ => return None,
    })
}

/// The discrete-output methods compared in the RMSE experiment (Figure 3).
pub const DISCRETE_KEYS: [&str; 6] = ["cabin", "bcs", "hlsh", "fh", "sh", "kt"];

/// All method keys in Table 3 column order (Cabin first for convenience).
pub const ALL_KEYS: [&str; 12] = [
    "cabin", "nnmf", "mca", "vae", "lda", "lsa", "pca", "fh", "sh", "kt", "bcs", "hlsh",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn registry_constructs_all() {
        for k in ALL_KEYS {
            let r = by_key(k).unwrap_or_else(|| panic!("missing reducer {k}"));
            assert_eq!(r.key(), k);
        }
        assert!(by_key("nope").is_none());
    }

    #[test]
    fn every_reducer_produces_right_count() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 24;
        spec.dim = 300;
        spec.max_density = 40;
        spec.mean_density = 25.0;
        let ds = spec.generate(8);
        for k in ALL_KEYS {
            let r = by_key(k).unwrap();
            let red = r.reduce(&ds, 32, 5);
            assert_eq!(red.len(), 24, "method {k}");
            // estimator callable and finite
            let e = red.estimate_hamming(0, 1);
            assert!(e.is_finite(), "method {k} est {e}");
            let m = red.to_matrix();
            assert_eq!(m.rows, 24, "method {k}");
        }
    }

    #[test]
    fn discrete_flags_match_figure3_set() {
        for k in DISCRETE_KEYS {
            assert!(by_key(k).unwrap().is_discrete(), "{k} should be discrete");
        }
        for k in ["pca", "lsa", "mca", "nnmf", "lda", "vae"] {
            assert!(!by_key(k).unwrap().is_discrete(), "{k} should be real");
        }
    }
}
