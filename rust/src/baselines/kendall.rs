//! Kendall-tau (KT) feature selection [Kendall 1938], the paper's
//! `pandas.DataFrame.corr(method="kendall")` baseline.
//!
//! Interpretation (the paper gives only the library call): compute Kendall
//! rank correlations between features over the points, score each feature
//! by its aggregate |τ| against other features, and keep the `d` most
//! correlated features; the sketch is the raw values of the selected
//! features and distances are scaled by `n/d`.
//!
//! The full τ matrix is Θ(n²·m) — this is precisely why the paper reports
//! KT as OOM on NYTimes/PubMed/BrainCell and DNS (>20h) on Enron. We keep
//! the cost model honest (pairwise over features) but bound the score
//! computation with a probe set of features and a point subsample so the
//! small datasets finish; the repro harness's budget mechanism reports
//! DNS/OOM for the big ones just like Table 3.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::util::rng::Xoshiro256;

pub struct KendallTau {
    /// Features scored against this many probe features.
    pub probes: usize,
    /// Point subsample used for τ computation.
    pub point_sample: usize,
}

impl Default for KendallTau {
    fn default() -> Self {
        Self {
            probes: 24,
            point_sample: 200,
        }
    }
}

/// Kendall τ-a between two equal-length value slices.
fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    let m = a.len();
    if m < 2 {
        return 0.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..m {
        for j in (i + 1)..m {
            let s = (a[i] - a[j]) * (b[i] - b[j]);
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let pairs = (m * (m - 1) / 2) as f64;
    (concordant - discordant) as f64 / pairs
}

impl DimReducer for KendallTau {
    fn key(&self) -> &'static str {
        "kt"
    }

    fn name(&self) -> &'static str {
        "Kendall-tau [19]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let n = ds.dim();
        let dim = dim.min(n);
        let mut rng = Xoshiro256::new(seed ^ 0x4B7);
        let pts: Vec<usize> = rng.sample_indices(ds.len(), self.point_sample.min(ds.len()));

        // Column extraction for the sampled points (dense over sample).
        let col = |feature: usize| -> Vec<f64> {
            pts.iter()
                .map(|&p| ds.points[p].get(feature) as f64)
                .collect()
        };

        // Candidate features = those with any support in the sample
        // (scoring all n features à la pandas is the DNS path; candidates
        // without support have τ = 0 against everything anyway).
        let mut support: Vec<usize> = {
            let mut seen = std::collections::BTreeSet::new();
            for &p in &pts {
                for &(i, _) in ds.points[p].entries() {
                    seen.insert(i as usize);
                }
            }
            seen.into_iter().collect()
        };
        if support.len() < dim {
            // pad with arbitrary features to reach d
            for f in 0..n {
                if support.len() >= dim {
                    break;
                }
                if !support.contains(&f) {
                    support.push(f);
                }
            }
        }

        let probes: Vec<Vec<f64>> = (0..self.probes.min(support.len()))
            .map(|_| col(support[rng.usize_in(0, support.len())]))
            .collect();

        let mut scored: Vec<(f64, usize)> = support
            .iter()
            .map(|&f| {
                let cf = col(f);
                let score: f64 = probes.iter().map(|p| kendall_tau(&cf, p).abs()).sum();
                (score, f)
            })
            .collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut selected: Vec<usize> = scored.into_iter().take(dim).map(|(_, f)| f).collect();
        selected.sort_unstable();

        let sketches: Vec<Vec<f64>> = ds
            .points
            .iter()
            .map(|p| selected.iter().map(|&f| p.get(f) as f64).collect())
            .collect();
        let scale = n as f64 / dim as f64;
        Reduced::Discrete {
            sketches,
            estimator: Box::new(move |a, b| {
                let hd = a.iter().zip(b).filter(|(x, y)| x != y).count() as f64;
                scale * hd
            }),
        }
    }

    fn is_discrete(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn tau_known_values() {
        assert!((kendall_tau(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((kendall_tau(&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        // independent-ish
        let t = kendall_tau(&[1.0, 2.0, 3.0, 4.0], &[2.0, 1.0, 4.0, 3.0]);
        assert!(t.abs() < 0.5);
    }

    #[test]
    fn selects_d_features_and_estimates() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 30;
        spec.dim = 400;
        let ds = spec.generate(6);
        let red = KendallTau::default().reduce(&ds, 50, 3);
        assert_eq!(red.len(), 30);
        if let Reduced::Discrete { sketches, .. } = &red {
            assert!(sketches.iter().all(|s| s.len() == 50));
        } else {
            panic!("KT must be Discrete");
        }
        assert!(red.estimate_hamming(0, 1).is_finite());
        assert_eq!(red.estimate_hamming(2, 2), 0.0);
    }

    #[test]
    fn tau_is_symmetric() {
        let a = [1.0, 3.0, 2.0, 5.0, 4.0];
        let b = [2.0, 1.0, 4.0, 3.0, 5.0];
        assert!((kendall_tau(&a, &b) - kendall_tau(&b, &a)).abs() < 1e-12);
    }
}
