//! Binary Compression Scheme (BCS) [Pratap–Kulkarni–Sohony, IEEE BigData
//! 2018], applied on a BinEm embedding (per the Table 2 footnote: "BCS and
//! H-LSH are applied on a BinEm embedding").
//!
//! BCS randomly partitions the `n` coordinates into `d` buckets and each
//! sketch bit is the **parity** (sum mod 2) of its bucket. A coordinate
//! where `u'` and `v'` differ flips the corresponding sketch-bit parity, so
//! a sketch bit differs iff an *odd* number of differing coordinates landed
//! in its bucket:
//!
//! `P[bit differs] = (1 − (1 − 2/d)^h) / 2`, `h = HD(u',v')`,
//!
//! inverted to `ĥ' = ln(1 − 2·hs/d) / ln(1 − 2/d)` (`hs` = sketch Hamming
//! distance), and `ĥ = 2·ĥ'` undoes BinEm's halving. Saturation (`hs ≥ d/2`)
//! clamps — exactly the regime where Figure 3 shows BCS's RMSE blowing up
//! at small `d`.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::sketch::mappings::derive_pi;
use crate::sketch::{BinEm, BitVec, PsiMode};
use crate::util::parallel;

pub struct Bcs;

impl DimReducer for Bcs {
    fn key(&self) -> &'static str {
        "bcs"
    }

    fn name(&self) -> &'static str {
        "BCS [34]"
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let binem = BinEm::new(ds.dim(), ds.num_categories(), PsiMode::PerAttribute, seed);
        let pi = derive_pi(seed.wrapping_add(0xBC5), ds.dim(), dim);
        let mut sketches: Vec<BitVec> = vec![BitVec::zeros(dim); ds.len()];
        parallel::par_chunks_mut(&mut sketches, parallel::default_threads(), |start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                let p = &ds.points[start + off];
                // parity accumulate
                for i in binem.encode_ones(p) {
                    let b = pi[i] as usize;
                    if slot.get(b) {
                        slot.clear(b);
                    } else {
                        slot.set(b);
                    }
                }
            }
        });
        let d = dim as f64;
        Reduced::Binary {
            sketches,
            estimator: Box::new(move |a, b| {
                let hs = a.xor_count(b) as f64;
                let ratio = (1.0 - 2.0 * hs / d).max(1.0 / d); // clamp at saturation
                let h_prime = ratio.ln() / (1.0 - 2.0 / d).ln();
                2.0 * h_prime
            }),
        }
    }

    fn is_discrete(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn parity_sketch_is_deterministic() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 8;
        let ds = spec.generate(2);
        let a = Bcs.reduce(&ds, 64, 3);
        let b = Bcs.reduce(&ds, 64, 3);
        assert!((a.estimate_hamming(0, 1) - b.estimate_hamming(0, 1)).abs() < 1e-12);
    }

    #[test]
    fn estimates_reasonable_at_large_dim() {
        // With d ≫ h, few parity collisions: estimate ≈ truth.
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 16;
        spec.mean_density = 40.0;
        spec.max_density = 60;
        let ds = spec.generate(5);
        let red = Bcs.reduce(&ds, 4096, 9);
        let mut rel = 0.0;
        let mut cnt = 0;
        for i in 0..8 {
            for j in (i + 1)..8 {
                let t = ds.points[i].hamming(&ds.points[j]) as f64;
                if t < 10.0 {
                    continue;
                }
                rel += (red.estimate_hamming(i, j) - t).abs() / t;
                cnt += 1;
            }
        }
        assert!(rel / (cnt as f64) < 0.5, "rel {}", rel / cnt as f64);
    }

    #[test]
    fn saturation_is_finite() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 4;
        let ds = spec.generate(7);
        let red = Bcs.reduce(&ds, 8, 1); // tiny d → saturation likely
        for i in 0..4 {
            for j in 0..4 {
                assert!(red.estimate_hamming(i, j).is_finite());
            }
        }
    }
}
