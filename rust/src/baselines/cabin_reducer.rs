//! Cabin wrapped in the [`DimReducer`] interface so the paper-table
//! harnesses compare it uniformly against the baselines.

use super::{DimReducer, Reduced};
use crate::data::CategoricalDataset;
use crate::sketch::{cham, CabinSketcher, Estimator, SketchConfig};
use crate::util::parallel;

/// Cabin as a baseline-harness method.
pub struct CabinReducer {
    pub estimator: Estimator,
}

impl Default for CabinReducer {
    fn default() -> Self {
        Self {
            estimator: Estimator::OccupancyInversion,
        }
    }
}

impl CabinReducer {
    /// Variant using the Algorithm-2 formula exactly as printed (ablation A1).
    pub fn literal() -> Self {
        Self {
            estimator: Estimator::PaperLiteral,
        }
    }
}

impl DimReducer for CabinReducer {
    fn key(&self) -> &'static str {
        match self.estimator {
            Estimator::OccupancyInversion => "cabin",
            Estimator::PaperLiteral => "cabin-lit",
        }
    }

    fn name(&self) -> &'static str {
        match self.estimator {
            Estimator::OccupancyInversion => "Cabin (ours)",
            Estimator::PaperLiteral => "Cabin (literal Alg.2)",
        }
    }

    fn reduce(&self, ds: &CategoricalDataset, dim: usize, seed: u64) -> Reduced {
        let cfg = SketchConfig::new(ds.dim(), ds.num_categories(), dim, seed)
            .with_estimator(self.estimator);
        let sk = CabinSketcher::from_config(cfg);
        let sketches = sk.sketch_dataset(ds, parallel::default_threads());
        let cfg_copy = *sk.config();
        Reduced::Binary {
            sketches,
            estimator: Box::new(move |a, b| cham::estimate_hamming(a, b, &cfg_copy)),
        }
    }

    fn is_discrete(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn estimates_track_truth() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 30;
        let ds = spec.generate(3);
        let red = CabinReducer::default().reduce(&ds, 512, 7);
        let mut total_rel = 0.0;
        let mut cnt = 0;
        for i in 0..10 {
            for j in (i + 1)..10 {
                let t = ds.points[i].hamming(&ds.points[j]) as f64;
                if t == 0.0 {
                    continue;
                }
                total_rel += (red.estimate_hamming(i, j) - t).abs() / t;
                cnt += 1;
            }
        }
        let mean_rel = total_rel / cnt as f64;
        assert!(mean_rel < 0.30, "mean rel err {}", mean_rel);
    }
}
