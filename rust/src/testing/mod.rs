//! Property-testing substrate (proptest is unavailable offline).
//!
//! [`PropRunner`] drives a property over many seeded random cases and, on
//! failure, retries with "shrunk" size parameters to report the smallest
//! failing scale it can find. Generators are plain closures over
//! [`crate::util::rng::Xoshiro256`], so properties stay readable:
//!
//! ```no_run
//! use cabin::testing::PropRunner;
//! PropRunner::new("addition commutes", 64).run(|rng, _size| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use crate::util::rng::Xoshiro256;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// A unique scratch directory under the system temp dir, removed on drop
/// (`tempfile` is unavailable offline). Uniqueness comes from the process
/// id plus a process-wide counter, so concurrent tests and concurrent test
/// processes never collide.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(label: &str) -> TempDir {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "cabin-{label}-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

pub struct PropRunner {
    pub name: String,
    pub cases: usize,
    pub base_seed: u64,
    /// Max "size" hint passed to the property; shrinking lowers this.
    pub max_size: usize,
}

impl PropRunner {
    pub fn new(name: &str, cases: usize) -> Self {
        Self {
            name: name.to_string(),
            cases,
            base_seed: 0xCAB1_0000,
            max_size: 256,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    pub fn with_max_size(mut self, s: usize) -> Self {
        self.max_size = s;
        self
    }

    /// Run the property. `prop(rng, size)` returns `Err(msg)` to fail the
    /// case. Panics with a reproduction line on failure.
    pub fn run<F>(&self, prop: F)
    where
        F: Fn(&mut Xoshiro256, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            // sizes sweep small → large so early failures are small already
            let size = 1 + (self.max_size * (case + 1)) / self.cases;
            let mut rng = Xoshiro256::new(seed);
            if let Err(msg) = prop(&mut rng, size) {
                // shrink: halve size until it passes, report last failure
                let mut fail_size = size;
                let mut fail_msg = msg;
                let mut s = size / 2;
                while s >= 1 {
                    let mut rng = Xoshiro256::new(seed);
                    match prop(&mut rng, s) {
                        Err(m) => {
                            fail_size = s;
                            fail_msg = m;
                            s /= 2;
                        }
                        Ok(()) => break,
                    }
                }
                panic!(
                    "property '{}' failed: case={} seed={:#x} size={} — {}",
                    self.name, case, seed, fail_size, fail_msg
                );
            }
        }
    }
}

/// Assert two f64 are within `atol + rtol*|expected|`.
pub fn assert_close(actual: f64, expected: f64, atol: f64, rtol: f64, ctx: &str) {
    let tol = atol + rtol * expected.abs();
    assert!(
        (actual - expected).abs() <= tol,
        "{}: |{} - {}| > {}",
        ctx,
        actual,
        expected,
        tol
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        PropRunner::new("trivial", 32).run(|rng, size| {
            let v = rng.gen_range(size as u64 + 1);
            if (v as usize) <= size {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        PropRunner::new("always fails", 4).run(|_, _| Err("nope".into()));
    }

    #[test]
    fn temp_dirs_are_unique_and_cleaned_up() {
        let a = TempDir::new("t");
        let b = TempDir::new("t");
        assert_ne!(a.path(), b.path());
        std::fs::write(a.path().join("f"), b"x").unwrap();
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists());
        assert!(b.path().exists());
    }

    #[test]
    fn close_assertion() {
        assert_close(1.0001, 1.0, 0.0, 1e-3, "ok");
    }

    #[test]
    #[should_panic]
    fn close_assertion_fails() {
        assert_close(2.0, 1.0, 0.1, 0.1, "must fail");
    }
}
