// The AVX-512 kernel arm uses intrinsics that are unstable at the crate
// MSRV; the default-off `avx512` cargo feature opts into them (and
// therefore into a nightly toolchain). Everything else builds on stable.
#![cfg_attr(feature = "avx512", feature(stdarch_x86_avx512))]

//! # cabin — Efficient Binary Embedding of Categorical Data using BinSketch
//!
//! A full reproduction of Verma, Pratap & Bera, *"Efficient Binary Embedding
//! of Categorical Data using BinSketch"* (2021): the **Cabin** sketching
//! algorithm (categorical → low-dimensional binary) and the **Cham**
//! Hamming-distance estimator, together with every substrate the paper's
//! evaluation depends on — eleven baseline dimensionality-reduction methods,
//! k-mode/k-means clustering with purity/NMI/ARI scoring, RMSE/heatmap/MAE
//! analysis harnesses, synthetic statistical twins of the paper's six
//! datasets, and a streaming sketch *service* over a **mutable corpus** —
//! dynamic write batching (insert, delete, upsert and per-row TTL are
//! first-class operations at every layer), point-balanced sharding over
//! contiguous bit-packed sketch arenas ([`sketch::SketchMatrix`], with
//! swap-remove deletes mirrored into the LSH index under the same shard
//! lock) with an O(1) id → (shard, row) index, and
//! single or batched top-k routing executed on a persistent shard-executor
//! runtime ([`coordinator::executor`]: one long-lived worker thread per
//! shard behind bounded work queues — no per-request thread spawning) with
//! batch-major blocked scoring (L1-tiled multi-query popcount kernels
//! runtime-dispatched to the widest ISA the CPU supports —
//! AVX2/AVX-512-VPOPCNTDQ/NEON with a property-tested scalar oracle as
//! fallback, [`sketch::kernels`] — feeding a bounded heap,
//! [`coordinator::TopK`]) or,
//! sublinearly, per-shard banded multi-probe Hamming-LSH candidate
//! generation ([`index::LshIndex`]) with exact Cham reranking through the
//! same gathered kernel and guaranteed full-scan fallback — whose compute
//! hot path can run either natively (bit-packed popcount over borrowed
//! `&[u64]` arena rows) or through AOT-compiled JAX/Pallas artifacts via
//! PJRT, and whose corpus can be made crash-durable ([`persist`]:
//! per-shard checksummed WALs logging every *mutation* — insert, delete,
//! upsert, TTL expiry, rebalance move — with group-committed fsyncs (one
//! per commit window per touched shard, acks released when their window
//! lands, commit failures surfaced to the client as write errors), plus
//! snapshot generations, dead-frame-triggered WAL compaction folded into
//! rotation, and full-fingerprint-checked warm recovery, so a restart
//! never re-sketches the corpus and never loads one persisted under a
//! different corpus shape), and whose reads scale out through
//! log-shipping replication ([`replica`]: every WAL frame carries a
//! monotonic per-shard sequence anchored by the manifest, a primary
//! ships snapshot arenas + checksummed frame ranges over the same wire
//! protocol, and a follower bootstraps through the ordinary recovery
//! path, applies the tail of mutations continuously into its own store +
//! WAL — deletes and upserts mirrored bit-identically, cross-shard moves
//! applied destination-before-source — serves bit-identical reads while
//! rejecting writes with a redirect, and can be promoted writable when
//! the primary dies, losing nothing the primary had acked and shipped;
//! promotion also adopts the primary-side TTL-sweep duty).
//!
//! ## Observability
//!
//! The serving runtime is instrumented end to end by [`obs`]: latency
//! is recorded into lock-free log-linear atomic-bucket histograms
//! ([`obs::ObsHistogram`] — fixed memory, mergeable, exact bucket
//! counts, p50/p95/p99/p999), one per pipeline stage
//! ([`obs::Stages`]: write path batcher-queue → sketch → placement →
//! WAL → fsync-wait → reply; read path executor-queue → scan → rerank
//! → gather), surfaced as `stage_*` fields in `stats` and as native
//! histogram families in the Prometheus text exposition
//! ([`obs::prom`], wire op `metrics_text`, CLI `stats --prom`) served
//! by primaries and followers alike. A per-connection trace id flows
//! through batcher tickets and executor jobs so requests breaching
//! `--slow-op-ms` emit one structured slow-op record with the full
//! per-stage breakdown via the leveled text/JSONL event logger
//! ([`obs::log`], `--log-level`/`--log-json`). The former
//! `Mutex<Vec<f64>>` sampler ([`util::timer::LatencyStats`]) survives
//! only in offline bench summaries, reservoir-capped.
//!
//! ## Architecture (three layers)
//!
//! * **L3** (this crate): coordinator + native library. See [`coordinator`],
//!   [`runtime`], [`sketch`].
//! * **L2** `python/compile/model.py`: JAX graph (BinEm lookup + kernel
//!   calls), AOT-lowered to HLO text at build time.
//! * **L1** `python/compile/kernels/`: Pallas kernels — blocked
//!   sketch-matmul and the fused all-pairs gram+estimator.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the cargo rpath to
//! # // libxla_extension; the same snippet runs in examples/quickstart.rs.
//! use cabin::sketch::{CabinSketcher, cham};
//! use cabin::data::CategoricalDataset;
//!
//! // 10_000-dim categorical vectors with ≤ 64 categories, density ≈ 100.
//! let ds = cabin::data::synth::SynthSpec::small_demo().generate(42);
//! let sk = CabinSketcher::new(ds.dim(), ds.num_categories(), 256, 7);
//! let a = sk.sketch(&ds.points[0]);
//! let b = sk.sketch(&ds.points[1]);
//! let est = cham::estimate_hamming(&a, &b, sk.config());
//! let truth = ds.points[0].hamming(&ds.points[1]) as f64;
//! assert!((est - truth).abs() <= 0.35 * truth + 32.0);
//! ```

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod index;
pub mod linalg;
pub mod obs;
pub mod persist;
pub mod replica;
pub mod repro;
pub mod runtime;
pub mod sketch;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
