//! Scoped data-parallel helpers over `std::thread` (no `rayon` offline).
//!
//! The analysis harnesses (all-pairs matrices, RMSE sweeps) and the blocked
//! matmul use [`par_chunks_mut`] / [`par_ranges`]; the coordinator uses its
//! own long-lived worker threads (see `coordinator::shard`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Run `f(range)` over `[0, n)` split into `threads` contiguous ranges.
pub fn par_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo..hi));
        }
    });
}

/// Dynamic work-stealing-ish loop: workers atomically grab indices. Use for
/// uneven per-item costs (e.g. per-baseline timing where some items DNS).
pub fn par_dynamic<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Split a mutable slice into `threads` contiguous chunks processed in
/// parallel; `f(chunk_start_index, chunk)`.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            let begin = start;
            s.spawn(move || f(begin, head));
            rest = tail;
            start += take;
        }
    });
}

/// Parallel map collecting results in order.
pub fn par_map<T: Send, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
    T: Default + Clone,
{
    let mut out = vec![T::default(); n];
    par_chunks_mut(&mut out, threads, |start, chunk| {
        for (off, slot) in chunk.iter_mut().enumerate() {
            *slot = f(start + off);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_cover_everything_once() {
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        par_ranges(1000, 7, |r| {
            for i in r {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn dynamic_covers_everything_once() {
        let sum = AtomicU64::new(0);
        par_dynamic(501, 5, |i| {
            sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 501 * 502 / 2);
    }

    #[test]
    fn chunks_mut_writes_disjoint() {
        let mut v = vec![0usize; 100];
        par_chunks_mut(&mut v, 3, |start, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = start + off;
            }
        });
        assert_eq!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn map_in_order() {
        let v = par_map(50, 4, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v.len(), 50);
    }

    #[test]
    fn degenerate_sizes() {
        par_ranges(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = par_map(1, 8, |i| i);
        assert_eq!(v, vec![0]);
    }
}
