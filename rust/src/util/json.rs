//! Minimal JSON (RFC 8259 subset) — the coordinator's wire format.
//!
//! `serde_json` is unavailable offline; this module provides a small value
//! model ([`Json`]), a recursive-descent parser and a serializer. It handles
//! everything the protocol needs: objects, arrays, strings with escapes,
//! f64 numbers, booleans, null. Not supported (unneeded): `\u` surrogate
//! pairs beyond the BMP are passed through as replacement chars.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — handy for tests and reproducible logs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors (protocol decoding).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    /// Convenience: array of u16 (categorical feature values).
    pub fn req_u16_vec(&self, key: &str) -> anyhow::Result<Vec<u16>> {
        Ok(self
            .req_arr(key)?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as u16)
            .collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{}", b),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{}", n)
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}", v)?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{}", v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{}", c)?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document. Returns an error with byte position on failure.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected '{}' at byte {}", c as char, self.i),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{}' at byte {}: {}", s, start, e)
        })?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| anyhow::anyhow!("invalid utf8 at byte {}", self.i))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5"] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"op":"insert","vec":[1,0,3],"meta":{"tag":"a b","ok":true},"x":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_str("op").unwrap(), "insert");
        assert_eq!(v.req_u16_vec("vec").unwrap(), vec![1, 0, 3]);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\"b\"\tA""#).unwrap();
        assert_eq!(v, Json::Str("a\n\"b\"\tA".into()));
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn integer_display_is_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn required_field_errors() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert!(v.req_str("a").is_err());
        assert!(v.req_usize("a").is_ok());
        assert!(v.req_usize("missing").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v, Json::Str("héllo ✓".into()));
    }
}
