//! Wall-clock timing + latency summaries (criterion is unavailable offline;
//! `bench.rs` builds on this module).

use std::time::Instant;

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Online latency accumulator: stores samples, summarises on demand.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub std_dev: f64,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn summary(&self) -> Summary {
        if self.samples.is_empty() {
            return Summary::default();
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            count: n,
            mean,
            min: s[0],
            max: s[n - 1],
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            p99: percentile(&s, 0.99),
            std_dev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile over a *sorted* slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

impl Summary {
    pub fn format_line(&self, unit_per_sec: Option<f64>) -> String {
        let base = format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.count,
            super::human_duration(self.mean),
            super::human_duration(self.p50),
            super::human_duration(self.p95),
            super::human_duration(self.p99),
            super::human_duration(self.max),
        );
        match unit_per_sec {
            Some(units) if self.mean > 0.0 => {
                format!("{base} thrpt={:.1}/s", units / self.mean)
            }
            _ => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&s, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile(&s, 0.5) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_basic() {
        let mut st = LatencyStats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            st.record(v);
        }
        let s = st.summary();
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(s.std_dev > 1.0 && s.std_dev < 1.2);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = LatencyStats::new().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_secs() >= 0.002);
    }
}
