//! Wall-clock timing + latency summaries (criterion is unavailable offline;
//! `bench.rs` builds on this module).
//!
//! [`LatencyStats`] is an *offline* accumulator for bench summaries —
//! it keeps a bounded reservoir of samples so percentile math stays
//! exact-ish at bench scale without unbounded memory. It must never sit
//! on a serving path: the server records latency into lock-free
//! [`crate::obs::ObsHistogram`] buckets instead.

use crate::util::rng::Xoshiro256;
use std::time::Instant;

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Reservoir capacity: beyond this many samples, new ones replace a
/// uniformly random slot (Algorithm R), so the reservoir stays a
/// uniform sample of everything seen and memory is bounded forever.
const RESERVOIR_CAP: usize = 8192;

/// Offline latency accumulator: keeps a bounded uniform reservoir of
/// samples, summarises on demand. `count`, `min`, `max` and the mean
/// remain exact over *all* recorded samples; percentiles and std-dev
/// are computed over the reservoir (exact until `RESERVOIR_CAP`
/// samples, a uniform estimate after).
#[derive(Clone, Debug)]
pub struct LatencyStats {
    samples: Vec<f64>,
    /// Total samples ever recorded (>= samples.len()).
    seen: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Deterministic replacement choices — summaries are reproducible.
    rng: Xoshiro256,
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self {
            samples: Vec::new(),
            seen: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: Xoshiro256::new(0x1a7e_5747),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub std_dev: f64,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, secs: f64) {
        self.seen += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(secs);
        } else {
            // Algorithm R: keep with probability CAP/seen.
            let j = self.rng.gen_range(self.seen) as usize;
            if j < RESERVOIR_CAP {
                self.samples[j] = secs;
            }
        }
    }

    /// Total samples recorded (not the reservoir size).
    pub fn len(&self) -> usize {
        self.seen as usize
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    pub fn summary(&self) -> Summary {
        if self.samples.is_empty() {
            return Summary::default();
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let mean = self.sum / self.seen as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / s.len() as f64;
        Summary {
            count: self.seen as usize,
            mean,
            min: self.min,
            max: self.max,
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            p99: percentile(&s, 0.99),
            std_dev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile over a *sorted* slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

impl Summary {
    pub fn format_line(&self, unit_per_sec: Option<f64>) -> String {
        let base = format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.count,
            super::human_duration(self.mean),
            super::human_duration(self.p50),
            super::human_duration(self.p95),
            super::human_duration(self.p99),
            super::human_duration(self.max),
        );
        match unit_per_sec {
            Some(units) if self.mean > 0.0 => {
                format!("{base} thrpt={:.1}/s", units / self.mean)
            }
            _ => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&s, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile(&s, 0.5) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_basic() {
        let mut st = LatencyStats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            st.record(v);
        }
        let s = st.summary();
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(s.std_dev > 1.0 && s.std_dev < 1.2);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = LatencyStats::new().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn reservoir_caps_memory_but_keeps_exact_extremes() {
        let mut st = LatencyStats::new();
        let n = RESERVOIR_CAP * 4;
        for i in 0..n {
            st.record(i as f64 / 1000.0);
        }
        assert_eq!(st.len(), n, "count stays exact");
        assert_eq!(st.samples.len(), RESERVOIR_CAP, "memory stays bounded");
        let s = st.summary();
        assert_eq!(s.count, n);
        assert_eq!(s.min, 0.0, "min exact despite sampling");
        assert_eq!(s.max, (n - 1) as f64 / 1000.0, "max exact despite sampling");
        // mean exact; p50 a uniform-sample estimate of the true median
        let true_mean = (n - 1) as f64 / 2.0 / 1000.0;
        assert!((s.mean - true_mean).abs() < 1e-9);
        assert!((s.p50 - true_mean).abs() < 0.1 * true_mean + 0.01);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_secs() >= 0.002);
    }
}
