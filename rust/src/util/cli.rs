//! Tiny CLI argument parser (no `clap` offline).
//!
//! Grammar: `prog <subcommand...> [--key value | --flag] [positional...]`.
//! Values may also be attached with `=`: `--dim=1000`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (subcommands are usually the first few).
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` pairs; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else {
                    // value-follows unless next token is another option or absent
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.options.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str_opt(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated usize list, e.g. `--dims 100,500,1000`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.str_opt(key) {
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated string list.
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| t.trim().to_string())
                .collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["repro", "fig3", "--dim", "1000", "--fast", "--seed=9"]);
        assert_eq!(a.positional, vec!["repro", "fig3"]);
        assert_eq!(a.usize_or("dim", 0), 1000);
        assert!(a.flag("fast"));
        assert_eq!(a.u64_or("seed", 0), 9);
    }

    #[test]
    fn flag_before_positional() {
        // `--fast repro` — "repro" is consumed as the value of --fast; users
        // must order flags last or use `--fast=true`. Documented behaviour.
        let a = parse(&["--fast=true", "repro"]);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["repro"]);
    }

    #[test]
    fn lists() {
        let a = parse(&["--dims", "100,200 ,300"]);
        assert_eq!(a.usize_list_or("dims", &[]), vec![100, 200, 300]);
        assert_eq!(a.usize_list_or("absent", &[5]), vec![5]);
        let b = parse(&["--sets", "kos,nips"]);
        assert_eq!(b.str_list_or("sets", &[]), vec!["kos", "nips"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str_or("x", "d"), "d");
        assert_eq!(a.f64_or("y", 1.5), 1.5);
        assert!(!a.flag("z"));
    }
}
