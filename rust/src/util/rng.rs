//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! Two layers:
//!
//! * [`SplitMix64`] — the seeding / hashing primitive. Its output stream for
//!   a given seed is **bit-identical** to `python/compile/prng.py`; both the
//!   attribute mapping π and the category mapping ψ are derived from it so
//!   the rust native path and the JAX AOT artifacts agree exactly.
//! * [`Xoshiro256`] — xoshiro256** for bulk randomness (datasets, baselines,
//!   clustering inits).
//!
//! On top of those: uniform ranges without modulo bias, normals (Box–Muller),
//! shuffling, reservoir/index sampling, and a bounded Zipf sampler used by
//! the synthetic dataset twins.

/// SplitMix64: tiny, fast, and good enough for seeding and index hashing.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (the java.util.SplittableRandom finalizer).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Stateless splitmix-style hash of a single 64-bit key. Used for the
/// per-attribute ψ variant (ablation A2) where ψ_i(v) = bit of hash(i,v).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — general-purpose generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; the pair's twin
    /// is discarded — simplicity over throughput, fine for our use).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates;
    /// O(n) memory, fine for our scales).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={} > n={}", k, n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.usize_in(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from an explicit discrete distribution (weights need not be
    /// normalised). Linear scan — used for small supports only.
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(α) sampler over `{0, …, n-1}` with precomputed CDF + binary search.
/// Rank 0 is the most frequent symbol. Used to give the synthetic BoW twins
/// realistic head-heavy word distributions.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let norm = acc;
        for c in cdf.iter_mut() {
            *c /= norm;
        }
        Self { cdf }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.total_cmp(&u))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These exact values are asserted by python/tests/test_prng.py too —
    /// the cross-language contract that makes π/ψ identical in both layers.
    #[test]
    fn splitmix_known_vectors() {
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
        let mut sm = SplitMix64::new(42);
        assert_eq!(sm.next_u64(), 0xBDD732262FEB6E95);
    }

    #[test]
    fn xoshiro_uniformity_smoke() {
        let mut rng = Xoshiro256::new(7);
        let n = 200_000;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            buckets[(rng.next_f64() * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            let expect = n / 10;
            assert!((b as i64 - expect as i64).abs() < (expect as i64) / 10);
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Xoshiro256::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Xoshiro256::new(9);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn zipf_is_head_heavy() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Xoshiro256::new(1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[999] * 10);
    }

    #[test]
    fn discrete_respects_weights() {
        let mut rng = Xoshiro256::new(2);
        let w = [1.0, 0.0, 3.0];
        let mut c = [0usize; 3];
        for _ in 0..20_000 {
            c[rng.discrete(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 2 * c[0]);
    }
}
