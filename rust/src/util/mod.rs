//! Offline utility substrates.
//!
//! This environment builds without network access, so the crates one would
//! normally reach for (`rand`, `serde`/`serde_json`, `clap`, `rayon`,
//! `indicatif`) are unavailable. Each submodule is a small, fully-tested
//! replacement for the subset of functionality this project needs:
//!
//! * [`rng`] — splitmix64 / xoshiro256** PRNG, distributions, sampling.
//!   The stream is bit-identical to the python implementation in
//!   `python/compile/prng.py` so π/ψ agree across layers.
//! * [`json`] — minimal JSON value model, parser and serializer (the
//!   coordinator wire protocol).
//! * [`cli`] — argument parser for the `cabin-sketch` binary.
//! * [`parallel`] — scoped data-parallel helpers over `std::thread`.
//! * [`timer`] — stopwatch + latency summaries (mean/p50/p95/p99).

pub mod cli;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod timer;

/// Format a byte count for humans (`12.3 MiB`).
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in adaptive units.
pub fn human_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(human_duration(0.5e-9 * 2.0), "1.0 ns");
        assert!(human_duration(0.002).ends_with("ms"));
        assert!(human_duration(5.0).ends_with(" s"));
        assert!(human_duration(600.0).ends_with("min"));
    }
}
