//! `cabin-sketch` — the coordinator binary.
//!
//! ```text
//! cabin-sketch serve   [--addr 127.0.0.1:7878] [--dim 4096] [--categories 64]
//!                      [--sketch-dim 1024] [--seed 42] [--shards 4]
//!                      [--no-xla] [--max-batch 64] [--max-delay-ms 2]
//!                      [--executor-queue 1024]
//!                      [--index auto|on|off] [--index-bands 8]
//!                      [--index-band-bits 16] [--index-probes 2]
//!                      [--index-auto-min-rows 1024]
//!                      [--data-dir DIR] [--persist off|wal|wal+snapshot]
//!                      [--fsync always|never] [--snapshot-every 50000]
//!                      [--commit-window-us 1000] [--wal-max-bytes 0]
//!                      [--compact-dead-frames 0] [--ttl-sweep-ms 1000]
//!                      [--replicate-from HOST:PORT] [--repl-poll-ms 2]
//!                      [--auto-promote] [--probe-interval-ms 500]
//!                      [--probe-timeout-ms 1000] [--probe-failures 3]
//!                      [--log-level info] [--log-json] [--slow-op-ms 0]
//!                      [--max-read-staleness-ms 0]
//! cabin-sketch stats   [--addr 127.0.0.1:7878] [--prom]
//! cabin-sketch events  [--addr 127.0.0.1:7878]
//! cabin-sketch promote [--addr 127.0.0.1:7878]
//! cabin-sketch demote  [--addr 127.0.0.1:7878] [--epoch N]
//! cabin-sketch sketch  --input docword.txt [--sketch-dim 1000] [--out sketches.bin]
//! cabin-sketch repro   <table1|table3|table4|fig2..fig12|ablation-*|all> [options]
//! cabin-sketch info    # artifact + environment report
//! ```
//!
//! See DESIGN.md for the experiment index and README.md for a tour.

use cabin::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, IndexConfig, PersistConfig, PersistMode,
};
use cabin::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "events" => cmd_events(&args),
        "promote" => cmd_promote(&args),
        "demote" => cmd_demote(&args),
        "sketch" => cmd_sketch(&args),
        "repro" => cmd_repro(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            std::process::exit(2);
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn print_help() {
    println!(
        "cabin-sketch — Cabin/Cham categorical sketching service\n\
         \n\
         commands:\n\
           serve    run the sketch service (TCP line-JSON protocol); the\n\
                    corpus is mutable — insert, delete, upsert and per-row\n\
                    TTL are first-class, durable, replicated operations\n\
           stats    fetch a running server's stats (--addr HOST:PORT);\n\
                    --prom prints the Prometheus text exposition instead\n\
                    (the metrics_text wire op: counters, gauges, and full\n\
                    per-stage latency histogram bucket families)\n\
           events   dump a running server's flight-recorder journal\n\
                    (--addr HOST:PORT): the last 256 lifecycle events —\n\
                    startup, promote, fence, slow ops, commit failures —\n\
                    as JSONL, oldest first; survives log rotation and is\n\
                    the first stop in a failover post-mortem\n\
           promote  flip a read replica writable now (--addr HOST:PORT);\n\
                    prints the per-shard applied sequences and the new\n\
                    failover epoch\n\
           demote   fence a server read-only (--addr HOST:PORT); optional\n\
                    --epoch N fences at an explicit epoch — see\n\
                    docs/FAILOVER.md for when to reach for this\n\
           sketch   one-shot: sketch a UCI docword file to packed binary\n\
           repro    regenerate a paper table/figure (see DESIGN.md §4)\n\
           info     report artifacts, backend and configuration\n\
         \n\
         repro ids: table1 table3 table4 fig2 fig3 fig4 fig5 fig6 fig7 fig8\n\
                    fig9 fig10 fig11 fig12 ablation-estimator ablation-psi\n\
                    ablation-onehot all\n\
         common options: --datasets kos,nips,... --points N --dims 100,500\n\
                    --dim 1000 --seed 42 --budget-secs 120\n\
         serve runtime: --executor-queue N (per-shard scan-queue bound; scan\n\
                    workers are persistent — one thread per shard, no\n\
                    per-request spawning)\n\
         serve persistence: --data-dir DIR [--persist off|wal|wal+snapshot]\n\
                    [--fsync always|never] [--snapshot-every 50000]\n\
                    [--commit-window-us N] (group-commit window: insert\n\
                    fsyncs coalesce across batches within the window; acks\n\
                    wait for their window's flush; 0 = commit per batch;\n\
                    engaged under --fsync always, where an fsync exists\n\
                    to amortise)\n\
                    [--wal-max-bytes N] (size-triggered auto-snapshot:\n\
                    rotate when the live WAL segments exceed N bytes — the\n\
                    persist_wal_live_bytes stats gauge; 0 = off; bounds\n\
                    replay and follower-bootstrap cost independently of\n\
                    --snapshot-every)\n\
                    [--compact-dead-frames N] (WAL compaction: deletes and\n\
                    in-place upserts leave dead frames behind; once N of\n\
                    them accumulate the next rotation folds them away by\n\
                    cutting a fresh snapshot — the persist_wal_dead_frames\n\
                    and persist_compactions stats track it; 0 = off)\n\
         serve mutations: delete / upsert wire ops, plus optional ttl_ms on\n\
                    every insert form (relative; the primary stamps the\n\
                    absolute deadline)\n\
                    [--ttl-sweep-ms N] (primary-side TTL sweep interval —\n\
                    also the expiry granularity; expired rows are removed\n\
                    by ordinary replicated Delete frames, so replicas just\n\
                    mirror them; 0 = off; default 1000)\n\
         serve replication: --replicate-from HOST:PORT (+ --data-dir; run as\n\
                    a read replica of that primary: bootstrap from its\n\
                    newest snapshot, apply its WAL stream continuously,\n\
                    serve query/query_batch/distance/stats with results\n\
                    bit-identical to the primary's, reject writes (insert,\n\
                    delete, upsert) with a redirect; the corpus flags must\n\
                    match the primary's.\n\
                    The `promote` wire op flips a caught-up replica\n\
                    writable — e.g. after killing a dead primary)\n\
                    [--repl-poll-ms N] (idle tail-poll interval)\n\
         serve failover: [--auto-promote] (replica-side health probing: the\n\
                    follower pings its primary every --probe-interval-ms\n\
                    (default 500) with a --probe-timeout-ms budget (default\n\
                    1000) and self-promotes after --probe-failures (default\n\
                    3) consecutive misses — a slow primary that answers\n\
                    within the budget is never promoted over, only a dead\n\
                    one; requires --replicate-from and --data-dir). Every\n\
                    promotion bumps a durable monotonic epoch; a revived\n\
                    stale primary fences itself read-only on first contact\n\
                    with the newer epoch (failover_* stats; see\n\
                    docs/FAILOVER.md)\n\
         serve observability: [--log-level debug|info|warn|error] (event\n\
                    filter, default info) [--log-json] (one JSON object\n\
                    per event line instead of text — machine-ingestable)\n\
                    [--slow-op-ms N] (emit one structured slow_op record,\n\
                    with the request's per-stage latency breakdown and\n\
                    trace id, for any request slower than N ms; 0 = off).\n\
                    Per-stage latency histograms (batcher queue wait,\n\
                    sketch, placement, WAL, fsync wait, reply; executor\n\
                    queue wait, scan, rerank, gather) ride in stats as\n\
                    stage_* fields and in `stats --prom` as full\n\
                    Prometheus histogram families.\n\
                    Requests may carry a client-set \"trace\" id that the\n\
                    server logs instead of stamping its own — replicated\n\
                    writes surface it on the follower too, so one grep\n\
                    tells a request's cross-node story\n\
                    [--max-read-staleness-ms N] (advisory replica-read\n\
                    staleness budget: exported as the\n\
                    cfg_max_read_staleness_ms gauge so dashboards can\n\
                    alert when repl_visibility_lag_p99_ms breaches it;\n\
                    0 = unset; does not gate reads)"
    );
}

fn coordinator_config(args: &Args) -> CoordinatorConfig {
    CoordinatorConfig {
        input_dim: args.usize_or("dim", 4096),
        num_categories: args.usize_or("categories", 64) as u16,
        sketch_dim: args.usize_or("sketch-dim", 1024),
        seed: args.u64_or("seed", 42),
        num_shards: args.usize_or("shards", 4),
        batcher: BatcherConfig {
            max_batch: args.usize_or("max-batch", 64),
            max_delay: Duration::from_millis(args.u64_or("max-delay-ms", 2)),
            queue_cap: args.usize_or("queue-cap", 4096),
        },
        use_xla: !args.flag("no-xla"),
        heatmap_limit: args.usize_or("heatmap-limit", 4096),
        index: index_config(args),
        persist: persist_config(args),
        executor_queue: args.usize_or("executor-queue", 1024),
        replicate_from: args.str_opt("replicate-from").map(str::to_string),
        repl_poll_ms: args.u64_or("repl-poll-ms", 2),
        auto_promote: args.flag("auto-promote"),
        probe_interval_ms: args.u64_or("probe-interval-ms", 500),
        probe_timeout_ms: args.u64_or("probe-timeout-ms", 1_000),
        probe_failures: args.u64_or("probe-failures", 3) as u32,
        ttl_sweep_ms: args.u64_or("ttl-sweep-ms", 1_000),
        log_level: args.str_or("log-level", "info"),
        log_json: args.flag("log-json"),
        slow_op_ms: args.u64_or("slow-op-ms", 0),
        max_read_staleness_ms: args.u64_or("max-read-staleness-ms", 0),
    }
}

fn index_config(args: &Args) -> IndexConfig {
    let defaults = IndexConfig::default();
    IndexConfig {
        mode: IndexConfig::mode_from_str_or_warn(&args.str_or("index", "auto"), "serve"),
        bands: args.usize_or("index-bands", defaults.bands),
        band_bits: args.usize_or("index-band-bits", defaults.band_bits),
        probes: args.usize_or("index-probes", defaults.probes),
        auto_min_rows: args.usize_or("index-auto-min-rows", defaults.auto_min_rows),
    }
}

/// Persistence flags: `--data-dir DIR` turns durability on (default mode
/// `wal+snapshot`); `--persist`, `--fsync` and `--snapshot-every` refine
/// it. `--persist wal` without `--data-dir` is a configuration error the
/// coordinator reports at startup (it needs somewhere to write).
fn persist_config(args: &Args) -> PersistConfig {
    let data_dir = args.str_opt("data-dir").map(std::path::PathBuf::from);
    let defaults = PersistConfig::default();
    let mode = match args.str_opt("persist") {
        Some(s) => PersistConfig::mode_from_str_or_warn(s, "serve"),
        None if data_dir.is_some() => PersistMode::WalSnapshot,
        None => PersistMode::Off,
    };
    PersistConfig {
        mode,
        data_dir,
        fsync: PersistConfig::fsync_from_str_or_warn(&args.str_or("fsync", "always"), "serve"),
        snapshot_every: args.u64_or("snapshot-every", defaults.snapshot_every),
        commit_window_us: args.u64_or("commit-window-us", defaults.commit_window_us),
        wal_max_bytes: args.u64_or("wal-max-bytes", defaults.wal_max_bytes),
        compact_dead_frames: args.u64_or("compact-dead-frames", defaults.compact_dead_frames),
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let config = coordinator_config(args);
    // (a persist mode without --data-dir is rejected inside try_new)
    let coordinator = Arc::new(Coordinator::try_new(config)?);
    println!(
        "[serve] corpus dim={} c={} sketch d={} shards={} index={:?} — listening",
        coordinator.config.input_dim,
        coordinator.config.num_categories,
        coordinator.config.sketch_dim,
        coordinator.config.num_shards,
        coordinator.config.index.mode
    );
    match (
        &coordinator.config.persist.data_dir,
        coordinator.store.persistence(),
    ) {
        (Some(dir), Some(p)) => println!(
            "[serve] persistence {:?} at {} (generation {}, {} sketches recovered)",
            coordinator.config.persist.mode,
            dir.display(),
            p.generation(),
            coordinator.store.len()
        ),
        _ => println!("[serve] persistence off (corpus is in-memory only)"),
    }
    if let Some(primary) = &coordinator.config.replicate_from {
        println!("[serve] read replica of {primary} — inserts are rejected until `promote`");
        if coordinator.config.auto_promote {
            println!(
                "[serve] auto-promote armed: probe every {}ms, {}ms budget, \
                 promote after {} consecutive failures",
                coordinator.config.probe_interval_ms,
                coordinator.config.probe_timeout_ms,
                coordinator.config.probe_failures
            );
        }
    }
    coordinator.serve(&addr, |bound| println!("[serve] bound {bound}"))
}

/// `stats --addr HOST:PORT [--prom]`: one-shot scrape of a running
/// server. Default output is the flat `stats` fields (name value per
/// line); `--prom` asks for the `metrics_text` Prometheus exposition
/// instead — suitable as a scrape target via
/// `cabin-sketch stats --addr … --prom > metrics.prom`.
fn cmd_stats(args: &Args) -> anyhow::Result<()> {
    use cabin::coordinator::client::Client;
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = Client::connect(&addr)?;
    if args.flag("prom") {
        print!("{}", client.metrics_text()?);
    } else {
        for (name, value) in client.stats()? {
            println!("{name} {value}");
        }
    }
    Ok(())
}

/// `events --addr HOST:PORT`: dump a running server's flight-recorder
/// journal as JSONL, oldest event first (`events` stream op). Pipe into
/// `jq`/`grep` — e.g. `cabin-sketch events --addr … | grep '"promoted"'`
/// finds exactly when and why a replica took over.
fn cmd_events(args: &Args) -> anyhow::Result<()> {
    use cabin::coordinator::client::Client;
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = Client::connect(&addr)?;
    print!("{}", client.events()?);
    Ok(())
}

/// `promote --addr HOST:PORT`: flip a read replica writable now, from
/// the operator's shell — the manual half of failover (the automatic
/// half is `serve --auto-promote`).
fn cmd_promote(args: &Args) -> anyhow::Result<()> {
    use cabin::coordinator::client::Client;
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = Client::connect(&addr)?;
    let (applied_seqs, epoch) = client.promote()?;
    println!("[promote] {addr} writable at epoch {epoch}, applied seqs {applied_seqs:?}");
    Ok(())
}

/// `demote --addr HOST:PORT [--epoch N]`: fence a server read-only so it
/// can be pointed at the new primary with `--replicate-from`. Without
/// `--epoch` it fences at the server's own epoch; with it, at
/// `max(own, N)` — a demote can raise a fence, never lower one.
fn cmd_demote(args: &Args) -> anyhow::Result<()> {
    use cabin::coordinator::client::Client;
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let mut client = Client::connect(&addr)?;
    let epoch = client.demote(args.str_opt("epoch").and_then(|e| e.parse().ok()))?;
    println!("[demote] {addr} fenced read-only at epoch {epoch} — rejoin with --replicate-from");
    Ok(())
}

fn cmd_sketch(args: &Args) -> anyhow::Result<()> {
    use cabin::sketch::{CabinSketcher, SketchConfig};
    use std::io::Write;
    let input = args
        .str_opt("input")
        .ok_or_else(|| anyhow::anyhow!("--input <docword.txt> required"))?;
    let d = args.usize_or("sketch-dim", 1000);
    let seed = args.u64_or("seed", 42);
    let cap = args.usize_or("categories", u16::MAX as usize) as u16;
    let max_points = args.str_opt("points").and_then(|p| p.parse().ok());
    let ds = cabin::data::bow::load_docword(input, cap, max_points)?;
    println!(
        "[sketch] {}: {} points, dim {}, density ≤ {}",
        ds.name,
        ds.len(),
        ds.dim(),
        ds.max_density()
    );
    let cfg = SketchConfig::new(ds.dim(), ds.num_categories(), d, seed);
    let sk = CabinSketcher::from_config(cfg);
    let sketches = sk.sketch_dataset(&ds, cabin::util::parallel::default_threads());
    let out = args.str_or("out", "sketches.bin");
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
    // header: magic, d, count — then packed u64 words per sketch
    f.write_all(b"CABN")?;
    f.write_all(&(d as u64).to_le_bytes())?;
    f.write_all(&(sketches.len() as u64).to_le_bytes())?;
    for s in &sketches {
        for w in s.words() {
            f.write_all(&w.to_le_bytes())?;
        }
    }
    println!(
        "[sketch] wrote {} ({} per point)",
        out,
        cabin::util::human_bytes(d.div_ceil(8))
    );
    Ok(())
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    cabin::repro::run(id, args)
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    println!("cabin-sketch {}", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", cabin::util::parallel::default_threads());
    match cabin::runtime::XlaEngine::try_default() {
        Some(engine) => {
            println!("xla: available (platform {})", engine.platform());
            let m = &engine.manifest;
            println!(
                "artifacts: n={} c={} d={} seed={} batches: sketch {}, allpairs {}, cross {}x{}",
                m.n, m.c, m.d, m.seed, m.m, m.mp, m.mq, m.mc
            );
            println!("sidecars validated: π and ψ match native derivations");
        }
        None => println!("xla: artifacts not found (native path only) — run `make artifacts`"),
    }
    let _ = args;
    Ok(())
}
