//! Durable storage for the coordinator's sketch corpus: per-shard
//! write-ahead logs + periodic full-arena snapshots + a manifest, so a
//! restarted coordinator warm-loads the corpus it had instead of
//! re-sketching it — which is exactly the cost BinSketch exists to avoid.
//!
//! Layout of a data dir at generation `G`:
//!
//! ```text
//!   MANIFEST                  commit point: {generation, fingerprint}
//!   snap-G-shard-{0..S}.bin   full arena snapshot at the generation cut
//!   wal-G-shard-{0..S}.log    every mutation since that cut, in order
//! ```
//!
//! Write path: the store appends WAL records *under the shard write lock*
//! (so log order = arena order) and commits once per batch before the
//! batch is acknowledged; with [`FsyncPolicy::Always`] an acknowledged
//! mutation therefore survives `kill -9`. The log is a full mutation
//! stream, not an insert stream: `Insert`/`InsertTtl` push a row,
//! `Delete` swap-removes one, `Upsert` overwrites one in place, and
//! `MoveOut`/`MoveIn` pairs (sharing a move id) relocate one across
//! shards — see [`wal`] for the frame formats.
//!
//! Group commit (`commit_window_us > 0` — the default — under
//! `fsync = always`; with `fsync = never` a commit is a buffered write
//! with nothing to amortise, so those stores keep the synchronous
//! per-batch path): the per-batch
//! commit is delegated to a dedicated group-commit thread. An insert
//! batch appends its frames (buffered in the writer, under the shard
//! lock), registers its shard in the current *commit window*, and blocks
//! until that window is flushed; the committer holds each window open for
//! the configured duration (or until a batch cap), then commits every
//! dirty shard's WAL once — so concurrent batches landing in the same
//! window share one write + fsync per touched shard instead of paying one
//! each. Acks are released only when their window's flush lands, which
//! preserves the "acked ⇒ survives kill -9" contract, and a flush
//! *failure* is handed back to every batch of that window — the store
//! surfaces it through `try_insert_batch` and the batcher turns it into a
//! client-visible insert error. Rebalance keeps its synchronous
//! dst-before-src commit ordering (the lost-row crash window depends on
//! that order, which a shared window fsync could not guarantee); a
//! rebalance commit flushing early frames of an open insert window is
//! harmless — the window's own commit then finds them already on disk.
//!
//! Snapshot rotation is
//! stop-the-world (it holds the store's id-index read lock, which blocks
//! inserts and rebalances): write `snap-(G+1)-*` durably → create empty
//! `wal-(G+1)-*` → write `MANIFEST(G+1)` (the commit point) → swap the
//! live writers → GC generation `G`. A crash on either side of the
//! manifest rename recovers a complete generation — never a mix.
//!
//! WAL compaction is folded into snapshot rotation rather than run as a
//! separate rewrite pass: a `Delete` frame makes two frames dead (itself
//! plus the insert it cancels) and an in-place `Upsert` makes one dead
//! (the version it shadows), and since a rotation cuts a snapshot that
//! already *contains* the survivors and starts a fresh empty segment,
//! rotating IS dropping every dead frame. The store accounts dead frames
//! as they are written (`persist_wal_dead_frames` gauge, reset by
//! rotation), `--compact-dead-frames N` arms a third auto-rotation
//! trigger on that count (alongside `snapshot_every` records and
//! `--wal-max-bytes`), and each rotation that reclaimed at least one
//! dead frame counts as a `persist_compactions`.
//!
//! Sequence numbers + retention (replication, see [`crate::replica`]):
//! every WAL frame carries an implicit monotonic per-shard sequence —
//! frame `j` of `wal-G-shard-i` is sequence `base_seqs[i] + j`, where the
//! manifest (v5) records each generation's per-shard base. The manifest
//! also records the failover `epoch` — the monotonic write-authority
//! term that fences a revived old primary after a promotion (see
//! [`Persistence::set_epoch`] and [`crate::replica`]). Rotation
//! advances the bases by the frames the cut absorbed, and *retains the
//! previous generation's WAL segments* for exactly one generation so a
//! follower that lags across a rotation can still be served the frames
//! the new snapshot already absorbed; two-generations-old segments are
//! GC'd. Rotation can be size-triggered too: with `--wal-max-bytes` set,
//! crossing that live-segment size claims a rotation exactly like the
//! record-count trigger (and a failed rotation likewise backs off a full
//! interval), bounding replay and follower-bootstrap cost independently
//! of `snapshot_every`.
//!
//! Recovery (see [`recovery`]): load the manifest, hard-error on a
//! configuration-fingerprint mismatch, load each shard's snapshot, replay
//! its WAL tail (dropping at most one torn trailing record), and hand the
//! shard states to the store, which bulk-rebuilds the per-shard LSH
//! indexes via the existing [`crate::index::LshIndex::rebuild`] path.
//!
//! Known limits (ROADMAP "Open items"): snapshots are stop-the-world and
//! full, not incremental; dead frames between rotations are reclaimed
//! only by the next rotation (there is no in-place segment rewrite).

pub mod manifest;
pub mod recovery;
pub mod snapshot;
pub mod wal;

pub use manifest::Fingerprint;
pub use recovery::RecoveryReport;
pub use snapshot::ShardState;

use crate::sketch::SketchMatrix;
use anyhow::{Context, Result};
use manifest::{snap_path, sync_dir, wal_path, Manifest};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};
use wal::{read_wal, WalWriter};

/// What gets persisted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PersistMode {
    /// No persistence: the corpus lives and dies with the process.
    Off,
    /// WAL only: every mutation is logged; recovery replays the full log.
    Wal,
    /// WAL + periodic snapshots: recovery loads the newest snapshot and
    /// replays only the log tail past it.
    WalSnapshot,
}

/// When WAL commits reach the disk platter, not just the OS page cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Flush to the OS per batch; fsync only on explicit `flush`/shutdown.
    /// Survives process crashes, not host power loss.
    Never,
    /// `fdatasync` once per committed batch, before the batch is
    /// acknowledged — acknowledged inserts survive `kill -9` and power
    /// loss.
    Always,
}

/// Persistence knobs, carried by `CoordinatorConfig`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PersistConfig {
    pub mode: PersistMode,
    /// Where the manifest, snapshots and WAL segments live. Required for
    /// any mode other than [`PersistMode::Off`].
    pub data_dir: Option<PathBuf>,
    pub fsync: FsyncPolicy,
    /// Auto-snapshot after this many WAL records (inserts + rebalance
    /// moves) since the last cut; `0` disables auto-snapshotting (the
    /// `snapshot` wire op still works). Only meaningful under
    /// [`PersistMode::WalSnapshot`].
    pub snapshot_every: u64,
    /// Group-commit window in microseconds (`--commit-window-us`): insert
    /// WAL commits from every batch landing within one window coalesce
    /// into a single write + fsync per touched shard, performed by the
    /// group-commit thread; each batch's ack waits for its window's
    /// flush. `0` commits synchronously on the insert path (the
    /// pre-group-commit behaviour). Default 1000 (≈1 ms). Only engaged
    /// under [`FsyncPolicy::Always`] — with `fsync = never` a commit is a
    /// buffered write with nothing to amortise, so holding acks for a
    /// window would be pure added latency and the synchronous path is
    /// kept.
    pub commit_window_us: u64,
    /// Size-triggered auto-snapshot (`--wal-max-bytes`): rotate when the
    /// live WAL segments' total on-disk size crosses this many bytes —
    /// the same number `stats` surfaces as `persist_wal_live_bytes`, so
    /// operators and the trigger read one gauge. `0` (the default)
    /// disables the size trigger; the record-count trigger
    /// (`snapshot_every`) is independent and either can fire. Only
    /// meaningful under [`PersistMode::WalSnapshot`].
    pub wal_max_bytes: u64,
    /// Dead-frame-triggered compaction (`--compact-dead-frames`): rotate —
    /// which drops every frame the new snapshot shadows — once the live
    /// segments have accumulated this many dead frames (each `Delete`
    /// deadens two frames, each in-place `Upsert` one). `0` (the default)
    /// disables the trigger; the record-count and byte-size triggers are
    /// independent and any of the three can fire. Only meaningful under
    /// [`PersistMode::WalSnapshot`].
    pub compact_dead_frames: u64,
}

impl Default for PersistConfig {
    fn default() -> Self {
        Self {
            mode: PersistMode::Off,
            data_dir: None,
            fsync: FsyncPolicy::Always,
            snapshot_every: 50_000,
            commit_window_us: 1_000,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        }
    }
}

impl PersistConfig {
    /// Whether the store should open a [`Persistence`] at all.
    pub fn enabled(&self) -> bool {
        self.mode != PersistMode::Off && self.data_dir.is_some()
    }

    /// Parse a CLI mode string (`off` | `wal` | `wal+snapshot`).
    pub fn mode_from_str(s: &str) -> Option<PersistMode> {
        match s {
            "off" => Some(PersistMode::Off),
            "wal" => Some(PersistMode::Wal),
            "wal+snapshot" | "wal-snapshot" | "snapshot" => Some(PersistMode::WalSnapshot),
            _ => None,
        }
    }

    /// CLI-friendly variant: unknown strings warn and fall back to
    /// `wal+snapshot` (the safe-and-complete default for a `--data-dir`).
    pub fn mode_from_str_or_warn(s: &str, context: &str) -> PersistMode {
        Self::mode_from_str(s).unwrap_or_else(|| {
            crate::obs::log::warn(
                context,
                "unknown_persist_mode",
                &[
                    ("value", crate::obs::log::V::s(s)),
                    ("want", crate::obs::log::V::s("off|wal|wal+snapshot")),
                    ("using", crate::obs::log::V::s("wal+snapshot")),
                ],
            );
            PersistMode::WalSnapshot
        })
    }

    /// Parse a CLI fsync string (`always` | `never`), warning and falling
    /// back to `always` (the durable default) on anything else.
    pub fn fsync_from_str_or_warn(s: &str, context: &str) -> FsyncPolicy {
        match s {
            "always" => FsyncPolicy::Always,
            "never" | "off" => FsyncPolicy::Never,
            other => {
                crate::obs::log::warn(
                    context,
                    "unknown_fsync_policy",
                    &[
                        ("value", crate::obs::log::V::s(other)),
                        ("want", crate::obs::log::V::s("always|never")),
                        ("using", crate::obs::log::V::s("always")),
                    ],
                );
                FsyncPolicy::Always
            }
        }
    }

    /// Read-only configuration view merged into the `stats` response
    /// (`persist_cfg_*`, mirroring `index_cfg_*`).
    pub fn stats_fields(&self) -> Vec<(String, f64)> {
        let mode = match self.mode {
            PersistMode::Off => 0.0,
            PersistMode::Wal => 1.0,
            PersistMode::WalSnapshot => 2.0,
        };
        let fsync = match self.fsync {
            FsyncPolicy::Never => 0.0,
            FsyncPolicy::Always => 1.0,
        };
        vec![
            ("persist_cfg_mode".into(), mode),
            ("persist_cfg_fsync".into(), fsync),
            (
                "persist_cfg_snapshot_every".into(),
                self.snapshot_every as f64,
            ),
            (
                "persist_cfg_commit_window_us".into(),
                self.commit_window_us as f64,
            ),
            (
                "persist_cfg_wal_max_bytes".into(),
                self.wal_max_bytes as f64,
            ),
            (
                "persist_cfg_compact_dead_frames".into(),
                self.compact_dead_frames as f64,
            ),
        ]
    }
}

/// Lock-free persistence traffic counters. One instance is shared (via
/// `Arc`) between `coordinator::Metrics` — which surfaces them as
/// `persist_*` stats fields — and the [`Persistence`] handle that updates
/// them.
#[derive(Debug, Default)]
pub struct PersistCounters {
    /// WAL records appended (inserts + rebalance moves) since startup.
    pub wal_records: AtomicU64,
    /// WAL bytes appended since startup.
    pub wal_bytes: AtomicU64,
    /// Snapshot rotations completed since startup.
    pub snapshots: AtomicU64,
    /// Wall-clock of the startup recovery pass, in milliseconds.
    pub recovery_ms: AtomicU64,
    /// Live snapshot generation.
    pub generation: AtomicU64,
    /// Commit windows flushed by the group-commit thread since startup
    /// (each window = one write + fsync per dirty shard, shared by every
    /// batch that landed in the window).
    pub group_commits: AtomicU64,
    /// Dead frames in the live WAL segments: frames the next rotation's
    /// snapshot will shadow (each `Delete` deadens itself plus the insert
    /// it cancels; each in-place `Upsert` deadens the version it
    /// shadows). Reset to 0 by a successful rotation.
    pub wal_dead_frames: AtomicU64,
    /// Rotations that reclaimed at least one dead frame — i.e. rotations
    /// that acted as WAL compactions, however they were triggered.
    pub compactions: AtomicU64,
}

/// Poison-recovering mutex lock: a WAL writer is plain buffered-file
/// state, so a panicking holder leaves nothing logically torn that the
/// frame checksums would not catch — recover the guard instead of letting
/// one crashed worker thread brick every subsequent request.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// How many batches a commit window accepts before it is flushed early
/// (the "~1 ms or N batches" bound on window occupancy).
const COMMIT_WINDOW_MAX_BATCHES: u64 = 256;

/// How many failed windows are remembered for late waiters. Waiters wake
/// on every flush, so in practice an entry is read within one window of
/// being pushed; the cap only bounds pathological pile-ups.
const COMMIT_FAILURES_KEPT: usize = 256;

/// Group-commit bookkeeping shared between submitters (insert batches),
/// waiters and the committer thread.
struct GcInner {
    /// The window currently accepting batches; tickets are its epoch.
    open_epoch: u64,
    /// Every window with epoch ≤ `completed` has been flushed (attempted).
    completed: u64,
    /// Shards with frames awaiting the open window's flush.
    dirty: Vec<bool>,
    /// Batches registered in the open window.
    pending_batches: u64,
    /// `(epoch, per-shard errors)` for windows whose flush failed on at
    /// least one shard. Attribution is per shard: a batch whose own
    /// shard committed cleanly must ack even when a sibling shard's
    /// flush in the same window failed.
    failures: VecDeque<(u64, Vec<(usize, String)>)>,
    stop: bool,
}

struct GcShared {
    inner: Mutex<GcInner>,
    /// Signals the committer: work arrived (or stop was requested).
    work: Condvar,
    /// Signals waiters: a window completed.
    done: Condvar,
    window: Duration,
}

impl GcShared {
    fn lock(&self) -> MutexGuard<'_, GcInner> {
        lock_recover(&self.inner)
    }
}

/// The group-commit thread handle. Dropping it drains: the committer
/// flushes every registered-but-unflushed window, completes all waiters,
/// and exits; the drop joins it.
struct GroupCommitter {
    shared: Arc<GcShared>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl GroupCommitter {
    fn start(
        num_shards: usize,
        window: Duration,
        wals: Arc<Vec<Mutex<WalWriter>>>,
        counters: Arc<PersistCounters>,
    ) -> GroupCommitter {
        let shared = Arc::new(GcShared {
            inner: Mutex::new(GcInner {
                // the open window is strictly ahead of `completed`, so a
                // fresh waiter can never observe its window as already
                // flushed
                open_epoch: 1,
                completed: 0,
                dirty: vec![false; num_shards],
                pending_batches: 0,
                failures: VecDeque::new(),
                stop: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            window,
        });
        let thread_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name("cabin-group-commit".into())
            .spawn(move || committer_loop(&thread_shared, &wals, &counters))
            .expect("spawn group-commit thread");
        GroupCommitter {
            shared,
            handle: Some(handle),
        }
    }
}

impl Drop for GroupCommitter {
    fn drop(&mut self) {
        {
            let mut g = self.shared.lock();
            g.stop = true;
            self.shared.work.notify_all();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The group-commit loop. Windows are numbered by epoch: `open_epoch` is
/// the window batches currently register in (they read it as their
/// ticket, under the same lock that sets their dirty flag), and a window
/// is *closed* by incrementing `open_epoch` — also under the lock — so a
/// batch's frames are always appended before its window closes, which
/// means the flush that follows the close is guaranteed to see them.
fn committer_loop(shared: &GcShared, wals: &[Mutex<WalWriter>], counters: &PersistCounters) {
    let mut g = shared.lock();
    loop {
        // wait for work (or stop)
        while g.pending_batches == 0 && !g.stop {
            g = shared.work.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        if g.pending_batches == 0 {
            break; // stopping with nothing left to flush
        }
        // hold the window open to coalesce — unless stopping (drain now)
        // or the batch cap is hit
        if !g.stop {
            let deadline = Instant::now() + shared.window;
            while !g.stop && g.pending_batches < COMMIT_WINDOW_MAX_BATCHES {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .work
                    .wait_timeout(g, deadline - now)
                    .unwrap_or_else(PoisonError::into_inner);
                g = guard;
            }
        }
        // close the window
        let epoch = g.open_epoch;
        g.open_epoch += 1;
        g.pending_batches = 0;
        let dirty: Vec<usize> = g
            .dirty
            .iter()
            .enumerate()
            .filter_map(|(si, &d)| d.then_some(si))
            .collect();
        for d in g.dirty.iter_mut() {
            *d = false;
        }
        drop(g);
        // flush outside the bookkeeping lock: one commit per dirty shard.
        // Only the WAL mutexes are taken, one at a time — no store locks,
        // so this can never deadlock against inserts or rotations.
        let mut failed: Vec<(usize, String)> = Vec::new();
        for &si in &dirty {
            if let Err(e) = lock_recover(&wals[si]).commit() {
                failed.push((si, format!("shard {si}: {e}")));
            }
        }
        counters.group_commits.fetch_add(1, Ordering::Relaxed);
        g = shared.lock();
        g.completed = epoch;
        if !failed.is_empty() {
            g.failures.push_back((epoch, failed));
            while g.failures.len() > COMMIT_FAILURES_KEPT {
                g.failures.pop_front();
            }
        }
        shared.done.notify_all();
    }
    // no unflushed window can remain (a registered batch keeps the loop
    // flushing), but wake any racing waiter so nobody hangs on shutdown
    g.stop = true;
    drop(g);
    shared.done.notify_all();
}

/// Per-shard WAL sequence anchoring — one consistent view of the live
/// generation, its per-shard base sequences, and the retained previous
/// segment's anchoring (if any). Mutated only by snapshot rotation, under
/// one lock, so the replication shipper can never observe a generation
/// paired with another generation's bases.
#[derive(Clone, Debug)]
pub struct SeqView {
    /// Live snapshot generation (addresses `wal-G-shard-*`).
    pub generation: u64,
    /// Sequence of each live segment's first frame.
    pub base_seqs: Vec<u64>,
    /// Retained previous segment: `(generation, per-shard base seqs)`.
    /// Served to followers that lag across one rotation; `None` right
    /// after first startup of a fresh dir, or when the retained files
    /// were damaged/missing at recovery.
    pub prev: Option<(u64, Vec<u64>)>,
}

/// Per-shard memo of the furthest frame boundary a WAL tail scan has
/// reached in the live segment, so the replication shipper can hand
/// [`wal::read_wal_tail`] a resume hint instead of rescanning the whole
/// segment per poll. Keyed by generation — a rotation (including a
/// compacting one) changes the generation and thereby self-invalidates
/// the memo. Advances monotonically within a generation: several
/// followers at different positions share the cache, and only the
/// furthest boundary is worth remembering (a hint past a slower
/// follower's `skip` is simply ignored by the reader).
#[derive(Clone, Copy, Debug, Default)]
struct TailOffsetCache {
    generation: u64,
    /// Frame index within the segment (`seq - base`) of the boundary.
    frame: u64,
    /// Byte offset of that boundary in the segment file.
    offset: u64,
}

/// The live persistence handle owned by the store: one WAL writer per
/// shard plus the snapshot/rotation and group-commit machinery.
pub struct Persistence {
    dir: PathBuf,
    mode: PersistMode,
    fsync: FsyncPolicy,
    snapshot_every: u64,
    /// Size-triggered rotation threshold (`0` = off); see
    /// [`PersistConfig::wal_max_bytes`].
    wal_max_bytes: u64,
    /// Live-byte floor the size trigger must cross. Starts at
    /// `wal_max_bytes`; a claim raises it by a full interval above the
    /// observed size (so a *failed* rotation is deferred, mirroring the
    /// record trigger's reset-on-claim), and a successful rotation resets
    /// it to `wal_max_bytes` alongside the now-empty segments.
    bytes_floor: AtomicU64,
    fingerprint: Fingerprint,
    /// Dead-frame-count rotation threshold (`0` = off); see
    /// [`PersistConfig::compact_dead_frames`].
    compact_dead_frames: u64,
    /// Dead frames accumulated since the last snapshot cut — the
    /// compaction trigger's basis (reset on claim and on rotation; the
    /// `counters.wal_dead_frames` gauge resets on rotation only).
    dead_since_snapshot: AtomicU64,
    /// Records appended since the last snapshot cut (drives auto-snapshot).
    records_since_snapshot: AtomicU64,
    /// Failover epoch (write-authority term) — always mirrors the value
    /// persisted in the manifest; advanced only through
    /// [`Persistence::set_epoch`], which fsyncs the manifest *before*
    /// publishing the new value here.
    epoch: AtomicU64,
    /// Shipper tail-scan memo, one per shard (see [`TailOffsetCache`]).
    tail_offsets: Vec<Mutex<TailOffsetCache>>,
    /// WAL sequence anchoring (see [`SeqView`]).
    seq: Mutex<SeqView>,
    /// Arc-shared with the group-commit thread (it flushes through the
    /// same mutexes the store appends under).
    wals: Arc<Vec<Mutex<WalWriter>>>,
    /// The group-commit thread; `None` when `commit_window_us == 0`
    /// (synchronous per-batch commits).
    group: Option<GroupCommitter>,
    /// Shared with `coordinator::Metrics`; also the single home of the
    /// live generation (`counters.generation`), so the stats field and the
    /// snapshot/WAL file addressing can never disagree.
    counters: std::sync::Arc<PersistCounters>,
}

/// Validate the retained previous-generation WAL segments against the
/// anchoring the manifest *recorded* for them: every file must exist,
/// parse cleanly, and hold exactly `live base − prev base` frames.
/// Recording (not re-deriving) the anchoring is load-bearing: a retained
/// file that silently lost an unsynced tail to a power loss would
/// otherwise shift every frame's inferred sequence and ship mislabelled
/// history. Best-effort — retention is a follower-catch-up convenience,
/// so any mismatch just disables it (`None`) rather than failing
/// recovery.
fn validate_retained_segment(
    dir: &Path,
    recorded: Option<(u64, Vec<u64>)>,
    base_seqs: &[u64],
    words_per_row: usize,
) -> Option<(u64, Vec<u64>)> {
    let (prev_gen, prev_bases) = recorded?;
    for (si, (&base, &prev_base)) in base_seqs.iter().zip(&prev_bases).enumerate() {
        let replay = read_wal(&wal_path(dir, prev_gen, si), words_per_row).ok()?;
        let expected = base.checked_sub(prev_base)?;
        if replay.truncated || replay.records.len() as u64 != expected {
            return None; // damaged retention: never ship questionable frames
        }
    }
    Some((prev_gen, prev_bases))
}

impl Persistence {
    /// Recover `cfg.data_dir` (initialising it on first use) and open the
    /// per-shard WAL writers for append. Returns the handle, the
    /// recovered shard states for the store to adopt, and the recovery
    /// report.
    pub fn open(
        cfg: &PersistConfig,
        fingerprint: Fingerprint,
        counters: std::sync::Arc<PersistCounters>,
    ) -> Result<(Persistence, Vec<ShardState>, RecoveryReport)> {
        anyhow::ensure!(
            cfg.enabled(),
            "Persistence::open requires mode != off and a data_dir"
        );
        let dir = cfg.data_dir.clone().expect("enabled() implies data_dir");
        let sw = crate::util::timer::Stopwatch::start();
        let (states, mut report) = recovery::recover(&dir, &fingerprint)?;
        report.recovery_ms = (sw.elapsed_secs() * 1e3).round() as u64;
        let wals: Arc<Vec<Mutex<WalWriter>>> = Arc::new(
            (0..fingerprint.num_shards)
                .map(|si| {
                    WalWriter::open_append(
                        &wal_path(&dir, report.generation, si),
                        cfg.fsync,
                        report.wal_frames.get(si).copied().unwrap_or(0),
                    )
                    .map(Mutex::new)
                    .with_context(|| format!("opening WAL for shard {si}"))
                })
                .collect::<Result<Vec<_>>>()?,
        );
        counters.recovery_ms.store(report.recovery_ms, Ordering::Relaxed);
        counters.generation.store(report.generation, Ordering::Relaxed);
        let seq = SeqView {
            generation: report.generation,
            base_seqs: report.base_seqs.clone(),
            prev: validate_retained_segment(
                &dir,
                report.retained_prev.clone(),
                &report.base_seqs,
                fingerprint.sketch_dim.div_ceil(64),
            ),
        };
        // The committer only exists where it has something to amortise:
        // an fdatasync per commit. Under `fsync = never` a commit is a
        // buffered write, so holding acks for a window would be pure
        // added latency — those stores keep the synchronous per-batch
        // path regardless of the window setting.
        let group = (cfg.commit_window_us > 0 && cfg.fsync == FsyncPolicy::Always).then(|| {
            GroupCommitter::start(
                fingerprint.num_shards,
                Duration::from_micros(cfg.commit_window_us),
                wals.clone(),
                counters.clone(),
            )
        });
        let p = Persistence {
            dir,
            mode: cfg.mode,
            fsync: cfg.fsync,
            snapshot_every: cfg.snapshot_every,
            wal_max_bytes: cfg.wal_max_bytes,
            bytes_floor: AtomicU64::new(cfg.wal_max_bytes),
            fingerprint,
            compact_dead_frames: cfg.compact_dead_frames,
            // the dead-frame basis restarts at 0 on reopen — replay cost
            // across restarts stays bounded by the record-count seeding
            // below either way
            dead_since_snapshot: AtomicU64::new(0),
            epoch: AtomicU64::new(report.epoch),
            tail_offsets: (0..fingerprint.num_shards)
                .map(|_| Mutex::new(TailOffsetCache::default()))
                .collect(),
            // a restart with a fat WAL tail counts it toward the next
            // auto-snapshot, so replay cost cannot grow without bound
            // across repeated crashes
            records_since_snapshot: AtomicU64::new(report.replayed_records as u64),
            seq: Mutex::new(seq),
            wals,
            group,
            counters,
        };
        Ok((p, states, report))
    }

    /// Whether insert commits go through the group-commit thread (a
    /// commit window is configured) rather than synchronously on the
    /// insert path.
    pub fn group_commit_enabled(&self) -> bool {
        self.group.is_some()
    }

    /// Register `shard`'s pending WAL frames in the open commit window,
    /// returning the window epoch to pass to
    /// [`Persistence::group_commit_wait_epoch`]. The register/wait split
    /// exists for the batcher's ack-wait pipelining: the batcher thread
    /// registers batch N and hands the wait to a completion thread, so it
    /// can sketch batch N+1 while N's fsync window is in flight.
    ///
    /// Correctness of the ticket: the dirty flag and the epoch read
    /// happen under one lock acquisition, and the committer closes a
    /// window (increments `open_epoch`) under the same lock *before*
    /// flushing — so frames appended before this call are always covered
    /// by the flush of the returned epoch (or an earlier one; a WAL
    /// commit is idempotent over already-written frames).
    pub fn group_commit_register(&self, shard: usize) -> u64 {
        let gc = self
            .group
            .as_ref()
            .expect("group_commit_register requires an enabled group committer");
        let mut g = gc.shared.lock();
        g.dirty[shard] = true;
        g.pending_batches += 1;
        gc.shared.work.notify_all();
        g.open_epoch
    }

    /// Block until window `epoch`'s flush lands; `Err` carries this
    /// shard's flush failure (a sibling shard's failure in the same
    /// window does not veto). The caller must NOT hold the shard's WAL
    /// mutex (the committer needs it to flush).
    pub fn group_commit_wait_epoch(
        &self,
        shard: usize,
        epoch: u64,
    ) -> std::result::Result<(), String> {
        let gc = self
            .group
            .as_ref()
            .expect("group_commit_wait_epoch requires an enabled group committer");
        let mut g = gc.shared.lock();
        loop {
            if g.completed >= epoch {
                // fail only if THIS shard's flush failed in the window —
                // a sibling shard's failure must not veto a durable ack
                let mine = g
                    .failures
                    .iter()
                    .find(|(e, _)| *e == epoch)
                    .and_then(|(_, shards)| shards.iter().find(|(si, _)| *si == shard))
                    .map(|(_, msg)| msg.clone());
                return match mine {
                    Some(msg) => Err(msg),
                    None => Ok(()),
                };
            }
            if g.stop {
                return Err("persistence shut down before the commit window flushed".into());
            }
            g = gc.shared.done.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Register-and-wait convenience: the synchronous (non-pipelined)
    /// group-commit ack path.
    pub fn group_commit_wait(&self, shard: usize) -> std::result::Result<(), String> {
        let epoch = self.group_commit_register(shard);
        self.group_commit_wait_epoch(shard, epoch)
    }

    pub fn data_dir(&self) -> &Path {
        &self.dir
    }

    pub fn mode(&self) -> PersistMode {
        self.mode
    }

    /// Live snapshot generation.
    pub fn generation(&self) -> u64 {
        self.counters.generation.load(Ordering::Relaxed)
    }

    /// Current failover epoch (write-authority term). Starts at 1 on a
    /// fresh dir; see [`Persistence::set_epoch`] for how it advances.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Durably advance the failover epoch: rewrite the manifest (same
    /// generation/bases) carrying `epoch`, fsync it, and only then
    /// publish the value in memory — so an ack gated on the new epoch
    /// can never be issued under a term a crash would roll back.
    /// `promote` calls this with `primary_epoch + 1` *before* flipping
    /// the replica writable; a fenced old primary calls it with the
    /// higher epoch it just observed, so the fence survives a restart.
    /// Strictly monotonic: a stale or equal epoch is refused.
    ///
    /// The seq lock is held across the save, which serialises this
    /// against [`Persistence::write_snapshot`]'s manifest save (also
    /// under the seq lock) — two manifest writers interleaving could
    /// otherwise publish a regressed generation or epoch.
    pub fn set_epoch(&self, epoch: u64) -> Result<()> {
        let s = lock_recover(&self.seq);
        let current = self.epoch.load(Ordering::Relaxed);
        anyhow::ensure!(
            epoch > current,
            "failover epoch must advance: requested {epoch}, already at {current}"
        );
        Manifest {
            generation: s.generation,
            fingerprint: self.fingerprint,
            epoch,
            base_seqs: s.base_seqs.clone(),
            prev: s.prev.clone(),
        }
        .save(&self.dir)?;
        self.epoch.store(epoch, Ordering::Relaxed);
        Ok(())
    }

    /// The configuration fingerprint this data dir is anchored to.
    pub fn fingerprint(&self) -> &Fingerprint {
        &self.fingerprint
    }

    /// Words per sketch row — the WAL frame-payload shape.
    pub fn words_per_row(&self) -> usize {
        self.fingerprint.sketch_dim.div_ceil(64)
    }

    pub fn num_shards(&self) -> usize {
        self.wals.len()
    }

    /// One consistent view of `(generation, base_seqs, retained prev)` —
    /// rotation swaps all three under the same lock, so the replication
    /// shipper can address segment files race-free (it re-checks
    /// [`Persistence::generation`] after reading a file and retries on a
    /// rotation that slid under it).
    pub fn seq_view(&self) -> SeqView {
        lock_recover(&self.seq).clone()
    }

    /// Durable sequence horizon of `shard`: the sequence the next frame
    /// *landed in the file* will get. Frames still pending in the writer
    /// are excluded — replication only ever ships landed frames, so a
    /// follower can never get ahead of the primary's crash-surviving
    /// state.
    pub fn committed_seq(&self, shard: usize) -> u64 {
        loop {
            let (generation, base) = {
                let s = lock_recover(&self.seq);
                (s.generation, s.base_seqs[shard])
            };
            let frames = lock_recover(&self.wals[shard]).file_frames();
            // re-read under the seq lock: an interleaved rotation would
            // pair the old base with the new (reset) frame count
            if lock_recover(&self.seq).generation == generation {
                return base + frames;
            }
        }
    }

    /// Crash-surviving sequence horizon of `shard` under the configured
    /// fsync policy — the horizon replication ships against. With
    /// `fsync = always` only fdatasync-covered frames count (frames
    /// write_all'd but not yet synced could be revoked by a power loss,
    /// and a follower holding revoked frames would read as diverged
    /// after the primary restarts); with `fsync = never` the policy's
    /// own contract is kill -9 survival, for which landed-in-file is the
    /// horizon.
    pub fn durable_seq(&self, shard: usize) -> u64 {
        loop {
            let (generation, base) = {
                let s = lock_recover(&self.seq);
                (s.generation, s.base_seqs[shard])
            };
            let frames = lock_recover(&self.wals[shard]).durable_frames();
            if lock_recover(&self.seq).generation == generation {
                return base + frames;
            }
        }
    }

    /// Applied sequence horizon of `shard` *including* writer-pending
    /// frames — the follower's catch-up cursor (a chunk whose commit
    /// failed is applied in memory and retried by the next commit, so it
    /// must not be re-requested and double-applied).
    pub fn next_seq(&self, shard: usize) -> u64 {
        loop {
            let (generation, base) = {
                let s = lock_recover(&self.seq);
                (s.generation, s.base_seqs[shard])
            };
            let frames = {
                let w = lock_recover(&self.wals[shard]);
                w.file_frames() + w.pending_frames()
            };
            if lock_recover(&self.seq).generation == generation {
                return base + frames;
            }
        }
    }

    /// Total on-disk size of the live WAL segments — the
    /// `persist_wal_live_bytes` stats gauge and the `--wal-max-bytes`
    /// size-trigger input (one number for both, by design).
    pub fn wal_live_bytes(&self) -> u64 {
        self.wals.iter().map(|w| lock_recover(w).file_len()).sum()
    }

    /// Lock shard `i`'s WAL writer. The store takes this while holding the
    /// shard's write lock (the WAL mutex is a strict leaf in the lock
    /// order: id index → shard locks ascending → WAL mutexes ascending).
    pub fn wal_guard(&self, shard: usize) -> MutexGuard<'_, WalWriter> {
        lock_recover(&self.wals[shard])
    }

    /// Account a committed append batch (records + frame bytes) toward the
    /// traffic counters and the auto-snapshot trigger.
    pub fn note_appended(&self, records: u64, bytes: u64) {
        self.counters.wal_records.fetch_add(records, Ordering::Relaxed);
        self.counters.wal_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.records_since_snapshot
            .fetch_add(records, Ordering::Relaxed);
    }

    /// Account frames that just became dead in the live segments (a
    /// `Delete` deadens 2, an in-place `Upsert` deadens 1) toward the
    /// `persist_wal_dead_frames` gauge and the compaction trigger.
    pub fn note_dead_frames(&self, frames: u64) {
        self.counters.wal_dead_frames.fetch_add(frames, Ordering::Relaxed);
        self.dead_since_snapshot.fetch_add(frames, Ordering::Relaxed);
    }

    /// The shipper's tail-scan resume hint for `shard`, valid only for
    /// `generation` — `(frame index, byte offset)` of the furthest
    /// boundary scanned, or `None` when the memo is cold or from another
    /// generation.
    pub fn tail_hint(&self, shard: usize, generation: u64) -> Option<(u64, u64)> {
        let c = lock_recover(&self.tail_offsets[shard]);
        (c.generation == generation && c.frame > 0).then_some((c.frame, c.offset))
    }

    /// Record the boundary a tail scan of `shard`'s generation-
    /// `generation` segment ended at. Overwrites a stale-generation memo;
    /// within a generation it only advances (slower followers must not
    /// drag the memo backwards under faster ones).
    pub fn note_tail_offset(&self, shard: usize, generation: u64, frame: u64, offset: u64) {
        let mut c = lock_recover(&self.tail_offsets[shard]);
        if c.generation != generation || frame > c.frame {
            *c = TailOffsetCache { generation, frame, offset };
        }
    }

    /// Whether an auto-snapshot threshold has been crossed — the record
    /// count (`snapshot_every`), the live-segment size (`wal_max_bytes`),
    /// or the dead-frame count (`compact_dead_frames`); any of the three
    /// can fire independently. Read-only probe; the store's trigger path
    /// uses [`Persistence::try_claim_auto_snapshot`].
    pub fn should_auto_snapshot(&self) -> bool {
        if self.mode != PersistMode::WalSnapshot {
            return false;
        }
        if self.snapshot_every > 0
            && self.records_since_snapshot.load(Ordering::Relaxed) >= self.snapshot_every
        {
            return true;
        }
        if self.compact_dead_frames > 0
            && self.dead_since_snapshot.load(Ordering::Relaxed) >= self.compact_dead_frames
        {
            return true;
        }
        self.wal_max_bytes > 0
            && self.wal_live_bytes() >= self.bytes_floor.load(Ordering::Relaxed)
    }

    /// Atomically claim the auto-snapshot trigger: returns `true` for
    /// exactly one caller per threshold crossing, resetting that
    /// trigger's basis in the same step (the record counter to 0, or the
    /// byte floor a full interval above the observed size). Two
    /// consequences: concurrent inserters cannot both run a
    /// (stop-the-world, full-corpus) rotation for the same crossing, and
    /// a *failed* rotation is naturally deferred for a full further
    /// interval — the store degrades to WAL-only instead of re-attempting
    /// on every batch (disk-full being the classic way a rotation starts
    /// failing persistently). A *successful* rotation resets both bases
    /// outright.
    pub fn try_claim_auto_snapshot(&self) -> bool {
        if self.mode != PersistMode::WalSnapshot {
            return false;
        }
        if self.snapshot_every > 0
            && self
                .records_since_snapshot
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    (v >= self.snapshot_every).then_some(0)
                })
                .is_ok()
        {
            return true;
        }
        if self.compact_dead_frames > 0
            && self
                .dead_since_snapshot
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    (v >= self.compact_dead_frames).then_some(0)
                })
                .is_ok()
        {
            return true;
        }
        if self.wal_max_bytes > 0 {
            let live = self.wal_live_bytes();
            return self
                .bytes_floor
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |floor| {
                    (live >= floor).then_some(live + self.wal_max_bytes)
                })
                .is_ok();
        }
        false
    }

    /// Flush + fsync every shard WAL (regardless of fsync policy) — the
    /// `flush` wire op and graceful shutdown.
    pub fn flush_all(&self) -> Result<()> {
        crate::fault::check_io("fsync").context("flushing WALs")?;
        for (si, wal) in self.wals.iter().enumerate() {
            lock_recover(wal)
                .sync()
                .with_context(|| format!("fsync WAL for shard {si}"))?;
        }
        Ok(())
    }

    /// Rotate to a new snapshot generation. The caller
    /// ([`crate::coordinator::store::ShardedStore::persist_snapshot`])
    /// holds the id-index read lock, every shard read lock, and passes in
    /// every WAL guard — so no record can be appended anywhere during the
    /// rotation and the snapshot cut is exact.
    ///
    /// Crash-safety ordering: durable snapshots → empty next-generation
    /// WAL files → manifest rename (the commit point) → seq/writer swap →
    /// GC. The old generation's WAL segments are *retained* (not GC'd)
    /// for one generation so a follower that lagged across this rotation
    /// can still be shipped the frames the new snapshot absorbed; the
    /// two-generations-old segments expire instead.
    ///
    /// Rotation doubles as WAL compaction: the fresh segments start
    /// empty, so every dead frame (delete-shadowed or upsert-shadowed) in
    /// the old generation is dropped from the live log in one move — the
    /// snapshot holds only the survivors.
    pub fn write_snapshot(
        &self,
        shards: &[(&[usize], &[u64], &SketchMatrix)],
        wal_guards: &mut [MutexGuard<'_, WalWriter>],
    ) -> Result<u64> {
        assert_eq!(shards.len(), self.wals.len());
        assert_eq!(wal_guards.len(), self.wals.len());
        crate::fault::check_io("snapshot_rotate").context("rotating snapshot")?;
        let old = self.generation();
        let new = old + 1;
        for (si, (ids, expiry, rows)) in shards.iter().enumerate() {
            snapshot::write_shard(
                &snap_path(&self.dir, new, si),
                self.fingerprint.sketch_dim,
                si,
                ids,
                expiry,
                rows,
            )
            .with_context(|| format!("snapshotting shard {si} at generation {new}"))?;
        }
        let mut fresh = Vec::with_capacity(self.wals.len());
        for (si, guard) in wal_guards.iter_mut().enumerate() {
            // flush the old segment so the pre-commit state stays whole if
            // the manifest write below fails and we keep appending to it
            guard.commit()?;
            fresh.push(WalWriter::create(&wal_path(&self.dir, new, si), self.fsync)?);
        }
        sync_dir(&self.dir);
        // The new bases absorb every frame the cut captured. The caller
        // holds every shard lock and every WAL guard, so no frame can
        // land anywhere between the `commit()` above and this read.
        // The manifest save and the seq publish happen under one seq-lock
        // hold: the shipper can never see `new` paired with the old
        // bases, and [`Persistence::set_epoch`] (the other manifest
        // writer, same lock) can never interleave its save with this one
        // and leave a regressed generation or epoch on disk.
        {
            let mut s = lock_recover(&self.seq);
            let old_bases = s.base_seqs.clone();
            let new_bases: Vec<u64> = old_bases
                .iter()
                .zip(wal_guards.iter())
                .map(|(base, guard)| base + guard.file_frames())
                .collect();
            Manifest {
                generation: new,
                fingerprint: self.fingerprint,
                epoch: self.epoch.load(Ordering::Relaxed),
                base_seqs: new_bases.clone(),
                prev: Some((old, old_bases.clone())),
            }
            .save(&self.dir)?;
            // Commit point passed: publish the new seq anchoring, then
            // (below) swap the live writers (retiring the old ones so
            // their Drop skips a pointless fsync of a now-frozen retained
            // segment) and GC (best-effort — leftovers are swept by the
            // next recovery).
            s.prev = Some((old, old_bases));
            s.base_seqs = new_bases;
            s.generation = new;
        }
        for (guard, writer) in wal_guards.iter_mut().zip(fresh) {
            guard.retire();
            **guard = writer;
        }
        self.records_since_snapshot.store(0, Ordering::Relaxed);
        self.bytes_floor.store(self.wal_max_bytes, Ordering::Relaxed);
        self.dead_since_snapshot.store(0, Ordering::Relaxed);
        if self.counters.wal_dead_frames.swap(0, Ordering::Relaxed) > 0 {
            self.counters.compactions.fetch_add(1, Ordering::Relaxed);
        }
        self.counters.snapshots.fetch_add(1, Ordering::Relaxed);
        self.counters.generation.store(new, Ordering::Relaxed);
        for si in 0..self.wals.len() {
            // wal(old) is follower-catch-up retention; wal(old-1) expires
            if old > 0 {
                let _ = std::fs::remove_file(wal_path(&self.dir, old - 1, si));
                let _ = std::fs::remove_file(snap_path(&self.dir, old, si));
            }
        }
        Ok(new)
    }
}

impl Drop for Persistence {
    fn drop(&mut self) {
        // drain + join the group-commit thread first (it flushes any open
        // window and completes its waiters), then the belt-and-braces
        // graceful-teardown fsync; hard kills are covered by the
        // commit-per-window protocol
        self.group = None;
        let _ = self.flush_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;
    use std::sync::Arc;

    fn cfg(dir: &TempDir, mode: PersistMode) -> PersistConfig {
        PersistConfig {
            mode,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Never,
            snapshot_every: 4,
            commit_window_us: 0, // group-commit tests opt in explicitly
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        }
    }

    fn fp() -> Fingerprint {
        Fingerprint {
            sketch_dim: 64,
            seed: 7,
            num_shards: 2,
            input_dim: 4096,
            num_categories: 16,
        }
    }

    #[test]
    fn open_initialises_and_reopens() {
        let dir = TempDir::new("persist-open");
        let counters = Arc::new(PersistCounters::default());
        let (p, states, report) =
            Persistence::open(&cfg(&dir, PersistMode::Wal), fp(), counters.clone()).unwrap();
        assert_eq!(states.len(), 2);
        assert_eq!(report.generation, 0);
        assert_eq!(p.generation(), 0);
        // append through the guards, then reopen and observe the records
        {
            let mut w = p.wal_guard(0);
            w.append_insert(0, &[0b1011]);
            w.commit().unwrap();
        }
        p.note_appended(1, 37);
        assert_eq!(counters.wal_records.load(Ordering::Relaxed), 1);
        assert_eq!(counters.wal_bytes.load(Ordering::Relaxed), 37);
        drop(p);
        let counters2 = Arc::new(PersistCounters::default());
        let (_, states, report) =
            Persistence::open(&cfg(&dir, PersistMode::Wal), fp(), counters2).unwrap();
        assert_eq!(report.replayed_records, 1);
        assert_eq!(states[0].ids, vec![0]);
        assert_eq!(states[0].rows.weight(0), 3);
    }

    #[test]
    fn open_rejects_disabled_config() {
        let err = Persistence::open(
            &PersistConfig::default(),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("data_dir"), "{err:#}");
    }

    #[test]
    fn auto_snapshot_trigger_counts_records() {
        let dir = TempDir::new("persist-trigger");
        let counters = Arc::new(PersistCounters::default());
        let (p, _, _) =
            Persistence::open(&cfg(&dir, PersistMode::WalSnapshot), fp(), counters).unwrap();
        assert!(!p.should_auto_snapshot());
        p.note_appended(3, 100);
        assert!(!p.should_auto_snapshot());
        assert!(!p.try_claim_auto_snapshot(), "below-threshold claim must not reset");
        assert!(!p.should_auto_snapshot());
        p.note_appended(1, 40);
        assert!(p.should_auto_snapshot());
        // the claim is exclusive per crossing and resets the counter
        assert!(p.try_claim_auto_snapshot());
        assert!(!p.try_claim_auto_snapshot());
        assert!(!p.should_auto_snapshot());
        p.note_appended(4, 160);
        assert!(p.should_auto_snapshot());
        // Wal-only mode never auto-snapshots
        let dir2 = TempDir::new("persist-trigger-wal");
        let (p2, _, _) = Persistence::open(
            &cfg(&dir2, PersistMode::Wal),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        p2.note_appended(100, 1000);
        assert!(!p2.should_auto_snapshot());
    }

    #[test]
    fn sequence_numbers_advance_and_survive_reopen() {
        let dir = TempDir::new("persist-seq");
        let (p, _, _) = Persistence::open(
            &cfg(&dir, PersistMode::Wal),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        assert_eq!(p.committed_seq(0), 0);
        assert_eq!(p.next_seq(0), 0);
        {
            let mut w = p.wal_guard(0);
            w.append_insert(0, &[0b1]);
            w.append_insert(1, &[0b10]);
        }
        // appended-but-uncommitted frames count toward next_seq only
        assert_eq!(p.committed_seq(0), 0);
        assert_eq!(p.next_seq(0), 2);
        p.wal_guard(0).commit().unwrap();
        assert_eq!(p.committed_seq(0), 2);
        assert_eq!(p.next_seq(0), 2);
        assert_eq!(p.committed_seq(1), 0, "shard 1 untouched");
        let view = p.seq_view();
        assert_eq!(view.generation, 0);
        assert_eq!(view.base_seqs, vec![0, 0]);
        assert!(view.prev.is_none());
        drop(p);
        let (p, _, _) = Persistence::open(
            &cfg(&dir, PersistMode::Wal),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        assert_eq!(p.committed_seq(0), 2, "seqs must survive a restart");
    }

    #[test]
    fn rotation_advances_bases_and_retains_one_generation() {
        let dir = TempDir::new("persist-rotate-seq");
        let (p, _, _) = Persistence::open(
            &cfg(&dir, PersistMode::WalSnapshot),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        {
            let mut w = p.wal_guard(0);
            w.append_insert(0, &[0b1]);
            w.append_insert(1, &[0b11]);
            w.commit().unwrap();
        }
        {
            let mut w = p.wal_guard(1);
            w.append_insert(2, &[0b111]);
            w.commit().unwrap();
        }
        let rotate = |p: &Persistence| {
            let empty = SketchMatrix::new(64);
            let views: Vec<(&[usize], &[u64], &SketchMatrix)> =
                vec![(&[], &[], &empty), (&[], &[], &empty)];
            let mut guards: Vec<_> = (0..2).map(|si| p.wal_guard(si)).collect();
            p.write_snapshot(&views, &mut guards).unwrap()
        };
        assert_eq!(rotate(&p), 1);
        let view = p.seq_view();
        assert_eq!(view.generation, 1);
        assert_eq!(view.base_seqs, vec![2, 1], "bases absorb the cut frames");
        assert_eq!(view.prev, Some((0, vec![0, 0])));
        // seqs continue across the rotation (fresh segment, same line)
        assert_eq!(p.committed_seq(0), 2);
        {
            let mut w = p.wal_guard(0);
            w.append_insert(3, &[0b1]);
            w.commit().unwrap();
        }
        assert_eq!(p.committed_seq(0), 3);
        // generation-0 segments are retained for follower catch-up …
        assert!(wal_path(dir.path(), 0, 0).exists());
        assert!(wal_path(dir.path(), 0, 1).exists());
        // … and a reopen re-anchors them
        drop(p);
        let (p, _, _) = Persistence::open(
            &cfg(&dir, PersistMode::WalSnapshot),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        assert_eq!(p.seq_view().prev, Some((0, vec![0, 0])));
        assert_eq!(p.committed_seq(0), 3);
        // a second rotation expires generation 0 and retains generation 1
        assert_eq!(rotate(&p), 2);
        assert!(!wal_path(dir.path(), 0, 0).exists(), "gen-0 wal must expire");
        assert!(wal_path(dir.path(), 1, 0).exists(), "gen-1 wal retained");
        assert_eq!(p.seq_view().prev, Some((1, vec![2, 1])));
    }

    #[test]
    fn epoch_is_durable_monotonic_and_survives_rotation() {
        let dir = TempDir::new("persist-epoch");
        let open = || {
            Persistence::open(
                &cfg(&dir, PersistMode::WalSnapshot),
                fp(),
                Arc::new(PersistCounters::default()),
            )
        };
        let (p, _, report) = open().unwrap();
        assert_eq!(report.epoch, 1, "a fresh dir is its own authority: epoch 1");
        assert_eq!(p.epoch(), 1);
        p.set_epoch(3).unwrap();
        assert_eq!(p.epoch(), 3);
        // strictly monotonic: stale and equal terms are refused
        let err = p.set_epoch(3).unwrap_err();
        assert!(err.to_string().contains("must advance"), "{err:#}");
        assert!(p.set_epoch(2).is_err());
        assert_eq!(p.epoch(), 3);
        // rotation re-writes the manifest carrying the current epoch
        let empty = SketchMatrix::new(64);
        let views: Vec<(&[usize], &[u64], &SketchMatrix)> =
            vec![(&[], &[], &empty), (&[], &[], &empty)];
        let mut guards: Vec<_> = (0..2).map(|si| p.wal_guard(si)).collect();
        p.write_snapshot(&views, &mut guards).unwrap();
        drop(guards);
        drop(p);
        let (p, _, report) = open().unwrap();
        assert_eq!(report.epoch, 3, "epoch must survive rotation + restart");
        assert_eq!(p.epoch(), 3);
    }

    #[test]
    fn wal_max_bytes_triggers_and_defers_like_the_record_trigger() {
        let dir = TempDir::new("persist-bytes-trigger");
        let config = PersistConfig {
            snapshot_every: 0, // isolate the size trigger
            wal_max_bytes: 64,
            ..cfg(&dir, PersistMode::WalSnapshot)
        };
        let (p, _, _) =
            Persistence::open(&config, fp(), Arc::new(PersistCounters::default())).unwrap();
        assert!(!p.should_auto_snapshot());
        assert!(!p.try_claim_auto_snapshot(), "below the floor: no claim");
        {
            let mut w = p.wal_guard(0);
            for id in 0..4u64 {
                w.append_insert(id, &[id]);
            }
            w.commit().unwrap(); // 4 × 29-byte frames = 116 live bytes
        }
        assert!(p.wal_live_bytes() >= 64);
        assert!(p.should_auto_snapshot());
        // the claim is exclusive and raises the floor by a full interval
        assert!(p.try_claim_auto_snapshot());
        assert!(!p.try_claim_auto_snapshot());
        assert!(!p.should_auto_snapshot());
        // as if the rotation failed: only another interval of growth
        // re-arms the trigger
        {
            let mut w = p.wal_guard(0);
            for id in 4..7u64 {
                w.append_insert(id, &[id]);
            }
            w.commit().unwrap();
        }
        assert!(p.should_auto_snapshot());
        assert!(p.try_claim_auto_snapshot());
        // a successful rotation resets the floor with the fresh segments
        let empty = SketchMatrix::new(64);
        let views: Vec<(&[usize], &[u64], &SketchMatrix)> =
            vec![(&[], &[], &empty), (&[], &[], &empty)];
        let mut guards: Vec<_> = (0..2).map(|si| p.wal_guard(si)).collect();
        p.write_snapshot(&views, &mut guards).unwrap();
        drop(guards);
        assert_eq!(p.wal_live_bytes(), 0);
        assert!(!p.should_auto_snapshot());
    }

    #[test]
    fn dead_frame_trigger_claims_and_rotation_counts_a_compaction() {
        let dir = TempDir::new("persist-dead-trigger");
        let config = PersistConfig {
            snapshot_every: 0, // isolate the compaction trigger
            compact_dead_frames: 3,
            ..cfg(&dir, PersistMode::WalSnapshot)
        };
        let counters = Arc::new(PersistCounters::default());
        let (p, _, _) = Persistence::open(&config, fp(), counters.clone()).unwrap();
        assert!(!p.should_auto_snapshot());
        p.note_dead_frames(2); // one delete
        assert!(!p.should_auto_snapshot());
        assert!(!p.try_claim_auto_snapshot());
        p.note_dead_frames(1); // one in-place upsert
        assert_eq!(counters.wal_dead_frames.load(Ordering::Relaxed), 3);
        assert!(p.should_auto_snapshot());
        // exclusive claim, reset-on-claim, gauge untouched by the claim
        assert!(p.try_claim_auto_snapshot());
        assert!(!p.try_claim_auto_snapshot());
        assert!(!p.should_auto_snapshot());
        assert_eq!(counters.wal_dead_frames.load(Ordering::Relaxed), 3);
        // rotation resets the gauge and counts a compaction
        let empty = SketchMatrix::new(64);
        let views: Vec<(&[usize], &[u64], &SketchMatrix)> =
            vec![(&[], &[], &empty), (&[], &[], &empty)];
        let mut guards: Vec<_> = (0..2).map(|si| p.wal_guard(si)).collect();
        p.write_snapshot(&views, &mut guards).unwrap();
        drop(guards);
        assert_eq!(counters.wal_dead_frames.load(Ordering::Relaxed), 0);
        assert_eq!(counters.compactions.load(Ordering::Relaxed), 1);
        // a rotation with no dead frames is not a compaction
        let mut guards: Vec<_> = (0..2).map(|si| p.wal_guard(si)).collect();
        p.write_snapshot(&views, &mut guards).unwrap();
        drop(guards);
        assert_eq!(counters.compactions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tail_offset_memo_is_generation_keyed_and_monotonic() {
        let dir = TempDir::new("persist-tail-memo");
        let (p, _, _) = Persistence::open(
            &cfg(&dir, PersistMode::WalSnapshot),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        assert_eq!(p.tail_hint(0, 0), None, "cold memo serves no hint");
        p.note_tail_offset(0, 0, 4, 116);
        assert_eq!(p.tail_hint(0, 0), Some((4, 116)));
        assert_eq!(p.tail_hint(1, 0), None, "per-shard memo");
        assert_eq!(p.tail_hint(0, 1), None, "other generation: invalid");
        // a slower follower's shorter scan must not drag the memo back
        p.note_tail_offset(0, 0, 2, 58);
        assert_eq!(p.tail_hint(0, 0), Some((4, 116)));
        // a rotation's new generation overwrites regardless of frame
        p.note_tail_offset(0, 1, 1, 29);
        assert_eq!(p.tail_hint(0, 1), Some((1, 29)));
        assert_eq!(p.tail_hint(0, 0), None);
    }

    #[test]
    fn mode_and_fsync_strings_parse() {
        assert_eq!(PersistConfig::mode_from_str("off"), Some(PersistMode::Off));
        assert_eq!(PersistConfig::mode_from_str("wal"), Some(PersistMode::Wal));
        assert_eq!(
            PersistConfig::mode_from_str("wal+snapshot"),
            Some(PersistMode::WalSnapshot)
        );
        assert_eq!(PersistConfig::mode_from_str("sideways"), None);
        assert_eq!(
            PersistConfig::mode_from_str_or_warn("sideways", "test"),
            PersistMode::WalSnapshot
        );
        assert_eq!(
            PersistConfig::fsync_from_str_or_warn("never", "test"),
            FsyncPolicy::Never
        );
        assert_eq!(
            PersistConfig::fsync_from_str_or_warn("bogus", "test"),
            FsyncPolicy::Always
        );
    }

    #[test]
    fn stats_fields_use_cfg_prefix() {
        let fields = PersistConfig::default().stats_fields();
        assert!(fields.iter().all(|(n, _)| n.starts_with("persist_cfg_")));
        assert!(fields
            .iter()
            .any(|(n, v)| n == "persist_cfg_mode" && *v == 0.0));
        assert!(fields
            .iter()
            .any(|(n, v)| n == "persist_cfg_commit_window_us" && *v == 1000.0));
    }

    fn group_cfg(dir: &TempDir, window_us: u64) -> PersistConfig {
        PersistConfig {
            commit_window_us: window_us,
            // group commit only engages where there is an fsync to amortise
            fsync: FsyncPolicy::Always,
            ..cfg(dir, PersistMode::Wal)
        }
    }

    #[test]
    fn group_commit_flushes_registered_batches() {
        let dir = TempDir::new("persist-group");
        let counters = Arc::new(PersistCounters::default());
        let (p, _, _) = Persistence::open(&group_cfg(&dir, 500), fp(), counters.clone()).unwrap();
        assert!(p.group_commit_enabled());
        {
            let mut w = p.wal_guard(0);
            w.append_insert(0, &[0b111]);
        } // drop the guard BEFORE waiting — the committer needs it
        p.group_commit_wait(0).unwrap();
        assert!(counters.group_commits.load(Ordering::Relaxed) >= 1);
        drop(p);
        // the frames reached the file through the committer, not drop
        let replay = wal::read_wal(&wal_path(dir.path(), 0, 0), 1).unwrap();
        assert_eq!(replay.records.len(), 1);
        // window 0 ⇒ no committer; fsync=never likewise (nothing to amortise)
        let dir2 = TempDir::new("persist-group-off");
        let (p2, _, _) = Persistence::open(
            &group_cfg(&dir2, 0),
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        assert!(!p2.group_commit_enabled());
        let dir3 = TempDir::new("persist-group-never");
        let (p3, _, _) = Persistence::open(
            &PersistConfig {
                fsync: FsyncPolicy::Never,
                ..group_cfg(&dir3, 500)
            },
            fp(),
            Arc::new(PersistCounters::default()),
        )
        .unwrap();
        assert!(!p3.group_commit_enabled());
    }

    #[test]
    fn sibling_shard_failure_does_not_veto_a_clean_shards_ack() {
        // two batches in ONE window (long window, racing waiters): shard
        // 1's flush fails, shard 0's succeeds — only shard 1's waiter may
        // see the error
        let dir = TempDir::new("persist-group-sibling");
        let counters = Arc::new(PersistCounters::default());
        let (p, _, _) =
            Persistence::open(&group_cfg(&dir, 100_000), fp(), counters).unwrap();
        {
            let mut w0 = p.wal_guard(0);
            w0.append_insert(0, &[0b1]);
        }
        {
            let mut w1 = p.wal_guard(1);
            w1.append_insert(1, &[0b10]);
            w1.fail_next_commit("sibling fault");
        }
        std::thread::scope(|s| {
            let ok = s.spawn(|| p.group_commit_wait(0));
            let bad = s.spawn(|| p.group_commit_wait(1));
            let ok = ok.join().unwrap();
            let bad = bad.join().unwrap();
            assert!(ok.is_ok(), "clean shard vetoed by sibling: {ok:?}");
            let err = bad.unwrap_err();
            assert!(err.contains("sibling fault"), "{err}");
        });
    }

    #[test]
    fn group_commit_failure_reaches_the_waiter_and_later_windows_recover() {
        let dir = TempDir::new("persist-group-fail");
        let counters = Arc::new(PersistCounters::default());
        let (p, _, _) = Persistence::open(&group_cfg(&dir, 500), fp(), counters).unwrap();
        {
            let mut w = p.wal_guard(1);
            w.append_insert(3, &[0b1]);
            w.fail_next_commit("window fault");
        }
        let err = p.group_commit_wait(1).unwrap_err();
        assert!(err.contains("window fault"), "{err}");
        // the frames stayed pending; the next window retries and succeeds
        {
            let mut w = p.wal_guard(1);
            w.append_insert(4, &[0b10]);
        }
        p.group_commit_wait(1).unwrap();
        drop(p);
        let replay = wal::read_wal(&wal_path(dir.path(), 0, 1), 1).unwrap();
        assert_eq!(replay.records.len(), 2, "both records recovered");
    }
}
