//! Warm-restart recovery: newest valid snapshot + WAL tail replay.
//!
//! Per shard, recovery is `state = snapshot(generation) ⊕ replay(wal
//! segment of that generation)`: the snapshot (if the live generation is
//! > 0) seeds the arena, and the WAL records mutate it forward in the
//! exact order the live store mutated it — `Insert` (with or without a
//! TTL deadline) and `MoveIn` push a row, `MoveOut` pops the trailing
//! row, `Delete` swap-removes the row holding the named id, and `Upsert`
//! overwrites it in place — mirroring the exact mutation shapes
//! [`crate::coordinator::store::ShardedStore`] performs. Replay keeps a
//! per-shard id → row map (seeded from the snapshot's id column) so
//! `Delete`/`Upsert` can address rows the way the live store does through
//! its id index. Because every record was logged under its shard's write
//! lock, no cross-shard ordering is needed: replaying each shard
//! independently reproduces the pre-crash
//! `ids`/`rows`/weights/deadlines/shard-sizes state exactly. A
//! `Delete`/`Upsert` naming an id the shard does not hold is a hard
//! error: the live store only logs them in the shard that held the row,
//! so a miss means the log does not extend the snapshot next to it.
//!
//! Failure policy:
//! * missing manifest → fresh dir: initialise generation 0 and start empty;
//! * fingerprint mismatch → hard, descriptive error (see
//!   [`super::manifest::Fingerprint::check`]);
//! * missing or corrupt *snapshot* named by the manifest → hard error (the
//!   manifest is only advanced after its snapshot files are durable, so
//!   this means external damage, not a crash);
//! * torn *WAL tail* (the stop point is followed by no complete valid
//!   frame — the signature of a crash mid-append) → the partial final
//!   record is dropped and the file truncated to the valid prefix, never
//!   fatal;
//! * corrupt frame in the *middle* of a WAL (complete valid frames exist
//!   past the bad one — bit rot inside a committed region, not a tear) →
//!   hard error: truncating there would silently destroy acknowledged
//!   records that are still intact on disk.

use super::manifest::{snap_path, wal_path, Fingerprint, Manifest};
use super::snapshot::{self, ShardState};
use super::wal::{read_wal, WalRecord};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// What a recovery pass did — logged at startup and surfaced through the
/// `persist_*` stats counters.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Live snapshot generation after recovery.
    pub generation: u64,
    /// Failover epoch (write-authority term) recorded by the manifest
    /// (v5); a fresh dir initialises it to 1.
    pub epoch: u64,
    /// Rows loaded from snapshot files.
    pub snapshot_rows: usize,
    /// WAL records replayed on top of the snapshots.
    pub replayed_records: usize,
    /// WAL segments whose torn/corrupt tail was dropped and truncated.
    pub truncated_tails: usize,
    /// Rows dropped because their id was recovered in two shards — the
    /// signature of a crash between a rebalance move's destination
    /// (`MoveIn`) and source (`MoveOut`) commits. Copies are
    /// bit-identical, so exactly one survives.
    pub duplicate_rows_dropped: usize,
    /// Wall-clock of the recovery pass, in milliseconds.
    pub recovery_ms: u64,
    /// Per-shard WAL base sequence of the live generation (manifest v3):
    /// the sequence of its segment's first frame.
    pub base_seqs: Vec<u64>,
    /// Per-shard frame count of the live segment's valid prefix — the
    /// next frame landed in shard `i` gets sequence
    /// `base_seqs[i] + wal_frames[i]`.
    pub wal_frames: Vec<u64>,
    /// Retained previous segment's anchoring as recorded by the manifest
    /// (`prev_generation`/`prev_base_seqs`); the persistence layer
    /// validates the files against it before the shipper may serve them.
    pub retained_prev: Option<(u64, Vec<u64>)>,
    /// Highest rebalance move id seen across every shard's replayed
    /// `MoveOut`/`MoveIn` frames — the store resumes its move-id counter
    /// at `max_move_id + 1` so restarted primaries never reuse an id a
    /// follower may still be sequencing on.
    pub max_move_id: u64,
}

/// Recover every shard's state from `dir`, initialising the dir on first
/// use. `recovery_ms` is left at 0 — the caller owns the clock.
pub fn recover(dir: &Path, expect: &Fingerprint) -> Result<(Vec<ShardState>, RecoveryReport)> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create data dir {}", dir.display()))?;
    let manifest = match Manifest::load(dir)? {
        Some(m) => {
            m.fingerprint.check(expect)?;
            m
        }
        None => {
            let m = Manifest {
                generation: 0,
                fingerprint: *expect,
                // a fresh dir is its own write authority: epoch term 1
                epoch: 1,
                base_seqs: vec![0; expect.num_shards],
                prev: None,
            };
            m.save(dir)?;
            m
        }
    };
    let generation = manifest.generation;
    let words_per_row = expect.sketch_dim.div_ceil(64);
    let mut report = RecoveryReport {
        generation,
        epoch: manifest.epoch,
        base_seqs: manifest.base_seqs.clone(),
        retained_prev: manifest.prev.clone(),
        ..Default::default()
    };
    let mut shards = Vec::with_capacity(expect.num_shards);
    for si in 0..expect.num_shards {
        let mut state = if generation > 0 {
            snapshot::load_shard(&snap_path(dir, generation, si), expect.sketch_dim, si)
                .with_context(|| {
                    format!("loading generation-{generation} snapshot for shard {si}")
                })?
        } else {
            ShardState {
                ids: Vec::new(),
                expiry: Vec::new(),
                rows: crate::sketch::SketchMatrix::new(expect.sketch_dim),
            }
        };
        report.snapshot_rows += state.ids.len();
        let mut shard_frames = 0u64;
        let wal_file = wal_path(dir, generation, si);
        if wal_file.exists() {
            let replay = read_wal(&wal_file, words_per_row)
                .with_context(|| format!("reading WAL {}", wal_file.display()))?;
            // id → row, maintained through the replay exactly like the
            // live store's id index (swap-remove re-homes the trailing row)
            let mut at: std::collections::HashMap<usize, usize> = state
                .ids
                .iter()
                .enumerate()
                .map(|(row, &id)| (id, row))
                .collect();
            for rec in &replay.records {
                match rec {
                    WalRecord::Insert { id, deadline, words }
                    | WalRecord::MoveIn { id, deadline, words, .. } => {
                        if let WalRecord::MoveIn { move_id, .. } = rec {
                            report.max_move_id = report.max_move_id.max(*move_id);
                        }
                        let weight = crate::sketch::bitvec::popcount_words(words) as u32;
                        at.insert(*id as usize, state.rows.len());
                        state.rows.push_row(words, weight);
                        state.ids.push(*id as usize);
                        state.expiry.push(*deadline);
                    }
                    WalRecord::MoveOut { move_id } => {
                        report.max_move_id = report.max_move_id.max(*move_id);
                        match (state.ids.pop(), state.expiry.pop()) {
                            (Some(id), Some(_)) if state.rows.pop_row() => {
                                at.remove(&id);
                            }
                            _ => bail!(
                                "WAL {}: MoveOut on an empty shard — log does not \
                                 match the snapshot it extends",
                                wal_file.display()
                            ),
                        }
                    }
                    WalRecord::Delete { id } => {
                        let Some(pos) = at.remove(&(*id as usize)) else {
                            bail!(
                                "WAL {}: Delete of id {id} which the shard does not \
                                 hold — log does not match the snapshot it extends",
                                wal_file.display()
                            );
                        };
                        let last = state.ids.len() - 1;
                        if pos != last {
                            at.insert(state.ids[last], pos);
                        }
                        state.ids.swap_remove(pos);
                        state.expiry.swap_remove(pos);
                        state.rows.swap_remove_row(pos);
                    }
                    WalRecord::Upsert { id, deadline, words } => {
                        let Some(&pos) = at.get(&(*id as usize)) else {
                            bail!(
                                "WAL {}: Upsert of id {id} which the shard does not \
                                 hold — log does not match the snapshot it extends",
                                wal_file.display()
                            );
                        };
                        let weight = crate::sketch::bitvec::popcount_words(words) as u32;
                        state.rows.overwrite_row(pos, words, weight);
                        state.expiry[pos] = *deadline;
                    }
                }
            }
            report.replayed_records += replay.records.len();
            shard_frames = replay.records.len() as u64;
            if replay.valid_frames_beyond_tear {
                bail!(
                    "WAL {}: corrupt frame at byte {} with intact records after it — this \
                     is mid-file damage, not a crash tear; refusing to truncate away \
                     acknowledged records. Repair or remove the file to proceed",
                    wal_file.display(),
                    replay.valid_len
                );
            }
            if replay.truncated {
                // drop the torn tail so appends resume at a frame boundary
                std::fs::OpenOptions::new()
                    .write(true)
                    .open(&wal_file)
                    .and_then(|f| f.set_len(replay.valid_len))
                    .with_context(|| format!("truncating torn tail of {}", wal_file.display()))?;
                report.truncated_tails += 1;
            }
        } else if generation > 0 {
            // Rotation creates every wal-G segment durably *before* the
            // manifest names generation G, so at G > 0 the file must
            // exist; its absence is external damage, and treating it as
            // an empty log would silently drop every post-snapshot
            // record. (At generation 0 a missing segment is normal: the
            // writers are only created after first recovery.)
            bail!(
                "WAL segment {} is missing for live generation {generation} — refusing to \
                 treat it as empty; restore the file or remove the data dir to start fresh",
                wal_file.display()
            );
        }
        report.wal_frames.push(shard_frames);
        shards.push(state);
    }
    dedup_recovered_ids(&mut shards, expect.sketch_dim, &mut report);
    gc_stale_generations(dir, generation);
    Ok((shards, report))
}

/// Drop all-but-one copy of any id recovered in two places. A crash
/// between a rebalance move's destination commit (`MoveIn`, committed
/// first) and source commit (`MoveOut`) persists the row in both shards'
/// logs; the copies are bit-identical by construction, so the first
/// occurrence wins. Left in place, a duplicate would inflate
/// `snapshot_ordered`/`snapshot_matrix`/shard sizes forever (and be
/// re-serialized into every future snapshot generation).
fn dedup_recovered_ids(shards: &mut [ShardState], sketch_dim: usize, report: &mut RecoveryReport) {
    let mut seen = std::collections::HashSet::new();
    for state in shards.iter_mut() {
        let fresh: Vec<bool> = state.ids.iter().map(|id| seen.insert(*id)).collect();
        if fresh.iter().all(|&f| f) {
            continue;
        }
        let kept = fresh.iter().filter(|&&f| f).count();
        let mut ids = Vec::with_capacity(kept);
        let mut expiry = Vec::with_capacity(kept);
        let mut rows = crate::sketch::SketchMatrix::with_row_capacity(sketch_dim, kept);
        for (row, (&id, &keep)) in state.ids.iter().zip(&fresh).enumerate() {
            if keep {
                ids.push(id);
                expiry.push(state.expiry[row]);
                rows.push_row(state.rows.row(row), state.rows.weight(row) as u32);
            }
        }
        report.duplicate_rows_dropped += state.ids.len() - kept;
        *state = ShardState { ids, expiry, rows };
    }
}

/// Remove snapshot/WAL files of any generation other than the live one —
/// except the *previous* generation's WAL segments, which snapshot
/// rotation deliberately retains for one generation so a lagging
/// replication follower can still be served the frames the newest
/// snapshot already absorbed (see [`crate::replica`]). Rotation GCs its
/// own two-generations-old predecessor, but a crash between the manifest
/// commit and that GC loop would otherwise leak a full corpus image per
/// crash; recovery is the natural sweep point (no rotation can be in
/// flight). Future-generation orphans (crash after writing `snap-(G+1)`
/// but before the manifest commit) are swept too — recovery at `G` proves
/// they never became live. Best-effort: a leftover file is waste, not
/// corruption.
fn gc_stale_generations(dir: &Path, live: u64) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let is_wal = name.starts_with("wal-");
        let generation = name
            .strip_prefix("snap-")
            .or_else(|| name.strip_prefix("wal-"))
            .and_then(|rest| rest.split('-').next())
            .and_then(|g| g.parse::<u64>().ok());
        if let Some(g) = generation {
            let retained_for_followers = is_wal && live > 0 && g == live - 1;
            if g != live && !retained_for_followers {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::wal::WalWriter;
    use crate::persist::FsyncPolicy;
    use crate::sketch::{BitVec, SketchMatrix};
    use crate::testing::TempDir;
    use crate::util::rng::Xoshiro256;

    const DIM: usize = 128;

    fn fp(num_shards: usize) -> Fingerprint {
        Fingerprint {
            sketch_dim: DIM,
            seed: 11,
            num_shards,
            input_dim: 1000,
            num_categories: 12,
        }
    }

    fn sk(rng: &mut Xoshiro256) -> BitVec {
        BitVec::from_indices(DIM, rng.sample_indices(DIM, 20))
    }

    #[test]
    fn fresh_dir_initialises_generation_zero() {
        let dir = TempDir::new("recover-fresh");
        let (shards, report) = recover(dir.path(), &fp(3)).unwrap();
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.ids.is_empty()));
        assert_eq!(report.generation, 0);
        assert_eq!(report.epoch, 1, "a fresh dir starts at epoch 1");
        assert_eq!(report.replayed_records, 0);
        assert_eq!(report.base_seqs, vec![0, 0, 0]);
        assert_eq!(report.wal_frames, vec![0, 0, 0]);
        // manifest written: a second recovery agrees
        let (_, again) = recover(dir.path(), &fp(3)).unwrap();
        assert_eq!(again.generation, 0);
    }

    #[test]
    fn previous_generation_wal_is_retained_for_followers() {
        // live generation 2: wal-1 (previous) is follower-catch-up
        // retention and must survive the sweep; wal-0 and snap-1 must not
        let dir = TempDir::new("recover-retention");
        let f = fp(1);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(13);
        let m = SketchMatrix::from_sketches(&[sk(&mut rng)]);
        snapshot::write_shard(&snap_path(dir.path(), 2, 0), DIM, 0, &[0], &[0], &m).unwrap();
        Manifest {
            generation: 2,
            fingerprint: f,
            epoch: 1,
            base_seqs: vec![1],
            prev: None,
        }
        .save(dir.path())
        .unwrap();
        for g in [0u64, 1, 2] {
            drop(WalWriter::create(&wal_path(dir.path(), g, 0), FsyncPolicy::Never).unwrap());
        }
        snapshot::write_shard(&snap_path(dir.path(), 1, 0), DIM, 0, &[0], &[0], &m).unwrap();
        recover(dir.path(), &f).unwrap();
        assert!(wal_path(dir.path(), 2, 0).exists(), "live wal swept");
        assert!(wal_path(dir.path(), 1, 0).exists(), "retained wal swept");
        assert!(!wal_path(dir.path(), 0, 0).exists(), "expired wal kept");
        assert!(!snap_path(dir.path(), 1, 0).exists(), "stale snap kept");
        assert!(snap_path(dir.path(), 2, 0).exists(), "live snap swept");
    }

    #[test]
    fn wal_replay_reproduces_insert_and_move_sequences() {
        let dir = TempDir::new("recover-replay");
        let f = fp(2);
        recover(dir.path(), &f).unwrap(); // init manifest
        let mut rng = Xoshiro256::new(1);
        let rows: Vec<BitVec> = (0..4).map(|_| sk(&mut rng)).collect();
        // shard 0: insert a, b, then move b out; shard 1: receives b
        let mut w0 = WalWriter::create(&wal_path(dir.path(), 0, 0), FsyncPolicy::Never).unwrap();
        w0.append_insert(0, rows[0].words());
        w0.append_insert(1, rows[1].words());
        w0.append_move_out(4);
        w0.commit().unwrap();
        drop(w0);
        let mut w1 = WalWriter::create(&wal_path(dir.path(), 0, 1), FsyncPolicy::Never).unwrap();
        w1.append_insert(2, rows[2].words());
        w1.append_move_in(4, 1, 0, rows[1].words());
        w1.commit().unwrap();
        drop(w1);
        let (shards, report) = recover(dir.path(), &f).unwrap();
        assert_eq!(report.replayed_records, 5);
        assert_eq!(report.max_move_id, 4);
        assert_eq!(shards[0].ids, vec![0]);
        assert_eq!(shards[0].rows.row_bitvec(0), rows[0]);
        assert_eq!(shards[1].ids, vec![2, 1]);
        assert_eq!(shards[1].expiry, vec![0, 0]);
        assert_eq!(shards[1].rows.row_bitvec(0), rows[2]);
        assert_eq!(shards[1].rows.row_bitvec(1), rows[1]);
        // weights were recomputed correctly on replay
        assert_eq!(shards[1].rows.weight(1), rows[1].count_ones());
    }

    #[test]
    fn mixed_mutation_stream_replays_to_the_exact_survivor_set() {
        // insert a,b,c,d → delete b (swap-remove: d takes b's row) →
        // upsert c (in place, new words + deadline) → insert-ttl e →
        // delete a. Survivors: d, c (overwritten), e.
        let dir = TempDir::new("recover-mixed");
        let f = fp(1);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(21);
        let rows: Vec<BitVec> = (0..6).map(|_| sk(&mut rng)).collect();
        let path = wal_path(dir.path(), 0, 0);
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for id in 0..4u64 {
            w.append_insert(id, rows[id as usize].words());
        }
        w.append_delete(1);
        w.append_upsert(2, 7_000, rows[4].words());
        w.append_insert_ttl(9, 1_234, rows[5].words());
        w.append_delete(0);
        w.commit().unwrap();
        drop(w);
        let (shards, report) = recover(dir.path(), &f).unwrap();
        assert_eq!(report.replayed_records, 8);
        assert_eq!(report.max_move_id, 0);
        // delete(1) swapped d (id 3) into row 1; delete(0) swapped the
        // TTL row (id 9) into row 0
        assert_eq!(shards[0].ids, vec![9, 3, 2]);
        assert_eq!(shards[0].expiry, vec![1_234, 0, 7_000]);
        assert_eq!(shards[0].rows.row_bitvec(0), rows[5]);
        assert_eq!(shards[0].rows.row_bitvec(1), rows[3]);
        assert_eq!(shards[0].rows.row_bitvec(2), rows[4]); // upserted words
        assert_eq!(shards[0].rows.weight(2), rows[4].count_ones());
    }

    #[test]
    fn delete_or_upsert_of_an_unheld_id_is_a_hard_error() {
        for (name, frame) in [("recover-del-miss", 4u8), ("recover-ups-miss", 5u8)] {
            let dir = TempDir::new(name);
            let f = fp(1);
            recover(dir.path(), &f).unwrap();
            let mut rng = Xoshiro256::new(22);
            let row = sk(&mut rng);
            let path = wal_path(dir.path(), 0, 0);
            let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
            w.append_insert(0, row.words());
            if frame == 4 {
                w.append_delete(33);
            } else {
                w.append_upsert(33, 0, row.words());
            }
            w.commit().unwrap();
            drop(w);
            let err = recover(dir.path(), &f).unwrap_err().to_string();
            assert!(err.contains("id 33"), "{err}");
            assert!(err.contains("does not match the snapshot"), "{err}");
        }
    }

    #[test]
    fn fingerprint_mismatch_is_hard_error() {
        let dir = TempDir::new("recover-fp");
        recover(dir.path(), &fp(2)).unwrap();
        let mut other = fp(2);
        other.seed = 12;
        let err = recover(dir.path(), &other).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
        let mut shards = fp(2);
        shards.num_shards = 4;
        let err = recover(dir.path(), &shards).unwrap_err().to_string();
        assert!(err.contains("num_shards"), "{err}");
    }

    #[test]
    fn torn_tail_is_truncated_and_non_fatal() {
        let dir = TempDir::new("recover-torn");
        let f = fp(1);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(2);
        let rows: Vec<BitVec> = (0..3).map(|_| sk(&mut rng)).collect();
        let path = wal_path(dir.path(), 0, 0);
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for (i, r) in rows.iter().enumerate() {
            w.append_insert(i as u64, r.words());
        }
        w.commit().unwrap();
        drop(w);
        let full = std::fs::metadata(&path).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(full - 3)
            .unwrap();
        let (shards, report) = recover(dir.path(), &f).unwrap();
        assert_eq!(report.truncated_tails, 1);
        assert_eq!(report.replayed_records, 2);
        assert_eq!(shards[0].ids, vec![0, 1]);
        // the file was truncated to a frame boundary: recovering again is
        // clean and appending resumes safely
        let (_, again) = recover(dir.path(), &f).unwrap();
        assert_eq!(again.truncated_tails, 0);
        assert_eq!(again.replayed_records, 2);
    }

    #[test]
    fn snapshot_plus_wal_tail_compose() {
        let dir = TempDir::new("recover-compose");
        let f = fp(1);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(3);
        let snap_rows: Vec<BitVec> = (0..5).map(|_| sk(&mut rng)).collect();
        let tail_row = sk(&mut rng);
        // generation-2 snapshot with ids 10..15, then a WAL insert of id 99
        let m = SketchMatrix::from_sketches(&snap_rows);
        let ids: Vec<usize> = (10..15).collect();
        snapshot::write_shard(&snap_path(dir.path(), 2, 0), DIM, 0, &ids, &[0; 5], &m).unwrap();
        Manifest {
            generation: 2,
            fingerprint: f,
            epoch: 1,
            base_seqs: vec![5],
            prev: None,
        }
        .save(dir.path())
        .unwrap();
        let mut w = WalWriter::create(&wal_path(dir.path(), 2, 0), FsyncPolicy::Never).unwrap();
        w.append_insert(99, tail_row.words());
        w.append_move_out(1);
        w.append_move_out(2);
        w.commit().unwrap();
        drop(w);
        let (shards, report) = recover(dir.path(), &f).unwrap();
        assert_eq!(report.generation, 2);
        assert_eq!(report.snapshot_rows, 5);
        assert_eq!(report.replayed_records, 3);
        // seq anchoring: the segment's 3 frames carry seqs 5, 6, 7
        assert_eq!(report.base_seqs, vec![5]);
        assert_eq!(report.wal_frames, vec![3]);
        // snapshot(10..15) + push(99) + pop + pop = ids [10, 11, 12, 13]
        assert_eq!(shards[0].ids, vec![10, 11, 12, 13]);
        assert_eq!(shards[0].rows.len(), 4);
        assert_eq!(shards[0].rows.row_bitvec(3), snap_rows[3]);
    }

    #[test]
    fn duplicated_id_from_crashed_move_is_deduped() {
        // Simulate a crash between a rebalance's dst commit (MoveIn
        // durable) and src commit (MoveOut lost): id 1 exists in both
        // shards' logs. Recovery must keep exactly one copy.
        let dir = TempDir::new("recover-dup");
        let f = fp(2);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(11);
        let rows: Vec<BitVec> = (0..3).map(|_| sk(&mut rng)).collect();
        let mut w0 = WalWriter::create(&wal_path(dir.path(), 0, 0), FsyncPolicy::Never).unwrap();
        w0.append_insert(0, rows[0].words());
        w0.append_insert(1, rows[1].words());
        // the MoveOut for id 1 never reached the log
        w0.commit().unwrap();
        drop(w0);
        let mut w1 = WalWriter::create(&wal_path(dir.path(), 0, 1), FsyncPolicy::Never).unwrap();
        w1.append_insert(2, rows[2].words());
        w1.append_move_in(7, 1, 0, rows[1].words());
        w1.commit().unwrap();
        drop(w1);
        let (shards, report) = recover(dir.path(), &f).unwrap();
        assert_eq!(report.duplicate_rows_dropped, 1);
        // the orphaned MoveIn's move id still advances the counter seed
        assert_eq!(report.max_move_id, 7);
        // first occurrence (shard 0) wins; shard 1's copy is dropped
        assert_eq!(shards[0].ids, vec![0, 1]);
        assert_eq!(shards[1].ids, vec![2]);
        assert_eq!(shards[1].rows.len(), 1);
        assert_eq!(shards[1].rows.row_bitvec(0), rows[2]);
        let total: usize = shards.iter().map(|s| s.ids.len()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn missing_wal_segment_at_live_generation_is_a_hard_error() {
        let dir = TempDir::new("recover-missing-wal");
        let f = fp(1);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(12);
        let m = SketchMatrix::from_sketches(&[sk(&mut rng)]);
        snapshot::write_shard(&snap_path(dir.path(), 1, 0), DIM, 0, &[5], &[0], &m).unwrap();
        Manifest {
            generation: 1,
            fingerprint: f,
            epoch: 1,
            base_seqs: vec![1],
            prev: None,
        }
        .save(dir.path())
        .unwrap();
        // snapshot exists but wal-1-shard-0.log does not
        let err = recover(dir.path(), &f).unwrap_err().to_string();
        assert!(err.contains("missing for live generation 1"), "{err}");
        // creating an (empty) segment clears the condition
        drop(WalWriter::create(&wal_path(dir.path(), 1, 0), FsyncPolicy::Never).unwrap());
        let (shards, _) = recover(dir.path(), &f).unwrap();
        assert_eq!(shards[0].ids, vec![5]);
    }

    #[test]
    fn mid_file_wal_corruption_is_a_hard_error_not_a_truncation() {
        let dir = TempDir::new("recover-midfile");
        let f = fp(1);
        recover(dir.path(), &f).unwrap();
        let mut rng = Xoshiro256::new(8);
        let rows: Vec<BitVec> = (0..4).map(|_| sk(&mut rng)).collect();
        let path = wal_path(dir.path(), 0, 0);
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for (i, r) in rows.iter().enumerate() {
            w.append_insert(i as u64, r.words());
        }
        w.commit().unwrap();
        drop(w);
        // damage the SECOND frame: frames 3 and 4 are intact past it
        let mut bytes = std::fs::read(&path).unwrap();
        let frame = 12 + 1 + 8 + (DIM / 64) * 8;
        bytes[frame + 12 + 2] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = recover(dir.path(), &f).unwrap_err().to_string();
        assert!(err.contains("mid-file damage"), "{err}");
        // and the file was NOT truncated — the intact records are still there
        assert_eq!(std::fs::metadata(&path).unwrap().len(), bytes.len() as u64);
    }

    #[test]
    fn stale_generations_are_swept_at_recovery() {
        let dir = TempDir::new("recover-gc");
        let f = fp(1);
        recover(dir.path(), &f).unwrap(); // live generation 0
        // simulate a crash-during-rotation leftover: an orphan future-gen
        // snapshot + wal pair that never became live
        let orphan_snap = snap_path(dir.path(), 1, 0);
        let orphan_wal = wal_path(dir.path(), 1, 0);
        std::fs::write(&orphan_snap, b"orphan").unwrap();
        std::fs::write(&orphan_wal, b"orphan").unwrap();
        recover(dir.path(), &f).unwrap();
        assert!(!orphan_snap.exists(), "stale snapshot not swept");
        assert!(!orphan_wal.exists(), "stale wal not swept");
        // the live generation's files survive the sweep
        let mut w = WalWriter::create(&wal_path(dir.path(), 0, 0), FsyncPolicy::Never).unwrap();
        let mut rng = Xoshiro256::new(9);
        w.append_insert(0, sk(&mut rng).words());
        w.commit().unwrap();
        drop(w);
        let (shards, _) = recover(dir.path(), &f).unwrap();
        assert_eq!(shards[0].ids, vec![0]);
        assert!(wal_path(dir.path(), 0, 0).exists());
    }

    #[test]
    fn missing_snapshot_for_live_generation_is_hard_error() {
        let dir = TempDir::new("recover-missing-snap");
        let f = fp(1);
        Manifest {
            generation: 3,
            fingerprint: f,
            epoch: 1,
            base_seqs: vec![0],
            prev: None,
        }
        .save(dir.path())
        .unwrap();
        let err = recover(dir.path(), &f).unwrap_err().to_string();
        assert!(err.contains("generation-3"), "{err}");
    }
}
