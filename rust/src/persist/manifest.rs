//! The data-dir manifest: which snapshot generation is live, and the
//! configuration fingerprint the persisted sketches were built under.
//!
//! The manifest is the commit point of the snapshot protocol: recovery
//! reads `MANIFEST` first and everything else (snapshot files, WAL
//! segments) is addressed by the generation it names, so a crash anywhere
//! in a snapshot rotation leaves either the old or the new generation
//! fully intact — never a mix. It is written via tmp-file + rename for the
//! same reason.
//!
//! The fingerprint (`input_dim`, `num_categories`, `sketch_dim`, `seed`,
//! `num_shards`) is checked on every recovery and a mismatch is a *hard,
//! descriptive error*: sketches are meaningful only under the π/ψ
//! mappings derived from `seed` over an `input_dim`-dimensional,
//! `num_categories`-valued corpus at `sketch_dim`, and rows are addressed
//! per shard — silently loading a corpus persisted under any other
//! mapping would corrupt every Cham estimate the coordinator serves.
//! (`input_dim`/`num_categories` drift under an identical seed used to be
//! undetected: the π mapping tables differ in *shape*, so recovered
//! sketches would compare against freshly-sketched queries from a
//! different embedding — manifest version 2 closes that hole.) `seed` is
//! stored as a string because the wire JSON model is f64-backed and a u64
//! seed must roundtrip exactly.
//!
//! Version 3 adds `base_seqs`: the per-shard WAL sequence number of the
//! first frame of this generation's segment — equivalently, the count of
//! frames absorbed into the snapshot cut. Frame `j` of
//! `wal-G-shard-i.log` therefore has the globally monotonic sequence
//! `base_seqs[i] + j`, which is what replication (see [`crate::replica`])
//! uses to address follower catch-up positions. When a rotation retains
//! the previous generation's WAL segments for follower catch-up, their
//! anchoring rides along as `prev_generation`/`prev_base_seqs` — recorded
//! rather than re-derived, so a retained file that silently lost an
//! unsynced tail (power loss) can be *detected* against its expected
//! frame count instead of mislabelling sequences. Like the seeds, the
//! seqs are stored as strings so they roundtrip exactly through the
//! f64-backed JSON model.
//!
//! Version 4 marks the mutable-corpus log format. WAL segments may now
//! contain `Delete` (kind 4), `Upsert` (kind 5) and `InsertTtl` (kind 6)
//! frames, `MoveOut`/`MoveIn` pairs carry a shared move id, and snapshot
//! rows (snapshot format v2) carry a per-row TTL deadline column. A v3
//! dir's bytes are not interpretable under these rules (a v3 MoveOut has
//! no move id; a v3 snapshot row has no deadline), so v3 manifests are
//! refused descriptively like v1/v2 rather than mis-decoded.
//!
//! Version 5 adds `epoch`: the monotonic write-authority term of the
//! replicated pair (see [`crate::replica`]). A fresh primary starts at
//! epoch 1; `promote` persists `primary_epoch + 1` before flipping the
//! replica writable; a server that observes a higher epoch than its own
//! (on a shipper request or a fenced write) knows a newer primary exists
//! and fences itself read-only. Like the seed and the seqs, the epoch is
//! stored as a string so it roundtrips exactly through the f64-backed
//! JSON model. A v4 dir has no epoch, so the old primary of a failed-over
//! pair could not be fenced — refused descriptively like v1/v2/v3.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Version 5 adds the monotonic failover `epoch` (write-authority term).
/// Version 4 dirs predate epoch fencing (a revived old primary could not
/// be fenced), version 3 dirs predate the mutable-corpus log format,
/// version 2 (no `base_seqs`) cannot anchor a follower's catch-up
/// position, and version 1 cannot even be verified against the live
/// corpus shape — each is refused with a descriptive error rather than
/// half-loaded.
const VERSION: u32 = 5;

/// The store configuration a data dir was persisted under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    pub sketch_dim: usize,
    pub seed: u64,
    pub num_shards: usize,
    /// Corpus dimensionality the π mapping was derived for.
    pub input_dim: usize,
    /// Category alphabet size the ψ mapping was derived for.
    pub num_categories: u16,
}

impl Fingerprint {
    /// Hard-error unless `self` (from disk) matches `expect` (the live
    /// config), naming every mismatched field.
    pub fn check(&self, expect: &Fingerprint) -> Result<()> {
        let mut diffs = Vec::new();
        if self.sketch_dim != expect.sketch_dim {
            diffs.push(format!(
                "sketch_dim: persisted {} vs configured {}",
                self.sketch_dim, expect.sketch_dim
            ));
        }
        if self.seed != expect.seed {
            diffs.push(format!(
                "seed: persisted {} vs configured {}",
                self.seed, expect.seed
            ));
        }
        if self.num_shards != expect.num_shards {
            diffs.push(format!(
                "num_shards: persisted {} vs configured {}",
                self.num_shards, expect.num_shards
            ));
        }
        if self.input_dim != expect.input_dim {
            diffs.push(format!(
                "input_dim: persisted {} vs configured {}",
                self.input_dim, expect.input_dim
            ));
        }
        if self.num_categories != expect.num_categories {
            diffs.push(format!(
                "num_categories: persisted {} vs configured {}",
                self.num_categories, expect.num_categories
            ));
        }
        if diffs.is_empty() {
            Ok(())
        } else {
            bail!(
                "persisted data was written under a different configuration ({}); \
                 refusing to load — sketches from another sketch_dim/seed mapping or \
                 shard layout would silently corrupt every distance estimate. Point \
                 --data-dir at a fresh directory or restore the original configuration",
                diffs.join("; ")
            )
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub generation: u64,
    pub fingerprint: Fingerprint,
    /// Monotonic write-authority term. Bumped (and persisted) by
    /// `promote` before the replica flips writable; a server observing a
    /// higher epoch than its own fences itself read-only. Starts at 1 on
    /// a fresh primary; a follower bootstraps with its primary's epoch.
    pub epoch: u64,
    /// Per-shard WAL sequence of this generation's first frame (frames
    /// absorbed into the snapshot cut). Length == `num_shards`.
    pub base_seqs: Vec<u64>,
    /// Retained previous generation's anchoring `(generation, per-shard
    /// base seqs)` — present from the first rotation on. Recovery
    /// validates the retained files against it before the shipper may
    /// serve them.
    pub prev: Option<(u64, Vec<u64>)>,
}

pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("MANIFEST")
}

/// Snapshot file for `(generation, shard)`.
pub fn snap_path(dir: &Path, generation: u64, shard: usize) -> PathBuf {
    dir.join(format!("snap-{generation}-shard-{shard}.bin"))
}

/// WAL segment for `(generation, shard)` — records since that generation's
/// snapshot cut.
pub fn wal_path(dir: &Path, generation: u64, shard: usize) -> PathBuf {
    dir.join(format!("wal-{generation}-shard-{shard}.log"))
}

impl Manifest {
    /// Write atomically (tmp + rename + dir sync best-effort).
    pub fn save(&self, dir: &Path) -> Result<()> {
        assert_eq!(
            self.base_seqs.len(),
            self.fingerprint.num_shards,
            "manifest base_seqs arity out of step with num_shards"
        );
        if let Some((_, prev_bases)) = &self.prev {
            assert_eq!(
                prev_bases.len(),
                self.fingerprint.num_shards,
                "manifest prev_base_seqs arity out of step with num_shards"
            );
        }
        let seq_strings = |seqs: &[u64]| {
            Json::Arr(seqs.iter().map(|s| Json::Str(s.to_string())).collect())
        };
        let mut pairs = vec![
            ("version", Json::Num(VERSION as f64)),
            ("generation", Json::Num(self.generation as f64)),
            ("epoch", Json::Str(self.epoch.to_string())),
            (
                "sketch_dim",
                Json::Num(self.fingerprint.sketch_dim as f64),
            ),
            ("seed", Json::Str(self.fingerprint.seed.to_string())),
            (
                "num_shards",
                Json::Num(self.fingerprint.num_shards as f64),
            ),
            (
                "input_dim",
                Json::Num(self.fingerprint.input_dim as f64),
            ),
            (
                "num_categories",
                Json::Num(self.fingerprint.num_categories as f64),
            ),
            ("base_seqs", seq_strings(&self.base_seqs)),
        ];
        if let Some((prev_generation, prev_bases)) = &self.prev {
            pairs.push(("prev_generation", Json::Num(*prev_generation as f64)));
            pairs.push(("prev_base_seqs", seq_strings(prev_bases)));
        }
        let json = Json::obj(pairs);
        let path = manifest_path(dir);
        let tmp = dir.join("MANIFEST.tmp");
        {
            // write + fsync before the rename: the manifest is the commit
            // point of the snapshot protocol, so its *contents* must be
            // durable before the directory entry can name it — otherwise a
            // power loss could surface a zero-length MANIFEST and strand
            // the whole data dir
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(json.to_string().as_bytes())
                .with_context(|| format!("write {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("fsync {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("rename manifest into place: {}", path.display()))?;
        sync_dir(dir);
        Ok(())
    }

    /// Load the manifest, or `None` when the dir has never been persisted
    /// to (no `MANIFEST`).
    pub fn load(dir: &Path) -> Result<Option<Manifest>> {
        let path = manifest_path(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("read {}", path.display())),
        };
        let obj = crate::util::json::parse(&text)
            .with_context(|| format!("parse {}", path.display()))?;
        let version = obj.req_usize("version")? as u32;
        if version == 1 {
            bail!(
                "{}: manifest version 1 predates the full configuration fingerprint \
                 (no input_dim/num_categories), so the persisted corpus cannot be \
                 verified against this server's corpus shape — re-ingest into a fresh \
                 --data-dir",
                path.display()
            );
        }
        if version == 2 {
            bail!(
                "{}: manifest version 2 predates per-shard WAL sequence numbering \
                 (no base_seqs), so replication catch-up positions cannot be anchored \
                 for this data dir — re-ingest into a fresh --data-dir",
                path.display()
            );
        }
        if version == 3 {
            bail!(
                "{}: manifest version 3 predates the mutable-corpus log format \
                 (no Delete/Upsert/TTL frame kinds, no move ids, no per-row TTL \
                 deadlines in snapshots), so its WAL and snapshot bytes cannot be \
                 interpreted by this server — re-ingest into a fresh --data-dir",
                path.display()
            );
        }
        if version == 4 {
            bail!(
                "{}: manifest version 4 predates epoch fencing (no failover epoch), \
                 so a revived old primary of this data dir could not be fenced against \
                 a promoted replica — re-ingest into a fresh --data-dir",
                path.display()
            );
        }
        if version != VERSION {
            bail!("{}: unsupported manifest version {version}", path.display());
        }
        let seed: u64 = obj
            .req_str("seed")?
            .parse()
            .with_context(|| format!("{}: seed is not a u64", path.display()))?;
        let fingerprint = Fingerprint {
            sketch_dim: obj.req_usize("sketch_dim")?,
            seed,
            num_shards: obj.req_usize("num_shards")?,
            input_dim: obj.req_usize("input_dim")?,
            num_categories: obj.req_usize("num_categories")? as u16,
        };
        let seq_vec = |key: &str| -> Result<Vec<u64>> {
            let seqs = obj
                .req_arr(key)?
                .iter()
                .map(|s| {
                    s.as_str().and_then(|s| s.parse::<u64>().ok()).ok_or_else(|| {
                        anyhow::anyhow!("{}: {key} entry is not a u64", path.display())
                    })
                })
                .collect::<Result<Vec<u64>>>()?;
            if seqs.len() != fingerprint.num_shards {
                bail!(
                    "{}: {key} has {} entries for {} shards — manifest is corrupt",
                    path.display(),
                    seqs.len(),
                    fingerprint.num_shards
                );
            }
            Ok(seqs)
        };
        let epoch: u64 = obj
            .req_str("epoch")?
            .parse()
            .with_context(|| format!("{}: epoch is not a u64", path.display()))?;
        let base_seqs = seq_vec("base_seqs")?;
        let prev = match obj.get("prev_generation").and_then(|v| v.as_usize()) {
            Some(prev_generation) => Some((prev_generation as u64, seq_vec("prev_base_seqs")?)),
            None => None,
        };
        Ok(Some(Manifest {
            generation: obj.req_usize("generation")? as u64,
            fingerprint,
            epoch,
            base_seqs,
            prev,
        }))
    }
}

/// Best-effort directory fsync so renames survive power loss on Linux.
pub fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// The fence marker: a one-line file naming the higher epoch this server
/// observed. Its *presence* is the durable "I am not the primary any
/// more" bit — a fenced ex-primary that crashes and restarts must come
/// back fenced, not writable, or the split-brain the fence closed would
/// reopen across the restart.
pub fn fence_path(dir: &Path) -> PathBuf {
    dir.join("FENCED")
}

/// Persist the fence marker (tmp + rename + dir sync, like the manifest —
/// the fence must never surface half-written).
pub fn write_fence(dir: &Path, epoch: u64) -> Result<()> {
    let path = fence_path(dir);
    let tmp = dir.join("FENCED.tmp");
    {
        use std::io::Write;
        let mut f =
            std::fs::File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(epoch.to_string().as_bytes())
            .with_context(|| format!("write {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("rename fence marker into place: {}", path.display()))?;
    sync_dir(dir);
    Ok(())
}

/// Read the fence marker: `None` when the server is not fenced. A marker
/// that exists but cannot be parsed is a hard error — guessing "not
/// fenced" on a corrupt marker would reopen the split-brain window.
pub fn read_fence(dir: &Path) -> Result<Option<u64>> {
    let path = fence_path(dir);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("read {}", path.display())),
    };
    let epoch = text.trim().parse::<u64>().with_context(|| {
        format!("{}: fence marker is not a u64 epoch", path.display())
    })?;
    Ok(Some(epoch))
}

/// Remove the fence marker (rejoining as an explicit follower via
/// `--replicate-from` supersedes it: the follower role is read-only by
/// construction). Missing markers are fine.
pub fn clear_fence(dir: &Path) -> Result<()> {
    match std::fs::remove_file(fence_path(dir)) {
        Ok(()) => {
            sync_dir(dir);
            Ok(())
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(e).with_context(|| format!("remove {}", fence_path(dir).display())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;

    fn fp() -> Fingerprint {
        Fingerprint {
            sketch_dim: 1024,
            // beyond f64's 2^53 integer range: must roundtrip exactly
            seed: (1u64 << 60) + 3,
            num_shards: 4,
            input_dim: 4096,
            num_categories: 64,
        }
    }

    #[test]
    fn manifest_roundtrips() {
        let dir = TempDir::new("manifest-roundtrip");
        let mut m = Manifest {
            generation: 7,
            fingerprint: fp(),
            // beyond f64's 2^53 integer range: must roundtrip exactly
            epoch: (1u64 << 57) + 5,
            base_seqs: vec![0, 41, (1u64 << 55) + 9, 7],
            prev: None,
        };
        m.save(dir.path()).unwrap();
        let back = Manifest::load(dir.path()).unwrap().unwrap();
        assert_eq!(back, m);
        assert!(!dir.path().join("MANIFEST.tmp").exists());
        // retained-segment anchoring rides along when present
        m.prev = Some((6, vec![0, 40, (1u64 << 55), 7]));
        m.save(dir.path()).unwrap();
        let back = Manifest::load(dir.path()).unwrap().unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn missing_manifest_is_none() {
        let dir = TempDir::new("manifest-missing");
        assert!(Manifest::load(dir.path()).unwrap().is_none());
    }

    #[test]
    fn fingerprint_mismatch_names_every_field() {
        let persisted = fp();
        let mut live = fp();
        live.sketch_dim = 512;
        live.num_shards = 8;
        let err = persisted.check(&live).unwrap_err().to_string();
        assert!(err.contains("sketch_dim"), "{err}");
        assert!(err.contains("num_shards"), "{err}");
        assert!(!err.contains("seed:"), "{err}");
        let mut seeded = fp();
        seeded.seed = 9;
        let err = persisted.check(&seeded).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
        // corpus-shape drift under an identical seed is detected too
        let mut shaped = fp();
        shaped.input_dim = 100;
        shaped.num_categories = 3;
        let err = persisted.check(&shaped).unwrap_err().to_string();
        assert!(err.contains("input_dim"), "{err}");
        assert!(err.contains("num_categories"), "{err}");
        persisted.check(&fp()).unwrap();
    }

    #[test]
    fn version_1_manifest_is_refused_descriptively() {
        let dir = TempDir::new("manifest-v1");
        std::fs::write(
            manifest_path(dir.path()),
            r#"{"version":1,"generation":0,"sketch_dim":64,"seed":"7","num_shards":2}"#,
        )
        .unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
        assert!(err.contains("fresh --data-dir"), "{err}");
    }

    #[test]
    fn version_2_manifest_is_refused_descriptively() {
        let dir = TempDir::new("manifest-v2");
        std::fs::write(
            manifest_path(dir.path()),
            r#"{"version":2,"generation":1,"sketch_dim":64,"seed":"7","num_shards":2,"input_dim":100,"num_categories":4}"#,
        )
        .unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("version 2"), "{err}");
        assert!(err.contains("base_seqs"), "{err}");
        assert!(err.contains("fresh --data-dir"), "{err}");
    }

    #[test]
    fn version_3_manifest_is_refused_descriptively() {
        let dir = TempDir::new("manifest-v3");
        std::fs::write(
            manifest_path(dir.path()),
            r#"{"version":3,"generation":2,"sketch_dim":64,"seed":"7","num_shards":2,"input_dim":100,"num_categories":4,"base_seqs":["5","9"]}"#,
        )
        .unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("version 3"), "{err}");
        assert!(err.contains("Delete/Upsert/TTL"), "{err}");
        assert!(err.contains("fresh --data-dir"), "{err}");
    }

    #[test]
    fn version_4_manifest_is_refused_descriptively() {
        let dir = TempDir::new("manifest-v4");
        std::fs::write(
            manifest_path(dir.path()),
            r#"{"version":4,"generation":2,"sketch_dim":64,"seed":"7","num_shards":2,"input_dim":100,"num_categories":4,"base_seqs":["5","9"]}"#,
        )
        .unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("version 4"), "{err}");
        assert!(err.contains("epoch"), "{err}");
        assert!(err.contains("fresh --data-dir"), "{err}");
    }

    #[test]
    fn base_seqs_arity_mismatch_is_refused() {
        let dir = TempDir::new("manifest-arity");
        let mut m = Manifest {
            generation: 1,
            fingerprint: fp(), // 4 shards
            epoch: 1,
            base_seqs: vec![1, 2, 3, 4],
            prev: None,
        };
        m.save(dir.path()).unwrap();
        Manifest::load(dir.path()).unwrap().unwrap();
        // hand-damage the array on disk: loading must refuse, not index OOB
        let text = std::fs::read_to_string(manifest_path(dir.path()))
            .unwrap()
            .replace(r#""1","2","3","4""#, r#""1","2""#);
        std::fs::write(manifest_path(dir.path()), text).unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("2 entries for 4 shards"), "{err}");
        m.base_seqs = vec![0; 3];
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = m.save(dir.path());
        }));
        assert!(panicked.is_err(), "saving a malformed manifest must assert");
    }

    #[test]
    fn fence_marker_roundtrips_and_clears() {
        let dir = TempDir::new("manifest-fence");
        assert_eq!(read_fence(dir.path()).unwrap(), None);
        write_fence(dir.path(), (1u64 << 54) + 11).unwrap();
        assert_eq!(read_fence(dir.path()).unwrap(), Some((1u64 << 54) + 11));
        assert!(!dir.path().join("FENCED.tmp").exists());
        // re-fencing at a later epoch overwrites
        write_fence(dir.path(), (1u64 << 54) + 12).unwrap();
        assert_eq!(read_fence(dir.path()).unwrap(), Some((1u64 << 54) + 12));
        clear_fence(dir.path()).unwrap();
        assert_eq!(read_fence(dir.path()).unwrap(), None);
        // clearing twice is fine (idempotent rejoin paths)
        clear_fence(dir.path()).unwrap();
        // a corrupt marker is refused, not treated as "not fenced"
        std::fs::write(fence_path(dir.path()), "what").unwrap();
        let err = read_fence(dir.path()).unwrap_err().to_string();
        assert!(err.contains("not a u64 epoch"), "{err}");
    }

    #[test]
    fn paths_embed_generation_and_shard() {
        let d = Path::new("/data");
        assert_eq!(
            snap_path(d, 3, 1),
            PathBuf::from("/data/snap-3-shard-1.bin")
        );
        assert_eq!(wal_path(d, 0, 2), PathBuf::from("/data/wal-0-shard-2.log"));
    }
}
