//! Full per-shard arena snapshots.
//!
//! A snapshot file captures one shard completely: its id column, per-row
//! TTL deadlines, and the [`SketchMatrix`] rows *with their cached
//! weights*, so loading a snapshot never re-popcounts the arena. Layout
//! (little-endian):
//!
//! ```text
//!   "CBSP" [u32 version][u64 sketch_dim][u64 shard_index][u64 row_count]
//!   row_count × ([u64 id][u32 weight][u64 deadline][words_per_row × u64])
//!   [u64 fnv1a64(everything after the magic, before this field)]
//! ```
//!
//! `deadline` is the row's absolute TTL expiry in unix milliseconds, `0`
//! for rows with no TTL (format version 2; version 1 had no deadline
//! column and is only ever seen behind a pre-v4 manifest, which recovery
//! refuses before any snapshot is opened).
//!
//! Files are written to a `.tmp` sibling, fsynced, then renamed into
//! place, so a crash mid-snapshot can never leave a half-written file
//! under the live name; the trailing checksum rejects bit rot and torn
//! renames on crash-prone filesystems. The embedded `sketch_dim` and
//! `shard_index` are cross-checked on load — a snapshot can never be
//! applied to the wrong shard or a differently-configured store.

use super::wal::fnv1a64;
use crate::sketch::SketchMatrix;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CBSP";
const VERSION: u32 = 2;

/// One shard's recovered state: the id column, the per-row TTL deadline
/// column (unix millis, 0 = none) and the packed arena. Also the shape
/// recovery hands back to [`crate::coordinator::store`] for both
/// snapshot-loaded and WAL-replayed shards.
#[derive(Debug, Default)]
pub struct ShardState {
    pub ids: Vec<usize>,
    pub expiry: Vec<u64>,
    pub rows: SketchMatrix,
}

/// Write one shard's snapshot atomically (`path.tmp` + rename).
pub fn write_shard(
    path: &Path,
    sketch_dim: usize,
    shard_index: usize,
    ids: &[usize],
    expiry: &[u64],
    rows: &SketchMatrix,
) -> Result<()> {
    assert_eq!(ids.len(), rows.len(), "id column out of step with arena");
    assert_eq!(
        expiry.len(),
        rows.len(),
        "expiry column out of step with arena"
    );
    let words_per_row = rows.words_per_row();
    let mut body =
        Vec::with_capacity(4 + 8 + 8 + 8 + ids.len() * (20 + words_per_row * 8));
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.extend_from_slice(&(sketch_dim as u64).to_le_bytes());
    body.extend_from_slice(&(shard_index as u64).to_le_bytes());
    body.extend_from_slice(&(ids.len() as u64).to_le_bytes());
    for (row, &id) in ids.iter().enumerate() {
        body.extend_from_slice(&(id as u64).to_le_bytes());
        body.extend_from_slice(&(rows.weight(row) as u32).to_le_bytes());
        body.extend_from_slice(&expiry[row].to_le_bytes());
        for w in rows.row(row) {
            body.extend_from_slice(&w.to_le_bytes());
        }
    }
    let checksum = fnv1a64(&body);
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create snapshot {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&body)?;
        f.write_all(&checksum.to_le_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename snapshot into place: {}", path.display()))?;
    Ok(())
}

/// Load and validate one shard's snapshot.
pub fn load_shard(path: &Path, sketch_dim: usize, shard_index: usize) -> Result<ShardState> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open snapshot {}", path.display()))?
        .read_to_end(&mut buf)?;
    if buf.len() < 4 + 28 + 8 || &buf[..4] != MAGIC {
        bail!("snapshot {}: bad magic or truncated header", path.display());
    }
    let body = &buf[4..buf.len() - 8];
    let want = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if fnv1a64(body) != want {
        bail!("snapshot {}: checksum mismatch (torn or corrupt)", path.display());
    }
    let version = u32::from_le_bytes(body[0..4].try_into().unwrap());
    if version != VERSION {
        bail!("snapshot {}: unsupported version {version}", path.display());
    }
    let dim = u64::from_le_bytes(body[4..12].try_into().unwrap()) as usize;
    let shard = u64::from_le_bytes(body[12..20].try_into().unwrap()) as usize;
    let n = u64::from_le_bytes(body[20..28].try_into().unwrap()) as usize;
    if dim != sketch_dim {
        bail!(
            "snapshot {}: sketch_dim {dim} does not match store sketch_dim {sketch_dim}",
            path.display()
        );
    }
    if shard != shard_index {
        bail!(
            "snapshot {}: written for shard {shard}, loaded as shard {shard_index}",
            path.display()
        );
    }
    let words_per_row = sketch_dim.div_ceil(64);
    let row_bytes = 20 + words_per_row * 8;
    if body.len() != 28 + n * row_bytes {
        bail!(
            "snapshot {}: body is {} bytes, expected {} for {n} rows",
            path.display(),
            body.len(),
            28 + n * row_bytes
        );
    }
    let mut ids = Vec::with_capacity(n);
    let mut expiry = Vec::with_capacity(n);
    let mut rows = SketchMatrix::with_row_capacity(sketch_dim, n);
    let mut words = vec![0u64; words_per_row];
    for r in 0..n {
        let at = 28 + r * row_bytes;
        ids.push(u64::from_le_bytes(body[at..at + 8].try_into().unwrap()) as usize);
        let weight = u32::from_le_bytes(body[at + 8..at + 12].try_into().unwrap());
        expiry.push(u64::from_le_bytes(body[at + 12..at + 20].try_into().unwrap()));
        for (wi, chunk) in body[at + 20..at + row_bytes].chunks_exact(8).enumerate() {
            words[wi] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
        rows.push_row(&words, weight);
    }
    Ok(ShardState { ids, expiry, rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::BitVec;
    use crate::testing::TempDir;
    use crate::util::rng::Xoshiro256;

    fn arena(seed: u64, n: usize, dim: usize) -> (Vec<usize>, Vec<u64>, SketchMatrix) {
        let mut rng = Xoshiro256::new(seed);
        let sketches: Vec<BitVec> = (0..n)
            .map(|_| BitVec::from_indices(dim, rng.sample_indices(dim, dim / 6)))
            .collect();
        let ids = (0..n).map(|i| i * 3 + 1).collect();
        // a mix of TTL'd rows (beyond f64's 2^53 range: must roundtrip
        // exactly) and deadline-0 (no TTL) rows
        let expiry = (0..n)
            .map(|i| if i % 3 == 0 { (1u64 << 55) + i as u64 } else { 0 })
            .collect();
        (ids, expiry, SketchMatrix::from_sketches(&sketches))
    }

    #[test]
    fn snapshot_roundtrips_ids_deadlines_rows_and_weights() {
        let dir = TempDir::new("snap-roundtrip");
        let path = dir.path().join("snap-1-shard-2.bin");
        let (ids, expiry, rows) = arena(1, 13, 130); // non-multiple-of-64 dim
        write_shard(&path, 130, 2, &ids, &expiry, &rows).unwrap();
        let loaded = load_shard(&path, 130, 2).unwrap();
        assert_eq!(loaded.ids, ids);
        assert_eq!(loaded.expiry, expiry);
        assert_eq!(loaded.rows, rows); // rows + cached weights, exactly
    }

    #[test]
    fn empty_shard_roundtrips() {
        let dir = TempDir::new("snap-empty");
        let path = dir.path().join("snap.bin");
        write_shard(&path, 64, 0, &[], &[], &SketchMatrix::new(64)).unwrap();
        let loaded = load_shard(&path, 64, 0).unwrap();
        assert!(loaded.ids.is_empty());
        assert!(loaded.expiry.is_empty());
        assert!(loaded.rows.is_empty());
    }

    #[test]
    fn wrong_dim_or_shard_is_a_described_error() {
        let dir = TempDir::new("snap-mismatch");
        let path = dir.path().join("snap.bin");
        let (ids, expiry, rows) = arena(2, 4, 128);
        write_shard(&path, 128, 1, &ids, &expiry, &rows).unwrap();
        let err = load_shard(&path, 256, 1).unwrap_err();
        assert!(err.to_string().contains("sketch_dim"), "{err:#}");
        let err = load_shard(&path, 128, 0).unwrap_err();
        assert!(err.to_string().contains("shard"), "{err:#}");
    }

    #[test]
    fn corruption_is_detected() {
        let dir = TempDir::new("snap-corrupt");
        let path = dir.path().join("snap.bin");
        let (ids, expiry, rows) = arena(3, 6, 64);
        write_shard(&path, 64, 0, &ids, &expiry, &rows).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_shard(&path, 64, 0).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err:#}");
    }

    #[test]
    fn no_tmp_file_left_behind() {
        let dir = TempDir::new("snap-tmp");
        let path = dir.path().join("snap.bin");
        let (ids, expiry, rows) = arena(4, 3, 64);
        write_shard(&path, 64, 0, &ids, &expiry, &rows).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists());
    }
}
