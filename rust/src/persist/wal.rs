//! Per-shard append-only write-ahead log.
//!
//! One WAL file serves one coordinator shard. Every mutation of a shard's
//! arena is appended as a length-prefixed, checksummed record *while the
//! shard's write lock is held*, so the record order in the file is exactly
//! the mutation order of the arena — replaying a shard's WAL alone
//! reproduces that shard's `ids`/`rows` state byte-for-byte (rebalance
//! moves always pop the source arena's *trailing* row, so a move is a
//! `MoveOut` in the source log plus a `MoveIn` in the destination log, and
//! no cross-shard ordering is required).
//!
//! Frame layout (all little-endian):
//!
//! ```text
//!   [u32 payload_len][u64 fnv1a64(payload)][payload]
//!   payload: [u8 1][u64 id][row words]                           Insert
//!            [u64 2][u64 move_id]                                MoveOut (pop trailing row)
//!            [u8 3][u64 move_id][u64 id][u64 deadline][words]    MoveIn
//!            [u8 4][u64 id]                                      Delete (swap-remove by id)
//!            [u8 5][u64 id][u64 deadline][row words]             Upsert (overwrite in place)
//!            [u8 6][u64 id][u64 deadline][row words]             InsertTtl
//! ```
//!
//! Kind 1 (`Insert`) keeps the original byte layout so pre-mutation logs
//! and the original frame-size arithmetic stay valid; rows with a TTL use
//! kind 6 with an absolute unix-millisecond `deadline` (0 = no expiry —
//! the decoder folds both kinds into one [`WalRecord::Insert`]). `MoveOut`
//! / `MoveIn` pairs produced by one rebalance move share a `move_id`, so a
//! replication follower can recognise the two halves of a cross-shard move
//! arriving in independent per-shard streams and apply the destination
//! half first (see [`crate::replica::follower`]).
//!
//! The reader stops at the first frame that is short, oversized, or fails
//! its checksum: a torn tail write (crash mid-append) therefore drops only
//! the partial final record, never the log ([`read_wal`] reports the valid
//! prefix length so recovery can truncate before appending again).
//!
//! Sequence numbers (replication, see [`crate::replica`]): every frame of
//! a shard's log carries an implicit monotonic per-shard sequence number —
//! its position in the shard's total frame history. The manifest records
//! each generation's per-shard *base* sequence (frames absorbed into the
//! snapshot cut), so frame `j` of segment `wal-G-shard-i` has sequence
//! `base_seqs[i] + j`. Nothing in the on-disk frame format changes; the
//! writer merely counts the frames it lands in the file
//! ([`WalWriter::file_frames`]), and [`read_wal_tail`] serves a
//! checksummed byte range of frames by position for the primary-side
//! shipper.
//!
//! Appended frames are buffered *in memory* (not in an OS-level buffered
//! writer) and reach the file only when [`WalWriter::commit`] runs, so no
//! record can spill to the OS — let alone the platter — before its batch
//! commits. This is load-bearing for the rebalance protocol: the store
//! commits the destination's `MoveIn` before the source's `MoveOut`, and
//! that ordering only guarantees "a moved row is never absent from both
//! logs after a crash" if an auto-flush can't leak `MoveOut` frames early.
//! The store commits once per insert/rebalance batch, before the batch is
//! acknowledged, so with [`FsyncPolicy::Always`] every acknowledged insert
//! survives a hard kill.

use super::FsyncPolicy;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const KIND_INSERT: u8 = 1;
const KIND_MOVE_OUT: u8 = 2;
const KIND_MOVE_IN: u8 = 3;
const KIND_DELETE: u8 = 4;
const KIND_UPSERT: u8 = 5;
const KIND_INSERT_TTL: u8 = 6;

/// 64-bit FNV-1a — the frame checksum. Not cryptographic; it guards
/// against torn writes and bit rot, which is all a local WAL needs.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One decoded WAL record (the owned, replay-side view). Deadlines are
/// absolute unix milliseconds; 0 means "never expires".
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// Append a row to the shard arena under `id` (kinds 1 and 6).
    Insert {
        id: u64,
        deadline: u64,
        words: Vec<u64>,
    },
    /// Pop the shard arena's trailing row (source side of a rebalance
    /// move); `move_id` pairs it with its destination `MoveIn`.
    MoveOut { move_id: u64 },
    /// Append a row moved in from another shard (destination side).
    MoveIn {
        move_id: u64,
        id: u64,
        deadline: u64,
        words: Vec<u64>,
    },
    /// Swap-remove the row holding `id` (delete, or a TTL expiry sweep).
    Delete { id: u64 },
    /// Overwrite `id`'s row and deadline in place.
    Upsert {
        id: u64,
        deadline: u64,
        words: Vec<u64>,
    },
}

/// Append handle for one shard's WAL. Uncommitted frames live in
/// `pending` (process memory) and hit the file only at
/// [`WalWriter::commit`]; `synced` tracks whether file bytes written
/// since the last `fdatasync` exist, so clean writers never pay a
/// redundant fsync on `sync`/drop.
pub struct WalWriter {
    file: File,
    path: PathBuf,
    fsync: FsyncPolicy,
    /// Frames appended since the last commit — nothing here can reach the
    /// OS (or survive a crash) until `commit` writes it out.
    pending: Vec<u8>,
    /// Frame count of `pending` (sequence-number bookkeeping).
    pending_frames: u64,
    /// Bytes successfully written to the file (the last good frame
    /// boundary). A failed `write_all` rewinds to this length before any
    /// retry, so a partial write can never leave garbage *between* valid
    /// frames — which recovery would refuse as mid-file corruption.
    file_len: u64,
    /// Frames successfully written to the file. Together with the
    /// manifest's per-shard base sequence this addresses every frame:
    /// the next landed frame gets sequence `base + file_frames`.
    file_frames: u64,
    /// Frames covered by the last successful `fdatasync` — the power-loss
    /// durability horizon under [`FsyncPolicy::Always`]. Replication
    /// ships no frame beyond [`WalWriter::durable_frames`], so a follower
    /// can never hold frames a primary power loss could revoke.
    synced_frames: u64,
    /// Whether every byte written to the file has been `fdatasync`ed.
    synced: bool,
    /// Test-support fault injection: when set, the next [`WalWriter::commit`]
    /// fails with this message *before* touching the file (frames stay
    /// pending, exactly like a real I/O error). One-shot — consumed by
    /// that commit. This is how the commit-error propagation path (store →
    /// batcher → wire) is exercised without real disk faults.
    inject_commit_error: Option<String>,
}

impl WalWriter {
    /// Create (truncating any existing file) — used by snapshot rotation,
    /// which starts every generation from an empty log.
    pub fn create(path: &Path, fsync: FsyncPolicy) -> std::io::Result<WalWriter> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(WalWriter {
            file,
            path: path.to_path_buf(),
            fsync,
            pending: Vec::new(),
            pending_frames: 0,
            file_len: 0,
            file_frames: 0,
            synced_frames: 0,
            synced: true,
            inject_commit_error: None,
        })
    }

    /// Open for appending after recovery. The caller (recovery) has
    /// already truncated any torn tail, so appending continues from the
    /// last valid frame boundary; `file_frames` is the frame count of
    /// that valid prefix (recovery just replayed it, so it knows).
    pub fn open_append(
        path: &Path,
        fsync: FsyncPolicy,
        file_frames: u64,
    ) -> std::io::Result<WalWriter> {
        let mut file = OpenOptions::new().create(true).write(true).open(path)?;
        let file_len = file.seek(SeekFrom::End(0))?;
        Ok(WalWriter {
            file,
            path: path.to_path_buf(),
            fsync,
            pending: Vec::new(),
            pending_frames: 0,
            file_len,
            file_frames,
            // the recovered prefix IS the crash-surviving state
            synced_frames: file_frames,
            synced: true,
            inject_commit_error: None,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn append(&mut self, kind: u8, fields: &[u64], words: &[u64]) -> usize {
        let body = 1 + fields.len() * 8 + words.len() * 8;
        self.pending.reserve(12 + body);
        self.pending.extend_from_slice(&(body as u32).to_le_bytes());
        let payload_at = self.pending.len() + 8;
        // checksum goes before the payload: reserve its slot, fill below
        self.pending.extend_from_slice(&[0u8; 8]);
        self.pending.push(kind);
        for f in fields {
            self.pending.extend_from_slice(&f.to_le_bytes());
        }
        for w in words {
            self.pending.extend_from_slice(&w.to_le_bytes());
        }
        let checksum = fnv1a64(&self.pending[payload_at..]);
        self.pending[payload_at - 8..payload_at].copy_from_slice(&checksum.to_le_bytes());
        self.pending_frames += 1;
        12 + body
    }

    /// Append an insert record; returns the frame size in bytes. Appends
    /// are infallible (they only buffer); I/O errors surface at
    /// [`WalWriter::commit`].
    pub fn append_insert(&mut self, id: u64, words: &[u64]) -> usize {
        self.append(KIND_INSERT, &[id], words)
    }

    /// Append an insert carrying a TTL deadline (absolute unix millis).
    pub fn append_insert_ttl(&mut self, id: u64, deadline: u64, words: &[u64]) -> usize {
        self.append(KIND_INSERT_TTL, &[id, deadline], words)
    }

    /// Append a trailing-row pop (rebalance source side); `move_id` pairs
    /// it with its destination `MoveIn`.
    pub fn append_move_out(&mut self, move_id: u64) -> usize {
        self.append(KIND_MOVE_OUT, &[move_id], &[])
    }

    /// Append a moved-in row (rebalance destination side).
    pub fn append_move_in(&mut self, move_id: u64, id: u64, deadline: u64, words: &[u64]) -> usize {
        self.append(KIND_MOVE_IN, &[move_id, id, deadline], words)
    }

    /// Append a delete-by-id record (explicit delete or TTL expiry).
    pub fn append_delete(&mut self, id: u64) -> usize {
        self.append(KIND_DELETE, &[id], &[])
    }

    /// Append an in-place row overwrite for `id`.
    pub fn append_upsert(&mut self, id: u64, deadline: u64, words: &[u64]) -> usize {
        self.append(KIND_UPSERT, &[id, deadline], words)
    }

    /// Append `count` pre-encoded frames verbatim (replication: a follower
    /// mirrors the primary's shipped frame bytes into its own log, so both
    /// logs stay byte-identical position-for-position). The caller must
    /// have validated the frames — [`scan_frames`] on the shipped chunk —
    /// since nothing re-checks them here.
    pub fn append_raw(&mut self, frames: &[u8], count: u64) {
        self.pending.extend_from_slice(frames);
        self.pending_frames += count;
    }

    /// Write the pending frames to the file in one shot. On failure the
    /// frames stay pending and the file is rewound to the last good frame
    /// boundary, so a retry cannot interleave torn bytes with valid
    /// frames.
    fn write_pending(&mut self) -> std::io::Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        match self.file.write_all(&self.pending) {
            Ok(()) => {
                self.file_len += self.pending.len() as u64;
                self.file_frames += self.pending_frames;
                self.pending_frames = 0;
                self.pending.clear();
                // don't let one huge rebalance batch pin megabytes forever
                if self.pending.capacity() > 1 << 20 {
                    self.pending.shrink_to(1 << 16);
                }
                self.synced = false;
                Ok(())
            }
            Err(e) => {
                // best-effort rewind; if even this fails, recovery's
                // mid-file corruption check turns the damage into a hard
                // error rather than silent loss
                let _ = self.file.set_len(self.file_len);
                let _ = self.file.seek(SeekFrom::Start(self.file_len));
                Err(e)
            }
        }
    }

    /// Arm a one-shot commit failure (see `inject_commit_error`): the next
    /// [`WalWriter::commit`] returns this message as an I/O error with the
    /// frames left pending, exactly like a real disk fault. Test support
    /// for the durability-error propagation path.
    pub fn fail_next_commit(&mut self, msg: &str) {
        self.inject_commit_error = Some(msg.to_string());
    }

    /// Make everything appended so far crash-durable per the fsync policy:
    /// write to the file always, `fdatasync` under
    /// [`FsyncPolicy::Always`]. The store calls this once per batch,
    /// before acknowledging it (directly, or through the group-commit
    /// thread when a commit window is configured).
    pub fn commit(&mut self) -> std::io::Result<()> {
        if let Some(msg) = self.inject_commit_error.take() {
            // io::Error::other — stable since 1.74, the crate MSRV
            return Err(std::io::Error::other(msg));
        }
        self.write_pending()?;
        if self.fsync == FsyncPolicy::Always && !self.synced {
            self.file.sync_data()?;
            self.synced = true;
            self.synced_frames = self.file_frames;
        }
        Ok(())
    }

    /// Write *and* fsync regardless of policy — the `flush` wire op and
    /// graceful shutdown use this to upgrade `FsyncPolicy::Never` data to
    /// durable on demand. No-op when nothing is pending or unsynced.
    pub fn sync(&mut self) -> std::io::Result<()> {
        self.write_pending()?;
        if !self.synced {
            self.file.sync_data()?;
            self.synced = true;
            self.synced_frames = self.file_frames;
        }
        Ok(())
    }

    /// Pending (uncommitted) buffer position — a watermark for
    /// [`WalWriter::rewind_pending_to`]. Stable while the caller holds
    /// this writer's mutex (appends are the only mutation).
    pub fn pending_watermark(&self) -> PendingMark {
        PendingMark {
            bytes: self.pending.len(),
            frames: self.pending_frames,
        }
    }

    /// Drop every pending frame appended after `mark`, keeping the
    /// frames buffered before it. The rebalance path uses this when the
    /// *destination* commit fails: the paired `MoveOut`s must then never
    /// become durable on their own (a later commit on the source shard
    /// would otherwise flush them, and a crash would leave the moved rows
    /// absent from both logs) — but frames buffered *before* the
    /// watermark by a concurrent group-commit insert batch are someone
    /// else's acked-pending data and must survive the rewind.
    pub fn rewind_pending_to(&mut self, mark: PendingMark) {
        debug_assert!(mark.bytes <= self.pending.len());
        debug_assert!(mark.frames <= self.pending_frames);
        self.pending.truncate(mark.bytes);
        self.pending_frames = mark.frames;
    }

    /// Frames landed in the file so far (committed, crash-visible). The
    /// next landed frame gets sequence `manifest base + file_frames`.
    pub fn file_frames(&self) -> u64 {
        self.file_frames
    }

    /// Crash-surviving frame horizon under this writer's fsync policy:
    /// with `always`, only fdatasync-covered frames count (a power loss
    /// revokes anything later); with `never`, the policy's own contract
    /// is kill -9 survival, for which landed-in-file is enough. This is
    /// the horizon replication ships against.
    pub fn durable_frames(&self) -> u64 {
        match self.fsync {
            FsyncPolicy::Always => self.synced_frames,
            FsyncPolicy::Never => self.file_frames,
        }
    }

    /// Frames buffered but not yet written to the file.
    pub fn pending_frames(&self) -> u64 {
        self.pending_frames
    }

    /// Bytes landed in the file so far — the live segment's on-disk size
    /// (`persist_wal_live_bytes`, and the `--wal-max-bytes` auto-snapshot
    /// trigger's input).
    pub fn file_len(&self) -> u64 {
        self.file_len
    }

    /// Mark this writer's segment as frozen at a snapshot rotation (it is
    /// retained one generation for follower catch-up, then GC'd): discard
    /// pending frames and suppress the drop-time fsync.
    pub fn retire(&mut self) {
        self.pending.clear();
        self.pending_frames = 0;
        self.synced = true;
    }
}

/// Opaque position in a writer's pending buffer (bytes + frames), taken
/// with [`WalWriter::pending_watermark`] and restored with
/// [`WalWriter::rewind_pending_to`].
#[derive(Clone, Copy, Debug)]
pub struct PendingMark {
    bytes: usize,
    frames: u64,
}

impl Drop for WalWriter {
    fn drop(&mut self) {
        // Best-effort durability on graceful teardown; a hard kill is the
        // case the commit-per-batch protocol already covers.
        let _ = self.sync();
    }
}

/// Result of scanning one WAL file.
pub struct WalReplay {
    pub records: Vec<WalRecord>,
    /// Byte length of the valid frame prefix. Anything past this is a torn
    /// or corrupt tail; recovery truncates the file here before reopening
    /// it for append.
    pub valid_len: u64,
    /// Whether a torn/corrupt tail was dropped.
    pub truncated: bool,
    /// Whether a *complete, checksum-valid* frame exists somewhere past
    /// the stop point. A genuinely torn tail is the prefix of one partial
    /// frame and can never contain one — so this flag distinguishes
    /// mid-file damage (bit rot inside a committed region, with good
    /// records after it) from a crash tear. Recovery treats it as a hard
    /// error instead of silently truncating away valid, acknowledged
    /// records.
    pub valid_frames_beyond_tear: bool,
    /// Byte offset just past each valid frame (`frame_ends[i]` ends
    /// `records[i]`; the last entry equals `valid_len`). Lets a consumer
    /// split a chunk at a frame boundary — the follower uses this to
    /// apply only the prefix before a not-yet-orderable `MoveOut`.
    pub frame_ends: Vec<u64>,
}

/// Expected payload size for a frame kind at `words_per_row` row width,
/// or `None` for an unknown kind — the per-kind framing truth table.
fn kind_payload(kind: u8, words_per_row: usize) -> Option<usize> {
    let row = words_per_row * 8;
    match kind {
        KIND_INSERT => Some(1 + 8 + row),
        KIND_MOVE_OUT | KIND_DELETE => Some(1 + 8),
        KIND_MOVE_IN => Some(1 + 24 + row),
        KIND_UPSERT | KIND_INSERT_TTL => Some(1 + 16 + row),
        _ => None,
    }
}

/// Validate the frame at byte offset `at`: complete, a legal payload
/// size for its kind, checksum-valid. Returns its total length (header +
/// payload) — the single source of frame-validity truth shared by
/// [`scan_frames`], [`read_wal_tail`] and the mid-file-damage probe.
fn frame_len_at(buf: &[u8], at: usize, words_per_row: usize) -> Option<usize> {
    if at + 12 > buf.len() {
        return None; // torn frame header (or clean EOF when at == len)
    }
    let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
    if len == 0 || len > 25 + words_per_row * 8 || at + 12 + len > buf.len() {
        return None; // impossible payload size, or torn payload
    }
    let payload = &buf[at + 12..at + 12 + len];
    let want = u64::from_le_bytes(buf[at + 4..at + 12].try_into().unwrap());
    if fnv1a64(payload) != want {
        return None; // checksum mismatch
    }
    (kind_payload(payload[0], words_per_row) == Some(len)).then_some(12 + len)
}

/// Decode a frame buffer, stopping (not failing) at the first torn or
/// corrupt frame. `words_per_row` fixes the only legal payload sizes, so
/// a frame with any other length is corruption by construction. Used on
/// WAL files (via [`read_wal`]) and on replication chunks a follower
/// received off the wire — the frame checksums are the transfer-integrity
/// check, and a short final frame simply stays un-applied and is
/// re-requested.
pub fn scan_frames(buf: &[u8], words_per_row: usize) -> WalReplay {
    let mut records = Vec::new();
    let mut frame_ends = Vec::new();
    let mut pos = 0usize;
    while let Some(frame_len) = frame_len_at(buf, pos, words_per_row) {
        let payload = &buf[pos + 12..pos + frame_len];
        let u64_at = |at: usize| u64::from_le_bytes(payload[at..at + 8].try_into().unwrap());
        let words_from = |at: usize| -> Vec<u64> {
            payload[at..]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        records.push(match payload[0] {
            KIND_INSERT => WalRecord::Insert {
                id: u64_at(1),
                deadline: 0,
                words: words_from(9),
            },
            KIND_INSERT_TTL => WalRecord::Insert {
                id: u64_at(1),
                deadline: u64_at(9),
                words: words_from(17),
            },
            KIND_MOVE_IN => WalRecord::MoveIn {
                move_id: u64_at(1),
                id: u64_at(9),
                deadline: u64_at(17),
                words: words_from(25),
            },
            KIND_UPSERT => WalRecord::Upsert {
                id: u64_at(1),
                deadline: u64_at(9),
                words: words_from(17),
            },
            KIND_DELETE => WalRecord::Delete { id: u64_at(1) },
            _ => WalRecord::MoveOut { move_id: u64_at(1) },
        });
        pos += frame_len;
        frame_ends.push(pos as u64);
    }
    let truncated = pos < buf.len();
    let valid_frames_beyond_tear = truncated
        && (pos + 1..buf.len()).any(|at| frame_len_at(buf, at, words_per_row).is_some());
    WalReplay {
        records,
        valid_len: pos as u64,
        truncated,
        valid_frames_beyond_tear,
        frame_ends,
    }
}

/// Scan a WAL file — [`scan_frames`] over its contents.
pub fn read_wal(path: &Path, words_per_row: usize) -> std::io::Result<WalReplay> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    Ok(scan_frames(&buf, words_per_row))
}

/// A frame range served to a follower: raw frame bytes (still
/// length-prefixed and checksummed — the follower validates them with
/// [`scan_frames`]) plus position bookkeeping.
pub struct WalTail {
    /// Raw bytes of the served frames (a whole-frame prefix starting at
    /// frame index `skip`).
    pub bytes: Vec<u8>,
    /// Frames in `bytes`.
    pub frames: u64,
    /// Total valid frames in the file — `base + file_frames` is the
    /// segment's live sequence horizon.
    pub file_frames: u64,
    /// Frame index just past the served range (`skip + frames`, clamped to
    /// the file), paired with `end_offset` — the resume point a caller can
    /// cache and pass back as `hint` to skip re-scanning the prefix.
    pub end_frame: u64,
    /// Byte offset of the frame at index `end_frame`.
    pub end_offset: u64,
}

/// Read frames `[skip, …)` of a WAL file, bounded by `max_bytes` (always
/// at least one frame when any is available past `skip` and `max_frames`
/// allows it) and by `max_frames` — the shipper passes the shard's
/// durable-frame horizon there, so frames written but not yet fsynced are
/// never served. Counts the file's full valid-frame total even after the
/// budgets are exhausted, so the caller can report the file horizon.
/// Concurrent appends are safe: a frame is either wholly present and
/// checksum-valid or the scan stops before it.
///
/// `hint`, when given, is a `(frame_index, byte_offset)` pair previously
/// returned as `(end_frame, end_offset)` for the *same* (append-only)
/// file: scanning starts there instead of at byte 0, making a steady-state
/// tail request O(chunk) instead of O(segment). A hint past `skip` or past
/// the file is ignored (full rescan) rather than trusted.
pub fn read_wal_tail(
    path: &Path,
    words_per_row: usize,
    skip: u64,
    max_bytes: usize,
    max_frames: u64,
    hint: Option<(u64, u64)>,
) -> std::io::Result<WalTail> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    let (mut file_frames, mut pos) = match hint {
        Some((frame, offset)) if frame <= skip && offset <= buf.len() as u64 => {
            (frame, offset as usize)
        }
        _ => (0, 0),
    };
    let mut bytes = Vec::new();
    let mut frames = 0u64;
    let (mut end_frame, mut end_offset) = (file_frames, pos as u64);
    while let Some(frame_len) = frame_len_at(&buf, pos, words_per_row) {
        if file_frames < skip {
            // pre-window frame: advance the resume point toward `skip`
            (end_frame, end_offset) = (file_frames + 1, (pos + frame_len) as u64);
        } else if bytes.len() < max_bytes && frames < max_frames {
            bytes.extend_from_slice(&buf[pos..pos + frame_len]);
            frames += 1;
            (end_frame, end_offset) = (file_frames + 1, (pos + frame_len) as u64);
        }
        file_frames += 1;
        pos += frame_len;
    }
    Ok(WalTail {
        bytes,
        frames,
        file_frames,
        end_frame,
        end_offset,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;

    fn roundtrip(dir: &TempDir, fsync: FsyncPolicy) -> WalReplay {
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, fsync).unwrap();
        w.append_insert(0, &[0xAB, 0xCD]);
        w.append_insert(1, &[0x11, 0x22]);
        w.append_move_out(3);
        w.append_move_in(3, 7, 0, &[0x33, 0x44]);
        w.append_insert_ttl(8, 1_234, &[0x55, 0x66]);
        w.append_upsert(1, 9_000, &[0x77, 0x88]);
        w.append_delete(0);
        w.commit().unwrap();
        read_wal(&path, 2).unwrap()
    }

    #[test]
    fn records_roundtrip() {
        let dir = TempDir::new("wal-roundtrip");
        let replay = roundtrip(&dir, FsyncPolicy::Never);
        assert!(!replay.truncated);
        assert_eq!(
            replay.records,
            vec![
                WalRecord::Insert {
                    id: 0,
                    deadline: 0,
                    words: vec![0xAB, 0xCD],
                },
                WalRecord::Insert {
                    id: 1,
                    deadline: 0,
                    words: vec![0x11, 0x22],
                },
                WalRecord::MoveOut { move_id: 3 },
                WalRecord::MoveIn {
                    move_id: 3,
                    id: 7,
                    deadline: 0,
                    words: vec![0x33, 0x44],
                },
                WalRecord::Insert {
                    id: 8,
                    deadline: 1_234,
                    words: vec![0x55, 0x66],
                },
                WalRecord::Upsert {
                    id: 1,
                    deadline: 9_000,
                    words: vec![0x77, 0x88],
                },
                WalRecord::Delete { id: 0 },
            ]
        );
    }

    #[test]
    fn fsync_always_also_roundtrips() {
        let dir = TempDir::new("wal-fsync");
        let replay = roundtrip(&dir, FsyncPolicy::Always);
        assert_eq!(replay.records.len(), 7);
    }

    #[test]
    fn insert_frames_keep_the_pre_mutation_byte_layout() {
        // kind-1 frames are pinned: 12-byte header + [kind][u64 id][words]
        let dir = TempDir::new("wal-pinned");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        let frame = w.append_insert(5, &[0xDEAD, 0xBEEF]);
        assert_eq!(frame, 12 + 1 + 8 + 16);
        w.commit().unwrap();
        drop(w);
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 12 + 1 + 8 + 16);
        assert_eq!(bytes[12], 1, "kind byte");
        assert_eq!(u64::from_le_bytes(bytes[13..21].try_into().unwrap()), 5);
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let dir = TempDir::new("wal-torn");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        w.append_insert(0, &[1, 2]);
        w.append_insert(1, &[3, 4]);
        w.commit().unwrap();
        drop(w);
        let full = std::fs::metadata(&path).unwrap().len();
        // tear the final frame mid-payload
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 5).unwrap();
        drop(f);
        let replay = read_wal(&path, 2).unwrap();
        assert!(replay.truncated);
        assert_eq!(replay.records.len(), 1);
        assert_eq!(replay.valid_len, (12 + 1 + 8 + 16) as u64);
        // truncate to the valid prefix and keep appending: log stays whole
        OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(replay.valid_len)
            .unwrap();
        let mut w = WalWriter::open_append(&path, FsyncPolicy::Never, 1).unwrap();
        assert_eq!(w.file_frames(), 1);
        w.append_insert(2, &[5, 6]);
        w.commit().unwrap();
        assert_eq!(w.file_frames(), 2);
        drop(w);
        let replay = read_wal(&path, 2).unwrap();
        assert!(!replay.truncated);
        assert_eq!(replay.records.len(), 2);
        assert_eq!(
            replay.records[1],
            WalRecord::Insert {
                id: 2,
                words: vec![5, 6],
            }
        );
    }

    #[test]
    fn corrupt_checksum_stops_replay() {
        let dir = TempDir::new("wal-corrupt");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        w.append_insert(0, &[1]);
        w.append_insert(1, &[2]);
        w.commit().unwrap();
        drop(w);
        // flip one payload byte of the second frame
        let mut bytes = std::fs::read(&path).unwrap();
        let second = 12 + 1 + 8 + 8; // first frame
        bytes[second + 12 + 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let replay = read_wal(&path, 1).unwrap();
        assert!(replay.truncated);
        assert_eq!(replay.records.len(), 1);
        assert_eq!(replay.valid_len, second as u64);
    }

    #[test]
    fn mid_file_corruption_is_distinguished_from_a_torn_tail() {
        let dir = TempDir::new("wal-midfile");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for id in 0..3 {
            w.append_insert(id, &[id + 10]);
        }
        w.commit().unwrap();
        drop(w);
        // flip a payload byte of the FIRST frame: frames 2 and 3 are still
        // intact past the damage, so this must read as mid-file corruption
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12 + 5] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let replay = read_wal(&path, 1).unwrap();
        assert!(replay.truncated);
        assert_eq!(replay.valid_len, 0);
        assert!(replay.valid_frames_beyond_tear, "intact later frames not seen");
        // whereas a genuine tail tear (prefix of one partial frame) is not:
        // rebuild a clean log, then tear its final frame
        let mut clean = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        clean.append_insert(0, &[1]);
        clean.append_insert(1, &[2]);
        clean.commit().unwrap();
        drop(clean);
        let full = std::fs::metadata(&path).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(full - 4)
            .unwrap();
        let replay = read_wal(&path, 1).unwrap();
        assert!(replay.truncated);
        assert!(!replay.valid_frames_beyond_tear);
        assert_eq!(replay.records.len(), 1);
    }

    #[test]
    fn rewind_pending_drops_only_frames_past_the_watermark() {
        // the rebalance failure path: an insert batch's frames are already
        // pending (group commit), then move-outs are appended and must be
        // rewound alone — the insert frames stay and commit later
        let dir = TempDir::new("wal-rewind");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        w.append_insert(0, &[7, 8]); // concurrent batch's acked-pending frame
        let mark = w.pending_watermark();
        w.append_move_out(1);
        w.append_move_out(2);
        w.rewind_pending_to(mark);
        w.commit().unwrap();
        drop(w);
        let replay = read_wal(&path, 2).unwrap();
        assert_eq!(
            replay.records,
            vec![WalRecord::Insert {
                id: 0,
                deadline: 0,
                words: vec![7, 8],
            }]
        );
    }

    #[test]
    fn injected_commit_failure_is_one_shot_and_preserves_frames() {
        let dir = TempDir::new("wal-inject");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        w.append_insert(0, &[1, 2]);
        w.fail_next_commit("synthetic fault");
        let err = w.commit().unwrap_err();
        assert!(err.to_string().contains("synthetic fault"));
        // frames stayed pending; the retry lands them intact
        w.commit().unwrap();
        drop(w);
        let replay = read_wal(&path, 2).unwrap();
        assert_eq!(replay.records.len(), 1);
        assert!(!replay.truncated);
    }

    #[test]
    fn empty_file_replays_empty() {
        let dir = TempDir::new("wal-empty");
        let path = dir.path().join("shard-0.wal");
        let w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        drop(w);
        let replay = read_wal(&path, 4).unwrap();
        assert!(replay.records.is_empty());
        assert!(!replay.truncated);
        assert_eq!(replay.valid_len, 0);
    }

    #[test]
    fn frame_counters_track_appends_and_commits() {
        let dir = TempDir::new("wal-frames");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        assert_eq!((w.file_frames(), w.pending_frames()), (0, 0));
        w.append_insert(0, &[1, 2]);
        w.append_move_out(1);
        assert_eq!((w.file_frames(), w.pending_frames()), (0, 2));
        w.commit().unwrap();
        assert_eq!((w.file_frames(), w.pending_frames()), (2, 0));
        assert_eq!(w.file_len(), std::fs::metadata(&path).unwrap().len());
        w.append_insert(1, &[3, 4]);
        let mark = w.pending_watermark();
        w.append_move_out(2);
        w.rewind_pending_to(mark);
        assert_eq!(w.pending_frames(), 1);
        w.commit().unwrap();
        assert_eq!(w.file_frames(), 3);
    }

    #[test]
    fn durable_frames_track_the_fsync_horizon() {
        let dir = TempDir::new("wal-durable");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Always).unwrap();
        w.append_insert(0, &[1]);
        assert_eq!(w.durable_frames(), 0, "pending frames are not durable");
        w.commit().unwrap();
        assert_eq!(w.durable_frames(), 1);
        // a failed commit leaves the horizon untouched; the retry advances it
        w.append_insert(1, &[2]);
        w.fail_next_commit("fault");
        assert!(w.commit().is_err());
        assert_eq!(w.durable_frames(), 1);
        w.commit().unwrap();
        assert_eq!(w.durable_frames(), 2);
        drop(w);
        // reopen: the recovered prefix is the crash-surviving state
        let w = WalWriter::open_append(&path, FsyncPolicy::Always, 2).unwrap();
        assert_eq!(w.durable_frames(), 2);
        // under `never`, landed-in-file is the policy's own contract
        let mut n = WalWriter::create(&dir.path().join("n.wal"), FsyncPolicy::Never).unwrap();
        n.append_insert(0, &[1]);
        n.commit().unwrap();
        assert_eq!(n.durable_frames(), 1);
    }

    #[test]
    fn read_wal_tail_honours_the_frame_budget() {
        // the shipper caps tails at the durable horizon via max_frames
        let dir = TempDir::new("wal-tail-budget");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for id in 0..4u64 {
            w.append_insert(id, &[id]);
        }
        w.commit().unwrap();
        drop(w);
        let tail = read_wal_tail(&path, 1, 1, usize::MAX, 2, None).unwrap();
        assert_eq!((tail.frames, tail.file_frames), (2, 4));
        let replay = scan_frames(&tail.bytes, 1);
        assert_eq!(replay.records.len(), 2);
        assert_eq!(
            replay.records[0],
            WalRecord::Insert {
                id: 1,
                deadline: 0,
                words: vec![1],
            }
        );
        let tail = read_wal_tail(&path, 1, 0, usize::MAX, 0, None).unwrap();
        assert_eq!((tail.frames, tail.file_frames), (0, 4));
    }

    #[test]
    fn append_raw_mirrors_shipped_frames_exactly() {
        // a follower appends the primary's frame bytes verbatim: both
        // files must be byte-identical and replay identically
        let dir = TempDir::new("wal-raw");
        let primary = dir.path().join("primary.wal");
        let mut w = WalWriter::create(&primary, FsyncPolicy::Never).unwrap();
        w.append_insert(3, &[0xAA, 0xBB]);
        w.append_move_out(11);
        w.commit().unwrap();
        drop(w);
        let tail = read_wal_tail(&primary, 2, 0, usize::MAX, u64::MAX, None).unwrap();
        assert_eq!(tail.frames, 2);
        assert_eq!(tail.file_frames, 2);
        let follower = dir.path().join("follower.wal");
        let mut f = WalWriter::create(&follower, FsyncPolicy::Never).unwrap();
        f.append_raw(&tail.bytes, tail.frames);
        assert_eq!(f.pending_frames(), 2);
        f.commit().unwrap();
        assert_eq!(f.file_frames(), 2);
        drop(f);
        assert_eq!(
            std::fs::read(&primary).unwrap(),
            std::fs::read(&follower).unwrap()
        );
        let replay = read_wal(&follower, 2).unwrap();
        assert_eq!(replay.records.len(), 2);
    }

    #[test]
    fn read_wal_tail_skips_and_bounds() {
        let dir = TempDir::new("wal-tail");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for id in 0..5u64 {
            w.append_insert(id, &[id + 1]);
        }
        w.commit().unwrap();
        drop(w);
        let frame = 12 + 1 + 8 + 8;
        // skip 2, unbounded: frames 2..5
        let tail = read_wal_tail(&path, 1, 2, usize::MAX, u64::MAX, None).unwrap();
        assert_eq!((tail.frames, tail.file_frames), (3, 5));
        assert_eq!((tail.end_frame, tail.end_offset), (5, 5 * frame as u64));
        let replay = scan_frames(&tail.bytes, 1);
        assert!(!replay.truncated);
        assert_eq!(
            replay.records[0],
            WalRecord::Insert {
                id: 2,
                deadline: 0,
                words: vec![3],
            }
        );
        // a 1-byte budget still serves exactly one whole frame
        let tail = read_wal_tail(&path, 1, 0, 1, u64::MAX, None).unwrap();
        assert_eq!(tail.frames, 1);
        assert_eq!(tail.bytes.len(), frame);
        assert_eq!(tail.file_frames, 5, "budget must not hide the horizon");
        assert_eq!((tail.end_frame, tail.end_offset), (1, frame as u64));
        // a budget of two frames serves two
        let tail = read_wal_tail(&path, 1, 1, 2 * frame, u64::MAX, None).unwrap();
        assert_eq!(tail.frames, 2);
        // skip at/past the end: nothing to serve, horizon still reported
        let tail = read_wal_tail(&path, 1, 5, usize::MAX, u64::MAX, None).unwrap();
        assert_eq!((tail.frames, tail.file_frames), (0, 5));
        let tail = read_wal_tail(&path, 1, 99, usize::MAX, u64::MAX, None).unwrap();
        assert_eq!((tail.frames, tail.file_frames), (0, 5));
    }

    #[test]
    fn read_wal_tail_resumes_from_a_cached_offset() {
        let dir = TempDir::new("wal-tail-hint");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        for id in 0..6u64 {
            w.append_insert(id, &[id + 1]);
        }
        w.commit().unwrap();
        drop(w);
        let frame = (12 + 1 + 8 + 8) as u64;
        // first pull: frames [0, 3) — returns the resume point
        let first = read_wal_tail(&path, 1, 0, 3 * frame as usize, u64::MAX, None).unwrap();
        assert_eq!(first.frames, 3);
        assert_eq!((first.end_frame, first.end_offset), (3, 3 * frame));
        // second pull continues from the hint: identical to a full rescan
        let hint = Some((first.end_frame, first.end_offset));
        let hinted = read_wal_tail(&path, 1, 3, usize::MAX, u64::MAX, hint).unwrap();
        let scanned = read_wal_tail(&path, 1, 3, usize::MAX, u64::MAX, None).unwrap();
        assert_eq!(hinted.bytes, scanned.bytes);
        assert_eq!(hinted.frames, 3);
        assert_eq!(hinted.file_frames, scanned.file_frames);
        assert_eq!((hinted.end_frame, hinted.end_offset), (6, 6 * frame));
        // a hint past the requested skip is ignored, not trusted
        let back = read_wal_tail(&path, 1, 1, usize::MAX, u64::MAX, hint).unwrap();
        assert_eq!(back.frames, 5);
        assert_eq!(back.bytes, read_wal_tail(&path, 1, 1, usize::MAX, u64::MAX, None).unwrap().bytes);
        // a hint past the file end is ignored too
        let bogus = read_wal_tail(&path, 1, 0, usize::MAX, u64::MAX, Some((0, 1 << 30))).unwrap();
        assert_eq!(bogus.frames, 6);
        // caught-up: hint at EOF serves nothing and stays put
        let eof = read_wal_tail(&path, 1, 6, usize::MAX, u64::MAX, Some((6, 6 * frame))).unwrap();
        assert_eq!((eof.frames, eof.end_frame, eof.end_offset), (0, 6, 6 * frame));
    }

    #[test]
    fn scan_frames_on_a_short_transfer_keeps_the_valid_prefix() {
        // a chunk cut mid-frame (connection drop) applies only whole
        // frames; the remainder is re-requested by sequence
        let dir = TempDir::new("wal-shortxfer");
        let path = dir.path().join("shard-0.wal");
        let mut w = WalWriter::create(&path, FsyncPolicy::Never).unwrap();
        w.append_insert(0, &[7]);
        w.append_insert(1, &[8]);
        w.commit().unwrap();
        drop(w);
        let tail = read_wal_tail(&path, 1, 0, usize::MAX, u64::MAX, None).unwrap();
        let cut = &tail.bytes[..tail.bytes.len() - 4];
        let replay = scan_frames(cut, 1);
        assert!(replay.truncated);
        assert_eq!(replay.records.len(), 1);
        assert_eq!(replay.valid_len, (12 + 1 + 8 + 8) as u64);
    }

    #[test]
    fn fnv_is_stable() {
        // pinned so on-disk logs stay readable across refactors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
