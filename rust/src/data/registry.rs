//! Registry of the paper's six evaluation datasets (Table 1) and the
//! generator parameters of their synthetic *statistical twins*.
//!
//! The real corpora (UCI bag-of-words + 10x Genomics Brain Cell) are not
//! reachable offline; each [`DatasetSpec`] records the Table 1 targets —
//! (categories, dimension, sparsity, density, #points) — and a twin is
//! synthesised to match them (see `synth`). `repro table1` prints target vs
//! measured so the substitution is auditable. If the real files are placed
//! under `data/uci/`, `load_or_synth` picks them up instead.

use super::categorical::CategoricalDataset;
use super::synth::SynthSpec;

/// One row of Table 1 plus twin-generation parameters.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Short key used on the CLI (`kos`, `nips`, …).
    pub key: &'static str,
    /// Paper's display name.
    pub name: &'static str,
    /// Table 1 "Categories" column (max word frequency used as category).
    pub categories: u16,
    /// Table 1 "Dimension" (vocabulary / #cells).
    pub dimension: usize,
    /// Table 1 "Sparsity" (%).
    pub sparsity_pct: f64,
    /// Table 1 "Density" (max Hamming weight = the paper's `s`).
    pub density: usize,
    /// Table 1 "Number of points".
    pub points: usize,
    /// UCI `docword.<key>.txt` basename when real data is available.
    pub uci_basename: Option<&'static str>,
}

/// The six rows of Table 1.
pub const TABLE1: [DatasetSpec; 6] = [
    DatasetSpec {
        key: "kos",
        name: "KOS blog entries",
        categories: 42,
        dimension: 6906,
        sparsity_pct: 93.38,
        density: 457,
        points: 3430,
        uci_basename: Some("docword.kos.txt"),
    },
    DatasetSpec {
        key: "nips",
        name: "NIPS full papers",
        categories: 132,
        dimension: 12419,
        sparsity_pct: 92.64,
        density: 914,
        points: 1500,
        uci_basename: Some("docword.nips.txt"),
    },
    DatasetSpec {
        key: "enron",
        name: "Enron Emails",
        categories: 150,
        dimension: 28102,
        sparsity_pct: 92.81,
        density: 2021,
        points: 39861,
        uci_basename: Some("docword.enron.txt"),
    },
    DatasetSpec {
        key: "nytimes",
        name: "NYTimes articles",
        categories: 114,
        dimension: 102_660,
        sparsity_pct: 99.15,
        density: 871,
        points: 10_000,
        uci_basename: Some("docword.nytimes.txt"),
    },
    DatasetSpec {
        key: "pubmed",
        name: "PubMed abstracts",
        categories: 47,
        dimension: 141_043,
        sparsity_pct: 99.86,
        density: 199,
        points: 10_000,
        uci_basename: Some("docword.pubmed.txt"),
    },
    DatasetSpec {
        key: "braincell",
        name: "Million Brain Cells, E18 Mice",
        categories: 2036,
        dimension: 1_306_127,
        sparsity_pct: 99.92,
        density: 1051,
        points: 2000,
        uci_basename: None,
    },
];

impl DatasetSpec {
    pub fn by_key(key: &str) -> Option<&'static DatasetSpec> {
        TABLE1.iter().find(|s| s.key == key)
    }

    /// Mean density implied by Table 1's sparsity column (the density
    /// column is the max).
    pub fn mean_density_target(&self) -> f64 {
        // Sparsity in Table 1 is dataset sparsity ≈ (1 - max density / n);
        // mean density is lower. We target mean ≈ 55% of max (typical BoW
        // skew) but never above the sparsity-implied bound.
        let bound = (1.0 - self.sparsity_pct / 100.0) * self.dimension as f64;
        (0.55 * self.density as f64).min(bound.max(1.0))
    }

    /// Synthesis parameters for this dataset's twin.
    pub fn synth_spec(&self, num_points: usize) -> SynthSpec {
        SynthSpec {
            name: self.name.to_string(),
            dim: self.dimension,
            num_points,
            num_categories: self.categories,
            max_density: self.density,
            mean_density: self.mean_density_target(),
            zipf_alpha: 1.05,
            topics: 10,
            topic_sharpness: 0.75,
        }
    }

    /// Load the real dataset if present under `data_dir`, else synthesise a
    /// twin capped at `num_points` points.
    pub fn load_or_synth(&self, data_dir: &str, num_points: usize, seed: u64) -> CategoricalDataset {
        if let Some(base) = self.uci_basename {
            let path = format!("{}/{}", data_dir, base);
            if std::path::Path::new(&path).exists() {
                if let Ok(ds) = super::bow::load_docword(&path, self.categories, Some(num_points)) {
                    return ds;
                }
            }
        }
        self.synth_spec(num_points.min(self.points)).generate(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert_eq!(DatasetSpec::by_key("kos").unwrap().dimension, 6906);
        assert!(DatasetSpec::by_key("nope").is_none());
        assert_eq!(TABLE1.len(), 6);
    }

    #[test]
    fn mean_density_below_max() {
        for s in &TABLE1 {
            let m = s.mean_density_target();
            assert!(m > 0.0 && m <= s.density as f64, "{}: {}", s.key, m);
        }
    }

    #[test]
    fn twin_matches_table1_stats() {
        // Generate a small twin of KOS and check the Table 1 columns the
        // algorithms actually depend on.
        let spec = DatasetSpec::by_key("kos").unwrap();
        let ds = spec.synth_spec(300).generate(42);
        assert_eq!(ds.dim(), spec.dimension);
        assert_eq!(ds.len(), 300);
        assert!(ds.num_categories() <= spec.categories);
        // max density within 15% of target
        let md = ds.max_density() as f64;
        assert!(
            (md - spec.density as f64).abs() < 0.15 * spec.density as f64,
            "max density {} target {}",
            md,
            spec.density
        );
        // sparsity at least Table-1-ish
        assert!(ds.sparsity() > 0.90, "sparsity {}", ds.sparsity());
    }
}
