//! Parser for the UCI Bag-of-Words `docword.*.txt` format [26], so the real
//! Table 1 corpora can be dropped in when network access exists.
//!
//! Format:
//! ```text
//! D            # number of documents
//! W            # vocabulary size
//! NNZ          # number of (doc, word, count) triples
//! docID wordID count
//! ...
//! ```
//! IDs are 1-based. Counts become categorical values, capped at the
//! dataset's category bound (the paper treats word frequencies as
//! categories).

use super::categorical::{CatVector, CategoricalDataset};
use anyhow::{Context, Result, bail};
use std::io::{BufRead, BufReader};

/// Load a `docword` file. `max_points` truncates to the first N documents
/// (the paper subsamples NYTimes/PubMed to 10k points the same way).
pub fn load_docword(
    path: &str,
    category_cap: u16,
    max_points: Option<usize>,
) -> Result<CategoricalDataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path))?;
    let mut lines = BufReader::new(f).lines();

    let mut header = |what: &str| -> Result<usize> {
        lines
            .next()
            .transpose()?
            .with_context(|| format!("missing header line: {}", what))?
            .trim()
            .parse::<usize>()
            .with_context(|| format!("bad header {}", what))
    };
    let n_docs = header("D")?;
    let vocab = header("W")?;
    let _nnz = header("NNZ")?;
    if vocab == 0 || n_docs == 0 {
        bail!("empty docword file");
    }

    let keep = max_points.unwrap_or(n_docs).min(n_docs);
    let mut buf: Vec<Vec<(u32, u16)>> = vec![Vec::new(); keep];
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let doc: usize = it.next().context("doc id")?.parse()?;
        let word: usize = it.next().context("word id")?.parse()?;
        let count: u64 = it.next().context("count")?.parse()?;
        if doc == 0 || doc > n_docs || word == 0 || word > vocab {
            bail!("id out of range: doc={} word={}", doc, word);
        }
        if doc > keep {
            continue;
        }
        let v = count.min(category_cap as u64).max(1) as u16;
        buf[doc - 1].push((word as u32 - 1, v));
    }

    let points: Vec<CatVector> = buf
        .into_iter()
        .map(|pairs| CatVector::from_pairs(vocab, pairs))
        .collect();
    let name = std::path::Path::new(path)
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "docword".into());
    Ok(CategoricalDataset::new(&name, vocab, category_cap, points))
}

/// Write a dataset in `docword` format (used to round-trip-test the parser
/// and to export synthetic twins for external tools).
pub fn save_docword(ds: &CategoricalDataset, path: &str) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let nnz: usize = ds.points.iter().map(|p| p.nnz()).sum();
    writeln!(f, "{}", ds.len())?;
    writeln!(f, "{}", ds.dim())?;
    writeln!(f, "{}", nnz)?;
    for (di, p) in ds.points.iter().enumerate() {
        for &(w, v) in p.entries() {
            writeln!(f, "{} {} {}", di + 1, w + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn roundtrip() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 20;
        spec.dim = 500;
        let ds = spec.generate(9);
        let path = std::env::temp_dir().join("cabin_test_docword.txt");
        let path = path.to_str().unwrap();
        save_docword(&ds, path).unwrap();
        let back = load_docword(path, spec.num_categories, None).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.dim(), ds.dim());
        for (a, b) in ds.points.iter().zip(back.points.iter()) {
            assert_eq!(a, b);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn truncation() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 10;
        spec.dim = 200;
        let ds = spec.generate(2);
        let path = std::env::temp_dir().join("cabin_test_docword2.txt");
        let path = path.to_str().unwrap();
        save_docword(&ds, path).unwrap();
        let back = load_docword(path, spec.num_categories, Some(4)).unwrap();
        assert_eq!(back.len(), 4);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn category_cap_applies() {
        let dir = std::env::temp_dir().join("cabin_test_docword3.txt");
        let path = dir.to_str().unwrap();
        std::fs::write(path, "1\n5\n2\n1 1 999\n1 3 2\n").unwrap();
        let ds = load_docword(path, 10, None).unwrap();
        assert_eq!(ds.points[0].get(0), 10); // capped
        assert_eq!(ds.points[0].get(2), 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_malformed() {
        let dir = std::env::temp_dir().join("cabin_test_docword4.txt");
        let path = dir.to_str().unwrap();
        std::fs::write(path, "1\n5\n1\n9 1 1\n").unwrap(); // doc out of range
        assert!(load_docword(path, 10, None).is_err());
        let _ = std::fs::remove_file(path);
    }
}
