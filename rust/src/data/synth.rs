//! Synthetic statistical twins of the paper's datasets.
//!
//! Each twin is a topic-mixture bag-of-words generator: documents draw a
//! dominant topic, words are drawn from a Zipf-distributed vocabulary whose
//! ranks are permuted per topic (so documents of the same topic share
//! vocabulary — giving the cluster structure Figures 6–9 measure), and word
//! frequencies (the categorical values) follow a geometric-ish distribution
//! capped at `num_categories` (matching Table 1's "Categories" column —
//! which for the BoW datasets is the maximum word frequency).
//!
//! Calibration targets per `DatasetSpec`: dimension, number of points, max
//! density (`s`), mean density, category cap. `repro table1` audits the
//! result against Table 1.

use super::categorical::{CatVector, CategoricalDataset};
use crate::util::rng::{Xoshiro256, Zipf};

/// Generator parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    /// Vocabulary size `n`.
    pub dim: usize,
    pub num_points: usize,
    /// Max categorical value `c` (word-frequency cap).
    pub num_categories: u16,
    /// Target maximum density (Table 1 "Density" = the paper's `s`).
    pub max_density: usize,
    /// Target mean density.
    pub mean_density: f64,
    /// Zipf exponent of the base vocabulary distribution.
    pub zipf_alpha: f64,
    /// Number of latent topics (cluster structure for Figures 6–9).
    pub topics: usize,
    /// Fraction of a document's words drawn from its own topic (the rest
    /// from the global distribution). 0 = no cluster structure.
    pub topic_sharpness: f64,
}

impl SynthSpec {
    /// A tiny spec for doctests / examples.
    pub fn small_demo() -> SynthSpec {
        SynthSpec {
            name: "demo".into(),
            dim: 10_000,
            num_points: 64,
            num_categories: 64,
            max_density: 120,
            mean_density: 90.0,
            zipf_alpha: 1.05,
            topics: 4,
            topic_sharpness: 0.7,
        }
    }

    /// Generate the dataset (deterministic in `seed`). Also returns the
    /// latent topic of each document through
    /// [`CategoricalDataset::points`]-aligned labels when requested via
    /// [`SynthSpec::generate_labeled`].
    pub fn generate(&self, seed: u64) -> CategoricalDataset {
        self.generate_labeled(seed).0
    }

    /// Generate dataset + latent topic labels (used as an auxiliary sanity
    /// signal for clustering experiments; the paper's protocol uses k-mode
    /// on the full data as ground truth, which we follow).
    pub fn generate_labeled(&self, seed: u64) -> (CategoricalDataset, Vec<usize>) {
        assert!(self.dim > 0 && self.num_points > 0 && self.num_categories > 0);
        let mut rng = Xoshiro256::new(seed);
        let zipf = Zipf::new(self.dim, self.zipf_alpha);

        // Per-topic vocabulary permutation: topic t remaps Zipf rank r to a
        // topic-specific word id. Use an affine map (cheap, collision-free).
        let topic_offsets: Vec<usize> = (0..self.topics.max(1))
            .map(|_| rng.gen_range(self.dim as u64) as usize)
            .collect();
        let topic_strides: Vec<usize> = (0..self.topics.max(1))
            .map(|_| {
                // odd stride coprime with dim not guaranteed; use 2k+1 and
                // accept rare collisions (values overwrite, fine for BoW)
                1 + 2 * (rng.gen_range((self.dim / 2).max(1) as u64) as usize)
            })
            .collect();

        // Document length distribution: lognormal-ish via exp(normal),
        // scaled so the mean hits mean_density and clamped to max_density.
        let sigma: f64 = 0.6;
        let mu = self.mean_density.max(2.0).ln() - sigma * sigma / 2.0;

        let mut points = Vec::with_capacity(self.num_points);
        let mut labels = Vec::with_capacity(self.num_points);
        let mut saw_max = 0usize;
        for doc in 0..self.num_points {
            let topic = doc % self.topics.max(1);
            labels.push(topic);
            let mut len = (mu + sigma * rng.normal()).exp().round() as usize;
            // Force the density ceiling to actually be realised: a handful
            // of documents get exactly max_density words.
            if doc < 3 {
                len = self.max_density;
            }
            len = len.clamp(1, self.max_density);

            let mut pairs: Vec<(u32, u16)> = Vec::with_capacity(len);
            let mut used = std::collections::HashSet::with_capacity(len * 2);
            let mut attempts = 0usize;
            while pairs.len() < len && attempts < len * 30 {
                attempts += 1;
                let rank = zipf.sample(&mut rng);
                let word = if rng.bernoulli(self.topic_sharpness) {
                    (topic_offsets[topic] + rank * topic_strides[topic]) % self.dim
                } else {
                    rank
                };
                if !used.insert(word) {
                    continue;
                }
                // frequency (categorical value): geometric, capped at c
                let mut f = 1u16;
                while f < self.num_categories && rng.bernoulli(0.35) {
                    f += 1;
                }
                pairs.push((word as u32, f));
            }
            saw_max = saw_max.max(pairs.len());
            points.push(CatVector::from_pairs(self.dim, pairs));
        }
        let _ = saw_max;
        (
            CategoricalDataset::new(&self.name, self.dim, self.num_categories, points),
            labels,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = SynthSpec::small_demo();
        let a = spec.generate(7);
        let b = spec.generate(7);
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x, y);
        }
        let c = spec.generate(8);
        assert_ne!(a.points[0], c.points[0]);
    }

    #[test]
    fn respects_caps() {
        let spec = SynthSpec::small_demo();
        let ds = spec.generate(1);
        assert_eq!(ds.len(), spec.num_points);
        assert_eq!(ds.dim(), spec.dim);
        assert!(ds.max_density() <= spec.max_density);
        assert_eq!(ds.max_density(), spec.max_density); // forced by doc<3
        for p in &ds.points {
            assert!(p.entries().iter().all(|&(_, v)| v >= 1 && v <= spec.num_categories));
        }
    }

    #[test]
    fn mean_density_near_target() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 400;
        let ds = spec.generate(3);
        let mean = ds.mean_density();
        assert!(
            (mean - spec.mean_density).abs() < 0.35 * spec.mean_density,
            "mean {} target {}",
            mean,
            spec.mean_density
        );
    }

    #[test]
    fn topic_structure_exists() {
        // Same-topic documents should be closer (in Hamming) than
        // cross-topic on average.
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 80;
        spec.topic_sharpness = 0.9;
        let (ds, labels) = spec.generate_labeled(5);
        let mut same = (0.0, 0usize);
        let mut diff = (0.0, 0usize);
        for i in 0..ds.len() {
            for j in (i + 1)..ds.len() {
                let h = ds.points[i].hamming(&ds.points[j]) as f64;
                if labels[i] == labels[j] {
                    same = (same.0 + h, same.1 + 1);
                } else {
                    diff = (diff.0 + h, diff.1 + 1);
                }
            }
        }
        let same_mean = same.0 / same.1 as f64;
        let diff_mean = diff.0 / diff.1 as f64;
        assert!(
            same_mean < diff_mean,
            "same {} !< diff {}",
            same_mean,
            diff_mean
        );
    }
}
