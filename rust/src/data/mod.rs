//! Categorical-data substrate: vector representation, dataset containers,
//! the UCI bag-of-words on-disk format, and synthetic *statistical twins*
//! of the paper's six datasets (Table 1) for offline reproduction.

pub mod bow;
pub mod categorical;
pub mod registry;
pub mod synth;

pub use categorical::{CatVector, CategoricalDataset};
pub use registry::{DatasetSpec, TABLE1};
