//! Label-encoded categorical vectors (`u ∈ {0,1,…,c}^n`), stored sparsely.
//!
//! `0` encodes a *missing* feature (paper Section 1). With the paper's
//! datasets at 92–99.9% sparsity, a sorted `(index, value)` list is the only
//! sensible representation; Hamming distance is a sorted merge over the two
//! nonzero lists — `O(nnz(u) + nnz(v))` instead of `O(n)`.

use crate::util::rng::Xoshiro256;

/// A sparse categorical vector. Invariants: entries sorted by index,
/// indices unique and `< dim`, values `≥ 1` (zero = missing = absent).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CatVector {
    dim: usize,
    entries: Vec<(u32, u16)>,
}

impl CatVector {
    /// Build from raw (index, value) pairs; sorts, deduplicates (last value
    /// wins) and drops zeros.
    pub fn from_pairs(dim: usize, mut pairs: Vec<(u32, u16)>) -> Self {
        pairs.retain(|&(i, v)| v != 0 && (i as usize) < dim);
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs.dedup_by(|a, b| {
            if a.0 == b.0 {
                b.1 = a.1; // keep the later pair's value in `b` (retained)
                true
            } else {
                false
            }
        });
        Self { dim, entries: pairs }
    }

    /// Build from a dense slice of category labels (0 = missing).
    pub fn from_dense(values: &[u16]) -> Self {
        let entries = values
            .iter()
            .enumerate()
            .filter(|&(_, &v)| v != 0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        Self {
            dim: values.len(),
            entries,
        }
    }

    pub fn to_dense(&self) -> Vec<u16> {
        let mut out = vec![0u16; self.dim];
        for &(i, v) in &self.entries {
            out[i as usize] = v;
        }
        out
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Density = number of non-missing features (paper's Hamming weight).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Sparsity as a fraction in [0,1].
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.dim.max(1) as f64
    }

    #[inline]
    pub fn entries(&self) -> &[(u32, u16)] {
        &self.entries
    }

    /// Value at index `i` (0 if missing). Binary search.
    pub fn get(&self, i: usize) -> u16 {
        match self.entries.binary_search_by_key(&(i as u32), |&(j, _)| j) {
            Ok(pos) => self.entries[pos].1,
            Err(_) => 0,
        }
    }

    /// Exact Hamming distance (the paper's categorical HD):
    /// `HD(u,v) = |{i : u_i ≠ v_i}|`, counting missing-vs-present as 1.
    pub fn hamming(&self, other: &CatVector) -> usize {
        debug_assert_eq!(self.dim, other.dim);
        let (a, b) = (&self.entries, &other.entries);
        let (mut i, mut j, mut d) = (0usize, 0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => {
                    d += 1;
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    d += 1;
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    if a[i].1 != b[j].1 {
                        d += 1;
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        d + (a.len() - i) + (b.len() - j)
    }

    /// Number of coordinates where both are present and equal (used by
    /// k-mode distance decompositions and tests).
    pub fn matches(&self, other: &CatVector) -> usize {
        let (a, b) = (&self.entries, &other.entries);
        let (mut i, mut j, mut m) = (0usize, 0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].0.cmp(&b[j].0) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    if a[i].1 == b[j].1 {
                        m += 1;
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        m
    }

    /// Random vector with `nnz` nonzeros and values in `1..=c`.
    pub fn random(dim: usize, nnz: usize, c: u16, rng: &mut Xoshiro256) -> Self {
        let idx = rng.sample_indices(dim, nnz.min(dim));
        let pairs = idx
            .into_iter()
            .map(|i| (i as u32, 1 + rng.gen_range(c as u64) as u16))
            .collect();
        Self::from_pairs(dim, pairs)
    }
}

/// A collection of categorical vectors with shared dimension/category count.
#[derive(Clone, Debug)]
pub struct CategoricalDataset {
    pub name: String,
    pub points: Vec<CatVector>,
    dim: usize,
    num_categories: u16,
}

impl CategoricalDataset {
    pub fn new(name: &str, dim: usize, num_categories: u16, points: Vec<CatVector>) -> Self {
        debug_assert!(points.iter().all(|p| p.dim() == dim));
        Self {
            name: name.to_string(),
            points,
            dim,
            num_categories,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_categories(&self) -> u16 {
        self.num_categories
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Max density over the dataset — the `s` in Theorem 2.
    pub fn max_density(&self) -> usize {
        self.points.iter().map(|p| p.nnz()).max().unwrap_or(0)
    }

    pub fn mean_density(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.nnz()).sum::<usize>() as f64 / self.points.len() as f64
    }

    /// Dataset sparsity = smallest per-vector sparsity (paper Section 1).
    pub fn sparsity(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.sparsity())
            .fold(f64::INFINITY, f64::min)
    }

    /// Random sample of `k` points (without replacement).
    pub fn sample(&self, k: usize, rng: &mut Xoshiro256) -> CategoricalDataset {
        let idx = rng.sample_indices(self.len(), k.min(self.len()));
        CategoricalDataset::new(
            &format!("{}-sample{}", self.name, k),
            self.dim,
            self.num_categories,
            idx.into_iter().map(|i| self.points[i].clone()).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_pairs_normalises() {
        let v = CatVector::from_pairs(10, vec![(3, 2), (1, 5), (3, 7), (4, 0), (99, 1)]);
        assert_eq!(v.entries(), &[(1, 5), (3, 7)]);
        assert_eq!(v.get(3), 7);
        assert_eq!(v.get(4), 0);
        assert_eq!(v.nnz(), 2);
    }

    #[test]
    fn dense_roundtrip() {
        let d = vec![0u16, 3, 0, 0, 9, 1];
        let v = CatVector::from_dense(&d);
        assert_eq!(v.to_dense(), d);
        assert_eq!(v.nnz(), 3);
        assert!((v.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn hamming_matches_dense_definition() {
        let u = CatVector::from_dense(&[4, 0, 2, 0, 0, 1, 0, 2, 0, 0, 3, 1, 0, 4]);
        let v = CatVector::from_dense(&[4, 1, 0, 0, 0, 1, 0, 3, 0, 0, 3, 0, 0, 4]);
        let du = u.to_dense();
        let dv = v.to_dense();
        let expect = du.iter().zip(&dv).filter(|(a, b)| a != b).count();
        assert_eq!(u.hamming(&v), expect);
        assert_eq!(v.hamming(&u), expect);
        assert_eq!(u.hamming(&u), 0);
    }

    #[test]
    fn hamming_counts_missing_vs_present() {
        let u = CatVector::from_dense(&[1, 0, 0]);
        let v = CatVector::from_dense(&[0, 0, 2]);
        assert_eq!(u.hamming(&v), 2);
    }

    #[test]
    fn matches_counts_agreements() {
        let u = CatVector::from_dense(&[1, 2, 0, 3]);
        let v = CatVector::from_dense(&[1, 5, 0, 3]);
        assert_eq!(u.matches(&v), 2);
    }

    #[test]
    fn random_vector_has_requested_shape() {
        let mut rng = Xoshiro256::new(1);
        let v = CatVector::random(1000, 50, 7, &mut rng);
        assert_eq!(v.nnz(), 50);
        assert!(v.entries().iter().all(|&(i, c)| (i as usize) < 1000 && (1..=7).contains(&c)));
    }

    #[test]
    fn dataset_stats() {
        let mut rng = Xoshiro256::new(2);
        let pts = (0..10)
            .map(|i| CatVector::random(100, 5 + i, 3, &mut rng))
            .collect();
        let ds = CategoricalDataset::new("t", 100, 3, pts);
        assert_eq!(ds.max_density(), 14);
        assert!((ds.mean_density() - 9.5).abs() < 1e-12);
        assert!((ds.sparsity() - (1.0 - 0.14)).abs() < 1e-9);
        let s = ds.sample(4, &mut rng);
        assert_eq!(s.len(), 4);
    }
}
