//! Variance analysis of the two Cabin stages (paper Subsection 5.3,
//! Figures 4–5): repeat the random embedding many times for fixed inputs
//! and box-plot the Hamming errors.

use super::stats::BoxStats;
use crate::baselines::{by_key, Reduced};
use crate::data::CategoricalDataset;
use crate::sketch::{BinEm, PsiMode};
use crate::util::parallel;

/// Figure 4 (top row): signed errors `HD(u,v) − 2·HD(BinEm(u),BinEm(v))`
/// for one fixed pair over `trials` independent ψ draws.
pub fn binem_pair_errors(
    ds: &CategoricalDataset,
    i: usize,
    j: usize,
    trials: usize,
    seed: u64,
) -> Vec<f64> {
    let truth = ds.points[i].hamming(&ds.points[j]) as f64;
    parallel::par_map(trials, parallel::default_threads(), |t| {
        let be = BinEm::new(ds.dim(), ds.num_categories(), PsiMode::PerAttribute, seed + t as u64);
        let e = be.encode(&ds.points[i]).xor_count(&be.encode(&ds.points[j])) as f64;
        truth - 2.0 * e
    })
}

/// Figure 4 (bottom row): for each of `runs` independent ψ draws, the
/// average *absolute* error over all pairs of the sample.
pub fn binem_avg_abs_errors(ds: &CategoricalDataset, runs: usize, seed: u64) -> Vec<f64> {
    let n = ds.len();
    let pairs = (n * (n - 1) / 2) as f64;
    parallel::par_map(runs, parallel::default_threads(), |t| {
        let be = BinEm::new(ds.dim(), ds.num_categories(), PsiMode::PerAttribute, seed + t as u64);
        let encs: Vec<_> = ds.points.iter().map(|p| be.encode(p)).collect();
        let mut total = 0.0;
        for a in 0..n {
            for b in (a + 1)..n {
                let truth = ds.points[a].hamming(&ds.points[b]) as f64;
                total += (truth - 2.0 * encs[a].xor_count(&encs[b]) as f64).abs();
            }
        }
        total / pairs
    })
}

/// Figure 5: per-method signed errors for one fixed pair over `trials`
/// independent draws of the *second-stage* compressor (methods: the
/// discrete reducer keys).
pub fn stage2_pair_errors(
    ds: &CategoricalDataset,
    method: &str,
    dim: usize,
    i: usize,
    j: usize,
    trials: usize,
    seed: u64,
) -> Vec<f64> {
    let truth = ds.points[i].hamming(&ds.points[j]) as f64;
    let reducer = by_key(method).unwrap_or_else(|| panic!("unknown method {method}"));
    // Sub-sample the dataset to just the pair: reducers that fit global
    // structure (kt) still behave; sketching methods are per-point anyway.
    let pair_ds = CategoricalDataset::new(
        &ds.name,
        ds.dim(),
        ds.num_categories(),
        vec![ds.points[i].clone(), ds.points[j].clone()],
    );
    (0..trials)
        .map(|t| {
            let red: Reduced = reducer.reduce(&pair_ds, dim, seed + t as u64);
            truth - red.estimate_hamming(0, 1)
        })
        .collect()
}

/// Convenience: box-stats of a signed-error sample.
pub fn error_box(samples: &[f64]) -> BoxStats {
    BoxStats::from_samples(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn ds() -> CategoricalDataset {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 12;
        spec.dim = 2500;
        spec.mean_density = 70.0;
        spec.max_density = 100;
        spec.generate(29)
    }

    #[test]
    fn binem_errors_centred_at_zero() {
        // Figure 4's finding: BinEm errors distribute around 0.
        let ds = ds();
        let errs = binem_pair_errors(&ds, 0, 1, 400, 7);
        let b = error_box(&errs);
        let truth = ds.points[0].hamming(&ds.points[1]) as f64;
        assert!(b.mean.abs() < 0.1 * truth, "mean {} truth {}", b.mean, truth);
        // both signs occur
        assert!(b.min < 0.0 && b.max > 0.0);
    }

    #[test]
    fn binem_avg_abs_error_is_consistent() {
        // Figure 4 bottom: small variance across runs.
        let ds = ds();
        let errs = binem_avg_abs_errors(&ds, 30, 3);
        let b = error_box(&errs);
        assert!(b.count == 30);
        assert!(b.std_dev < 0.25 * b.mean + 1e-9, "std {} mean {}", b.std_dev, b.mean);
    }

    #[test]
    fn stage2_binsketch_lowest_spread() {
        // Figure 5's finding: BinSketch (cabin) has smaller IQR than FH at
        // moderate dimension.
        let ds = ds();
        let cabin = error_box(&stage2_pair_errors(&ds, "cabin", 256, 0, 1, 120, 11));
        let fh = error_box(&stage2_pair_errors(&ds, "fh", 256, 0, 1, 120, 11));
        assert!(
            cabin.iqr() <= fh.iqr() * 1.2,
            "cabin iqr {} fh iqr {}",
            cabin.iqr(),
            fh.iqr()
        );
    }
}
