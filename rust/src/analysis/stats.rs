//! Box-plot statistics (five-number summaries) for the variance-analysis
//! figures (4–5), plus simple mean/std helpers.

use crate::util::timer::percentile;

/// Five-number summary + mean, the data behind one box in a box plot.
#[derive(Clone, Copy, Debug, Default)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub std_dev: f64,
    pub count: usize,
}

impl BoxStats {
    pub fn from_samples(samples: &[f64]) -> BoxStats {
        if samples.is_empty() {
            return BoxStats::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / s.len() as f64;
        BoxStats {
            min: s[0],
            q1: percentile(&s, 0.25),
            median: percentile(&s, 0.5),
            q3: percentile(&s, 0.75),
            max: s[s.len() - 1],
            mean,
            std_dev: var.sqrt(),
            count: s.len(),
        }
    }

    /// Interquartile range.
    pub fn iqr(&self) -> f64 {
        self.q3 - self.q1
    }

    pub fn csv_row(&self, label: &str) -> String {
        format!(
            "{},{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
            label, self.count, self.min, self.q1, self.median, self.q3, self.max, self.mean,
            self.std_dev
        )
    }

    pub const CSV_HEADER: &'static str = "label,count,min,q1,median,q3,max,mean,std";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_numbers() {
        let s: Vec<f64> = (1..=9).map(|x| x as f64).collect();
        let b = BoxStats::from_samples(&s);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 9.0);
        assert_eq!(b.median, 5.0);
        assert_eq!(b.q1, 3.0);
        assert_eq!(b.q3, 7.0);
        assert_eq!(b.mean, 5.0);
        assert_eq!(b.iqr(), 4.0);
    }

    #[test]
    fn empty_is_default() {
        let b = BoxStats::from_samples(&[]);
        assert_eq!(b.count, 0);
        assert_eq!(b.median, 0.0);
    }

    #[test]
    fn csv_row_format() {
        let b = BoxStats::from_samples(&[1.0, 2.0, 3.0]);
        let row = b.csv_row("x");
        assert!(row.starts_with("x,3,"));
        assert_eq!(row.split(',').count(), BoxStats::CSV_HEADER.split(',').count());
    }
}
