//! All-pairs similarity matrix ("heatmap") generation — paper Subsection
//! 5.5, Figures 11–12, Table 4.
//!
//! A heatmap is the `N×N` matrix of pairwise (estimated) Hamming
//! distances. We materialise it as a flat `Vec<f64>`, write PGM images for
//! visual comparison (Figure 11/12 stand-ins that render anywhere) and CSV
//! summaries, and compute the error heatmap + MAE against the exact one.

use crate::baselines::Reduced;
use crate::data::CategoricalDataset;
use crate::sketch::bitvec::and_count_words;
use crate::sketch::{BitVec, SketchMatrix};
use crate::util::parallel;

/// Send+Sync wrapper for the striped-row writer (rows are disjoint).
struct ValuesCell(*mut f64);
unsafe impl Send for ValuesCell {}
unsafe impl Sync for ValuesCell {}

/// Square symmetric distance matrix.
#[derive(Clone, Debug)]
pub struct Heatmap {
    pub n: usize,
    pub values: Vec<f64>,
}

impl Heatmap {
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.values[i * self.n + j]
    }

    /// Exact categorical Hamming heatmap (the paper's "full-dimensional"
    /// side of Figure 11 — the 78 ms/entry side).
    pub fn exact(ds: &CategoricalDataset) -> Heatmap {
        let n = ds.len();
        let mut values = vec![0.0; n * n];
        let threads = parallel::default_threads();
        parallel::par_chunks_mut(&mut values, threads, |start, chunk| {
            for (off, v) in chunk.iter_mut().enumerate() {
                let idx = start + off;
                let (i, j) = (idx / n, idx % n);
                if i < j {
                    *v = ds.points[i].hamming(&ds.points[j]) as f64;
                }
            }
        });
        let mut h = Heatmap { n, values };
        h.mirror();
        h
    }

    /// Heatmap from any reduced representation.
    pub fn estimated(red: &Reduced) -> Heatmap {
        let n = red.len();
        let mut values = vec![0.0; n * n];
        let threads = parallel::default_threads();
        parallel::par_chunks_mut(&mut values, threads, |start, chunk| {
            for (off, v) in chunk.iter_mut().enumerate() {
                let idx = start + off;
                let (i, j) = (idx / n, idx % n);
                if i < j {
                    *v = red.estimate_hamming(i, j);
                }
            }
        });
        let mut h = Heatmap { n, values };
        h.mirror();
        h
    }

    /// Fast path for binary sketches: packs them into a contiguous
    /// [`SketchMatrix`] arena and scans that. Kept as the slice-of-BitVecs
    /// entry point for callers that haven't materialised an arena yet.
    pub fn from_sketches_occupancy(sketches: &[BitVec], scale: f64) -> Heatmap {
        Self::from_matrix_occupancy(&SketchMatrix::from_sketches(sketches), scale)
    }

    /// All-pairs estimated-Hamming heatmap over a sketch arena — the native
    /// hot loop benched in §Perf. Three optimizations over
    /// [`Heatmap::from_sketches_naive`] (kept as the measured baseline):
    ///
    /// 1. the per-point occupancy inversions `est(|ũ|)` are precomputed
    ///    (one `ln` per *point*), so the pair loop performs a single `ln`
    ///    per pair instead of three — the logs, not the popcounts,
    ///    dominate at d ≤ 4096;
    /// 2. work is scheduled dynamically over rows (upper-triangle rows
    ///    shrink with i; static row blocks leave the first thread with
    ///    ~2× the work of the last);
    /// 3. the pair loop reads borrowed `&[u64]` arena rows and the arena's
    ///    cached row weights — one contiguous allocation, no per-sketch
    ///    pointer chase.
    pub fn from_matrix_occupancy(m: &SketchMatrix, scale: f64) -> Heatmap {
        let n = m.len();
        let d = m.bits();
        let df = d as f64;
        let inv_ln_ratio = 1.0 / (1.0 - 1.0 / df).ln();
        let weights: Vec<f64> = (0..n).map(|i| m.weight(i) as f64).collect();
        // est(w_i) precomputed: ĥ = 2·est(union) − est(w_i) − est(w_j)
        let est_w: Vec<f64> = weights
            .iter()
            .map(|&w| (1.0 - w.min(df - 1.0) / df).ln() * inv_ln_ratio)
            .collect();
        let mut values = vec![0.0; n * n];
        let threads = parallel::default_threads();
        // dynamic row scheduling via striped ownership: row i belongs to
        // thread i % T — balances the shrinking upper-triangle rows.
        let values_ptr = ValuesCell(values.as_mut_ptr());
        std::thread::scope(|s| {
            for t in 0..threads {
                let weights = &weights;
                let est_w = &est_w;
                let vp = &values_ptr;
                s.spawn(move || {
                    let mut i = t;
                    while i < n {
                        // SAFETY: each row i is written by exactly one
                        // thread (i % threads == t) and rows are disjoint.
                        let row = unsafe {
                            std::slice::from_raw_parts_mut(vp.0.add(i * n), n)
                        };
                        let si = m.row(i);
                        let (wi, ei) = (weights[i], est_w[i]);
                        for (j, slot) in row.iter_mut().enumerate().skip(i + 1) {
                            let ip = and_count_words(si, m.row(j)) as f64;
                            let union = (wi + weights[j] - ip).min(df - 1.0).max(0.0);
                            let est_union = (1.0 - union / df).ln() * inv_ln_ratio;
                            let h = 2.0 * est_union - ei - est_w[j];
                            *slot = scale * h.max(0.0);
                        }
                        i += threads;
                    }
                });
            }
        });
        let mut h = Heatmap { n, values };
        h.mirror();
        h
    }

    /// Unoptimised baseline retained for the §Perf before/after comparison
    /// (three logs per pair, static row blocks).
    pub fn from_sketches_naive(sketches: &[BitVec], scale: f64) -> Heatmap {
        use crate::sketch::cham::binhamming_from_stats;
        let n = sketches.len();
        let d = sketches.first().map(|s| s.len()).unwrap_or(0);
        let weights: Vec<f64> = sketches.iter().map(|s| s.count_ones() as f64).collect();
        let mut values = vec![0.0; n * n];
        let threads = parallel::default_threads();
        let rows_per = n.div_ceil(threads).max(1);
        std::thread::scope(|s| {
            for (t, chunk) in values.chunks_mut(rows_per * n).enumerate() {
                let r0 = t * rows_per;
                let weights = &weights;
                s.spawn(move || {
                    for (ri, row) in chunk.chunks_mut(n).enumerate() {
                        let i = r0 + ri;
                        for (j, slot) in row.iter_mut().enumerate().skip(i + 1) {
                            let ip = sketches[i].and_count(&sketches[j]) as f64;
                            *slot =
                                scale * binhamming_from_stats(weights[i], weights[j], ip, d);
                        }
                    }
                });
            }
        });
        let mut h = Heatmap { n, values };
        h.mirror();
        h
    }

    fn mirror(&mut self) {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                self.values[j * self.n + i] = self.values[i * self.n + j];
            }
        }
    }

    /// Mean absolute error against another heatmap (Table 4's metric),
    /// over the strict upper triangle.
    pub fn mae_vs(&self, other: &Heatmap) -> f64 {
        assert_eq!(self.n, other.n);
        let mut total = 0.0;
        let mut cnt = 0usize;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                total += (self.get(i, j) - other.get(i, j)).abs();
                cnt += 1;
            }
        }
        if cnt == 0 {
            0.0
        } else {
            total / cnt as f64
        }
    }

    /// Element-wise absolute error heatmap (Figure 12).
    pub fn error_vs(&self, other: &Heatmap) -> Heatmap {
        assert_eq!(self.n, other.n);
        Heatmap {
            n: self.n,
            values: self
                .values
                .iter()
                .zip(&other.values)
                .map(|(a, b)| (a - b).abs())
                .collect(),
        }
    }

    /// Write an 8-bit PGM (portable graymap) visualisation; values are
    /// min-max normalised. Dark = small (matches Figure 12's "darker =
    /// better" convention when applied to error maps).
    pub fn write_pgm(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &self.values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let range = (hi - lo).max(1e-12);
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "P5\n{} {}\n255", self.n, self.n)?;
        let bytes: Vec<u8> = self
            .values
            .iter()
            .map(|&v| (255.0 * (v - lo) / range).round() as u8)
            .collect();
        f.write_all(&bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::by_key;
    use crate::data::synth::SynthSpec;
    use crate::sketch::{CabinSketcher, SketchConfig};

    fn ds() -> CategoricalDataset {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 25;
        spec.dim = 2000;
        spec.mean_density = 60.0;
        spec.max_density = 90;
        spec.generate(23)
    }

    #[test]
    fn exact_heatmap_symmetric_zero_diag() {
        let ds = ds();
        let h = Heatmap::exact(&ds);
        for i in 0..h.n {
            assert_eq!(h.get(i, i), 0.0);
            for j in 0..h.n {
                assert_eq!(h.get(i, j), h.get(j, i));
            }
        }
        assert_eq!(
            h.get(3, 7),
            ds.points[3].hamming(&ds.points[7]) as f64
        );
    }

    #[test]
    fn estimated_close_to_exact_for_cabin() {
        let ds = ds();
        let red = by_key("cabin").unwrap().reduce(&ds, 512, 7);
        let exact = Heatmap::exact(&ds);
        let est = Heatmap::estimated(&red);
        let mae = est.mae_vs(&exact);
        let mean_dist = {
            let mut t = 0.0;
            let mut c = 0;
            for i in 0..exact.n {
                for j in (i + 1)..exact.n {
                    t += exact.get(i, j);
                    c += 1;
                }
            }
            t / c as f64
        };
        assert!(mae < 0.2 * mean_dist, "mae {} mean {}", mae, mean_dist);
    }

    #[test]
    fn optimized_matches_naive_baseline() {
        let ds = ds();
        let cfg = SketchConfig::new(ds.dim(), ds.num_categories(), 512, 3);
        let sk = CabinSketcher::from_config(cfg);
        let sketches = sk.sketch_dataset(&ds, 4);
        let fast = Heatmap::from_sketches_occupancy(&sketches, 2.0);
        let naive = Heatmap::from_sketches_naive(&sketches, 2.0);
        for i in 0..fast.values.len() {
            assert!(
                (fast.values[i] - naive.values[i]).abs() < 1e-9,
                "idx {i}: {} vs {}",
                fast.values[i],
                naive.values[i]
            );
        }
    }

    #[test]
    fn matrix_scan_matches_slice_entry_point() {
        let ds = ds();
        let cfg = SketchConfig::new(ds.dim(), ds.num_categories(), 512, 5);
        let sk = CabinSketcher::from_config(cfg);
        let sketches = sk.sketch_dataset(&ds, 4);
        let via_slice = Heatmap::from_sketches_occupancy(&sketches, 2.0);
        let via_matrix =
            Heatmap::from_matrix_occupancy(&SketchMatrix::from_sketches(&sketches), 2.0);
        assert_eq!(via_slice.values, via_matrix.values);
    }

    #[test]
    fn fast_path_matches_generic() {
        let ds = ds();
        let cfg = SketchConfig::new(ds.dim(), ds.num_categories(), 256, 9);
        let sk = CabinSketcher::from_config(cfg);
        let sketches = sk.sketch_dataset(&ds, 4);
        let fast = Heatmap::from_sketches_occupancy(&sketches, 2.0);
        let red = by_key("cabin").unwrap().reduce(&ds, 256, 9);
        let gen = Heatmap::estimated(&red);
        for i in 0..fast.n {
            for j in 0..fast.n {
                assert!(
                    (fast.get(i, j) - gen.get(i, j)).abs() < 1e-9,
                    "({},{}) {} vs {}",
                    i,
                    j,
                    fast.get(i, j),
                    gen.get(i, j)
                );
            }
        }
    }

    #[test]
    fn error_heatmap_and_mae_consistent() {
        let ds = ds();
        let red = by_key("cabin").unwrap().reduce(&ds, 128, 2);
        let exact = Heatmap::exact(&ds);
        let est = Heatmap::estimated(&red);
        let err = est.error_vs(&exact);
        // MAE computed two ways agrees
        let mut total = 0.0;
        let mut c = 0;
        for i in 0..err.n {
            for j in (i + 1)..err.n {
                total += err.get(i, j);
                c += 1;
            }
        }
        assert!((total / c as f64 - est.mae_vs(&exact)).abs() < 1e-9);
    }

    #[test]
    fn pgm_write() {
        let h = Heatmap {
            n: 4,
            values: (0..16).map(|x| x as f64).collect(),
        };
        let p = std::env::temp_dir().join("cabin_test_hm.pgm");
        h.write_pgm(p.to_str().unwrap()).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P5\n4 4\n255\n"));
        assert_eq!(data.len(), 11 + 16);
        let _ = std::fs::remove_file(p);
    }
}
