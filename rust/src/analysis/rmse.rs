//! RMSE of Hamming-distance estimation (paper Subsection 5.2, Figure 3):
//! `RMSE = sqrt( Σ_{u,v} HE(u,v)² / N )` over all pairs of a sample, where
//! `HE = HD(u,v) − estimate from sketches`.

use crate::baselines::Reduced;
use crate::data::CategoricalDataset;
use crate::util::parallel;

/// All-pairs RMSE of a reduced representation against the true categorical
/// Hamming distances. Parallel over the first index.
pub fn rmse(ds: &CategoricalDataset, red: &Reduced) -> f64 {
    let n = ds.len();
    assert_eq!(red.len(), n);
    if n < 2 {
        return 0.0;
    }
    let threads = parallel::default_threads();
    let partial: Vec<f64> = parallel::par_map(n, threads, |i| {
        let mut acc = 0.0;
        for j in (i + 1)..n {
            let truth = ds.points[i].hamming(&ds.points[j]) as f64;
            let est = red.estimate_hamming(i, j);
            let e = truth - est;
            acc += e * e;
        }
        acc
    });
    let total: f64 = partial.iter().sum();
    let pairs = (n * (n - 1) / 2) as f64;
    (total / pairs).sqrt()
}

/// Mean absolute error over all pairs (Table 4's MAE).
pub fn mae(ds: &CategoricalDataset, red: &Reduced) -> f64 {
    let n = ds.len();
    assert_eq!(red.len(), n);
    if n < 2 {
        return 0.0;
    }
    let threads = parallel::default_threads();
    let partial: Vec<f64> = parallel::par_map(n, threads, |i| {
        let mut acc = 0.0;
        for j in (i + 1)..n {
            let truth = ds.points[i].hamming(&ds.points[j]) as f64;
            acc += (truth - red.estimate_hamming(i, j)).abs();
        }
        acc
    });
    let total: f64 = partial.iter().sum();
    total / (n * (n - 1) / 2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::by_key;
    use crate::data::synth::SynthSpec;

    fn sample_ds() -> CategoricalDataset {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 40;
        spec.dim = 3000;
        spec.mean_density = 80.0;
        spec.max_density = 120;
        spec.generate(19)
    }

    #[test]
    fn cabin_rmse_decreases_with_dim() {
        let ds = sample_ds();
        let r = by_key("cabin").unwrap();
        let rmse_small = rmse(&ds, &r.reduce(&ds, 64, 3));
        let rmse_large = rmse(&ds, &r.reduce(&ds, 1024, 3));
        assert!(
            rmse_large < rmse_small,
            "rmse larger dim {} !< smaller {}",
            rmse_large,
            rmse_small
        );
    }

    #[test]
    fn cabin_beats_hlsh_at_moderate_dim() {
        // The headline qualitative claim of Figure 3.
        let ds = sample_ds();
        let d = 256;
        let cabin = rmse(&ds, &by_key("cabin").unwrap().reduce(&ds, d, 5));
        let hlsh = rmse(&ds, &by_key("hlsh").unwrap().reduce(&ds, d, 5));
        assert!(cabin < hlsh, "cabin {} !< hlsh {}", cabin, hlsh);
    }

    #[test]
    fn mae_leq_rmse() {
        let ds = sample_ds();
        let red = by_key("cabin").unwrap().reduce(&ds, 128, 1);
        let m = mae(&ds, &red);
        let r = rmse(&ds, &red);
        assert!(m <= r + 1e-9, "mae {} > rmse {}", m, r);
        assert!(m >= 0.0);
    }
}
