//! Analysis harnesses behind the paper's evaluation figures:
//! RMSE (Figure 3), box-plot variance analysis (Figures 4–5),
//! heatmaps + MAE (Figures 11–12, Table 4).

pub mod heatmap;
pub mod rmse;
pub mod stats;
pub mod variance;

use std::io::Write;

/// Write a CSV file under `results/` (creating the directory).
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> std::io::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    #[test]
    fn csv_writer_roundtrip() {
        let p = super::write_csv(
            "test_csv_writer",
            "a,b",
            &["1,2".to_string(), "3,4".to_string()],
        )
        .unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert_eq!(content, "a,b\n1,2\n3,4\n");
        let _ = std::fs::remove_file(p);
    }
}
