//! Leveled structured logging for the serving runtime.
//!
//! Replaces the raw `eprintln!` sites scattered across
//! server/store/batcher/executor/replica with one emitter that tags
//! every event with a level, a component, an event name, and key-value
//! context. Two output shapes, both one line per event on stderr:
//!
//! - text (default): `[component] LEVEL event key=val key="quoted val"`
//! - JSONL (`--log-json`): `{"ts_ms":…,"level":"warn","component":"store",
//!   "event":"wal_commit_failed","shard":3,"error":"…"}` — built through
//!   [`crate::util::json::Json`], so escaping is correct and keys are
//!   deterministically ordered.
//!
//! The level filter and format are process-global atomics set once by
//! `serve` startup ([`init`]) — call sites are a relaxed load plus an
//! early-out when filtered, so `debug!`-class events cost nothing in
//! production. Levels: `debug < info < warn < error` (`--log-level`).

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    /// Parse a level name (case-insensitive); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static JSON: AtomicBool = AtomicBool::new(false);

/// Configure the global logger (idempotent; last call wins). Called once
/// from `serve` startup; tests may call it to force a format.
pub fn init(level: Level, json: bool) {
    LEVEL.store(level as u8, Ordering::Relaxed);
    JSON.store(json, Ordering::Relaxed);
}

/// Would an event at `level` currently be emitted?
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// A log field value. Constructors keep call sites terse:
/// `("shard", V::u(si as u64))`, `("error", V::s(format!("{e:#}")))`.
#[derive(Clone, Debug)]
pub enum V {
    S(String),
    U(u64),
    I(i64),
    F(f64),
    B(bool),
}

impl V {
    pub fn s(v: impl Into<String>) -> V {
        V::S(v.into())
    }

    pub fn u(v: u64) -> V {
        V::U(v)
    }

    pub fn i(v: i64) -> V {
        V::I(v)
    }

    pub fn f(v: f64) -> V {
        V::F(v)
    }

    pub fn b(v: bool) -> V {
        V::B(v)
    }

    fn to_json(&self) -> Json {
        match self {
            V::S(s) => Json::Str(s.clone()),
            V::U(u) => Json::Num(*u as f64),
            V::I(i) => Json::Num(*i as f64),
            V::F(f) => Json::Num(*f),
            V::B(b) => Json::Bool(*b),
        }
    }
}

impl std::fmt::Display for V {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            V::S(s) => write!(f, "{s}"),
            V::U(u) => write!(f, "{u}"),
            V::I(i) => write!(f, "{i}"),
            V::F(x) => write!(f, "{x:.3}"),
            V::B(b) => write!(f, "{b}"),
        }
    }
}

pub fn debug(component: &str, event: &str, fields: &[(&str, V)]) {
    emit(Level::Debug, component, event, fields);
}

pub fn info(component: &str, event: &str, fields: &[(&str, V)]) {
    emit(Level::Info, component, event, fields);
}

pub fn warn(component: &str, event: &str, fields: &[(&str, V)]) {
    emit(Level::Warn, component, event, fields);
}

pub fn error(component: &str, event: &str, fields: &[(&str, V)]) {
    emit(Level::Error, component, event, fields);
}

fn emit(level: Level, component: &str, event: &str, fields: &[(&str, V)]) {
    if !enabled(level) {
        return;
    }
    eprintln!(
        "{}",
        format_line(level, JSON.load(Ordering::Relaxed), component, event, fields)
    );
}

/// Render one event line (pure — unit-testable without capturing stderr).
pub fn format_line(
    level: Level,
    json: bool,
    component: &str,
    event: &str,
    fields: &[(&str, V)],
) -> String {
    if json {
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let mut pairs: Vec<(&str, Json)> = vec![
            ("ts_ms", Json::Num(ts_ms)),
            ("level", Json::Str(level.name().to_string())),
            ("component", Json::Str(component.to_string())),
            ("event", Json::Str(event.to_string())),
        ];
        for (k, v) in fields {
            pairs.push((k, v.to_json()));
        }
        Json::obj(pairs).to_string()
    } else {
        let mut out = format!("[{component}] {} {event}", level.name().to_uppercase());
        for (k, v) in fields {
            let rendered = v.to_string();
            if rendered.contains(|c: char| c.is_whitespace() || c == '"') {
                out.push_str(&format!(" {k}={:?}", rendered));
            } else {
                out.push_str(&format!(" {k}={rendered}"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_order() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Debug < Level::Error);
    }

    #[test]
    fn text_format_quotes_spaces() {
        let line = format_line(
            Level::Warn,
            false,
            "store",
            "wal_commit_failed",
            &[("shard", V::u(3)), ("error", V::s("disk full: no space"))],
        );
        assert_eq!(
            line,
            "[store] WARN wal_commit_failed shard=3 error=\"disk full: no space\""
        );
    }

    #[test]
    fn json_format_is_parseable_with_context() {
        let line = format_line(
            Level::Error,
            true,
            "replica",
            "diverged",
            &[("shard", V::u(1)), ("detail", V::s("checksum \"x\"\nline"))],
        );
        let v = crate::util::json::parse(&line).unwrap();
        assert_eq!(v.req_str("level").unwrap(), "error");
        assert_eq!(v.req_str("component").unwrap(), "replica");
        assert_eq!(v.req_str("event").unwrap(), "diverged");
        assert_eq!(v.req_usize("shard").unwrap(), 1);
        assert_eq!(v.req_str("detail").unwrap(), "checksum \"x\"\nline");
        assert!(v.get("ts_ms").is_some());
    }

    #[test]
    fn terse_constructors_cover_every_variant() {
        let line = format_line(
            Level::Info,
            false,
            "test",
            "ctor",
            &[
                ("s", V::s("x")),
                ("u", V::u(7)),
                ("i", V::i(-5)),
                ("f", V::f(1.5)),
                ("b", V::b(true)),
            ],
        );
        assert_eq!(line, "[test] INFO ctor s=x u=7 i=-5 f=1.500 b=true");
    }

    #[test]
    fn filter_respects_level() {
        init(Level::Warn, false);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        init(Level::Info, false); // restore default for other tests
        assert!(enabled(Level::Info));
    }
}
