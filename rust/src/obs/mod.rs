//! Observability: lock-free histograms, stage timing, structured logs,
//! Prometheus exposition.
//!
//! The serving path answers three questions without locks or unbounded
//! allocation:
//!
//! 1. **How slow?** [`ObsHistogram`] — fixed-memory log-linear atomic
//!    buckets (see [`histogram`]) — backs every latency metric.
//! 2. **Slow *where*?** [`Stages`] holds one histogram per pipeline
//!    stage. Write path: batcher queue wait → sketch encode → placement
//!    → WAL append → group-commit fsync wait → reply. Read path:
//!    executor queue wait → scan/kernel → rerank → gather. The batcher
//!    and router record into them via `Arc<Stages>` handles threaded
//!    through `Metrics`, the store, and `QueryOpts`; per-request
//!    critical-path copies land in a [`ReadSpan`] so a `--slow-op-ms`
//!    breach logs one structured record with the full breakdown,
//!    correlated by the per-connection trace id the server stamps on
//!    batcher tickets and executor jobs.
//! 3. **What happened?** [`log`] — leveled text/JSONL events replacing
//!    raw `eprintln!`; [`prom`] renders everything in Prometheus text
//!    format for the `metrics_text` wire op.
//! 4. **What happened *before it broke*?** [`journal`] — a fixed-size
//!    flight-recorder ring of lifecycle events with monotonic seqs,
//!    dumped via the `events` wire op / CLI and flushed to stderr by
//!    the panic hook, so failover timelines are reconstructible after
//!    the fact.

pub mod histogram;
pub mod journal;
pub mod log;
pub mod prom;

pub use histogram::{HistogramSnapshot, ObsHistogram};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// One histogram per serving-pipeline stage. Shared as `Arc<Stages>`
/// from `Metrics` into the batcher, store, and router; recording is
/// lock-free (see [`ObsHistogram::record_us`]).
#[derive(Default)]
pub struct Stages {
    /// Write path: ticket enqueue → batcher pickup.
    pub write_queue: ObsHistogram,
    /// Write path: categorical vectors → BinSketch encode (per batch).
    pub write_sketch: ObsHistogram,
    /// Write path: shard placement + arena append + LSH insert + WAL
    /// frame buffering, under the shard locks (per batch).
    pub write_place: ObsHistogram,
    /// Write path: WAL commit, or group-commit window registration
    /// (per batch).
    pub write_wal: ObsHistogram,
    /// Write path: wait for the group-commit fsync epoch (per batch).
    pub write_fsync: ObsHistogram,
    /// Write path: replying to all tickets in the batch (per batch).
    pub write_reply: ObsHistogram,
    /// Read path: job submit → executor worker pickup (per shard job).
    pub read_queue: ObsHistogram,
    /// Read path: candidate scan / blocked kernel time (per shard job).
    pub read_scan: ObsHistogram,
    /// Read path: exact rerank of LSH candidates (per indexed shard job).
    pub read_rerank: ObsHistogram,
    /// Read path: merging per-shard top-k heaps (per query batch).
    pub read_gather: ObsHistogram,
}

impl Stages {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stable stage names, in pipeline order — drives both the
    /// `stage_*` stats fields and the Prometheus families.
    pub fn named(&self) -> [(&'static str, &ObsHistogram); 10] {
        [
            ("write_queue", &self.write_queue),
            ("write_sketch", &self.write_sketch),
            ("write_place", &self.write_place),
            ("write_wal", &self.write_wal),
            ("write_fsync", &self.write_fsync),
            ("write_reply", &self.write_reply),
            ("read_queue", &self.read_queue),
            ("read_scan", &self.read_scan),
            ("read_rerank", &self.read_rerank),
            ("read_gather", &self.read_gather),
        ]
    }
}

/// Per-request critical-path view of the read pipeline. Shard jobs run
/// in parallel, so each stage keeps the *maximum* across jobs
/// (`fetch_max`) — the time that actually bounded the request — rather
/// than a sum that could exceed wall clock. Cheap enough to allocate
/// per request; dropped with the reply.
#[derive(Default)]
pub struct ReadSpan {
    pub queue_us: AtomicU64,
    pub scan_us: AtomicU64,
    pub rerank_us: AtomicU64,
    pub gather_us: AtomicU64,
}

impl ReadSpan {
    pub fn note_queue(&self, us: u64) {
        self.queue_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn note_scan(&self, us: u64) {
        self.scan_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn note_rerank(&self, us: u64) {
        self.rerank_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn note_gather(&self, us: u64) {
        self.gather_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn ms(&self, field: &AtomicU64) -> f64 {
        field.load(Ordering::Relaxed) as f64 / 1e3
    }
}

/// Global slow-op threshold in µs; 0 = disabled. Set once at `serve`
/// startup from `--slow-op-ms` (a global, not a config field, so the
/// batcher/server don't need signature changes at their many
/// construction sites).
static SLOW_OP_US: AtomicU64 = AtomicU64::new(0);

pub fn set_slow_op_ms(ms: u64) {
    SLOW_OP_US.store(ms.saturating_mul(1000), Ordering::Relaxed);
}

/// Current threshold in µs (0 = disabled).
#[inline]
pub fn slow_op_us() -> u64 {
    SLOW_OP_US.load(Ordering::Relaxed)
}

/// Elapsed µs since `start`, saturating at u64::MAX.
#[inline]
pub fn elapsed_us(start: Instant) -> u64 {
    let us = start.elapsed().as_micros();
    us.min(u64::MAX as u128) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_are_unique_and_ordered() {
        let stages = Stages::new();
        let names: Vec<&str> = stages.named().iter().map(|(n, _)| *n).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate stage name");
        assert_eq!(names[0], "write_queue");
        assert_eq!(names[9], "read_gather");
    }

    #[test]
    fn read_span_keeps_max_across_jobs() {
        let span = ReadSpan::default();
        span.note_scan(100);
        span.note_scan(40);
        span.note_scan(250);
        assert_eq!(span.scan_us.load(Ordering::Relaxed), 250);
        assert!((span.ms(&span.scan_us) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn slow_op_threshold_roundtrip() {
        set_slow_op_ms(25);
        assert_eq!(slow_op_us(), 25_000);
        set_slow_op_ms(0);
        assert_eq!(slow_op_us(), 0);
    }
}
