//! Flight recorder: a fixed-size ring of structured lifecycle events.
//!
//! The structured log answers "what is happening" while someone is
//! watching stderr; the journal answers "what happened" after the
//! fact. Every notable lifecycle transition — promotion, fence raised,
//! epoch observed, probe streaks, snapshot rotation, compaction,
//! divergence, WAL commit failure, executor panic, slow ops — records
//! one event into a process-global ring of [`JOURNAL_CAPACITY`] slots
//! with a monotonic sequence number, so the last few hundred events
//! survive in memory regardless of log level and can be dumped:
//!
//! - over the wire via the `{"stream":"events"}` op (JSONL payload),
//! - from the CLI via `cabin-sketch events --addr`,
//! - to stderr by the panic hook ([`install_panic_hook`]).
//!
//! Events are rendered to their final JSONL form at record time, one
//! line per event: `{"seq":N,"ts_ms":M,"component":"...","event":"...",
//! ...fields}`. Unlike the f64-backed [`crate::util::json::Json`]
//! model, `seq`, `ts_ms` and `u64`/`i64` fields are written as exact
//! integers — sequence numbers and trace ids must round-trip.
//!
//! Recording is cheap and non-blocking in practice: one relaxed
//! `fetch_add` to reserve a sequence number, the line render, and one
//! uncontended per-slot mutex (contention requires two threads landing
//! on the same slot modulo the capacity at the same instant). The ring
//! never allocates after construction beyond the event lines
//! themselves. Ordering is total: `seq` is the authority, and
//! [`Journal::render_jsonl`] emits slots sorted by it, so tests can
//! assert timelines ("probe-fail happened before promote") instead of
//! polling counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::obs::log::V;
use crate::util::json::Json;

/// Slots in the process-global ring. 256 events comfortably covers a
/// failover story (probe streak + promote + fence + rejoin) plus the
/// surrounding snapshot/compaction chatter.
pub const JOURNAL_CAPACITY: usize = 256;

struct Slot {
    seq: u64,
    line: String,
}

/// A fixed-size event ring. Most callers use the process-global
/// instance via the free functions ([`record`], [`render_jsonl`],
/// [`events`], [`dropped`]); tests construct their own.
pub struct Journal {
    next_seq: AtomicU64,
    slots: Vec<Mutex<Option<Slot>>>,
}

impl Journal {
    pub fn new(capacity: usize) -> Journal {
        Journal {
            next_seq: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Record one event; returns its sequence number. Field order is
    /// preserved as given (journal lines are their own surface — they
    /// do not promise the lexicographic key order of wire replies).
    pub fn record(&self, component: &str, event: &str, fields: &[(&str, V)]) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let line = render_event(seq, now_ms(), component, event, fields);
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        let mut guard = slot.lock().unwrap_or_else(|e| e.into_inner());
        // Two threads can race for the same slot one capacity apart;
        // keep the newer event.
        if guard.as_ref().map_or(true, |s| s.seq < seq) {
            *guard = Some(Slot { seq, line });
        }
        seq
    }

    /// Total events ever recorded (== the next sequence number).
    pub fn events(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed)
    }

    /// Events overwritten by newer ones (ring wrap).
    pub fn dropped(&self) -> u64 {
        self.events().saturating_sub(self.slots.len() as u64)
    }

    /// Dump the surviving events as JSONL, oldest first, trailing
    /// newline included (empty string when nothing was recorded).
    pub fn render_jsonl(&self) -> String {
        let mut entries: Vec<(u64, String)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let guard = slot.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(s) = guard.as_ref() {
                entries.push((s.seq, s.line.clone()));
            }
        }
        entries.sort_unstable_by_key(|&(seq, _)| seq);
        let mut out = String::new();
        for (_, line) in entries {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

/// Render one event line. `u64`/`i64` fields are written as exact
/// integers (the f64-backed `Json` model would round trace ids and
/// sequence numbers above 2^53); strings go through `Json::Str` so
/// escaping is correct.
fn render_event(seq: u64, ts_ms: u64, component: &str, event: &str, fields: &[(&str, V)]) -> String {
    let mut out = format!(
        "{{\"seq\":{seq},\"ts_ms\":{ts_ms},\"component\":{},\"event\":{}",
        Json::Str(component.to_string()),
        Json::Str(event.to_string())
    );
    for (k, v) in fields {
        out.push(',');
        out.push_str(&Json::Str((*k).to_string()).to_string());
        out.push(':');
        match v {
            V::S(s) => out.push_str(&Json::Str(s.clone()).to_string()),
            V::U(u) => out.push_str(&u.to_string()),
            V::I(i) => out.push_str(&i.to_string()),
            V::F(f) => out.push_str(&Json::Num(*f).to_string()),
            V::B(b) => out.push_str(if *b { "true" } else { "false" }),
        }
    }
    out.push('}');
    out
}

static GLOBAL: OnceLock<Journal> = OnceLock::new();

/// The process-global journal (created on first use).
pub fn global() -> &'static Journal {
    GLOBAL.get_or_init(|| Journal::new(JOURNAL_CAPACITY))
}

/// Record one event into the process-global journal.
pub fn record(component: &str, event: &str, fields: &[(&str, V)]) -> u64 {
    global().record(component, event, fields)
}

/// Dump the process-global journal as JSONL (see
/// [`Journal::render_jsonl`]).
pub fn render_jsonl() -> String {
    global().render_jsonl()
}

/// Total events recorded process-wide (`journal_events` in stats).
pub fn events() -> u64 {
    global().events()
}

/// Events lost to ring wrap (`journal_dropped` in stats).
pub fn dropped() -> u64 {
    global().dropped()
}

static HOOK: OnceLock<()> = OnceLock::new();

/// Install a panic hook (once per process) that records the panic as a
/// journal event and flushes the journal to stderr, chaining to the
/// previously installed hook first. Caught panics (the executor's
/// per-job `catch_unwind`) also trigger the hook — by design: a worker
/// panic is exactly the moment the recent-event timeline matters.
pub fn install_panic_hook() {
    HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            prev(info);
            let message = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| info.payload().downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic payload>".to_string());
            let location = info
                .location()
                .map(|l| format!("{}:{}", l.file(), l.line()))
                .unwrap_or_else(|| "<unknown>".to_string());
            record(
                "process",
                "panic",
                &[("message", V::s(message)), ("location", V::s(location))],
            );
            eprintln!(
                "--- flight recorder: {} event(s) recorded, {} dropped ---",
                events(),
                dropped()
            );
            eprint!("{}", render_jsonl());
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seqs_are_monotonic_and_lines_parse() {
        let j = Journal::new(8);
        let a = j.record("test", "first", &[("shard", V::u(3))]);
        let b = j.record("test", "second", &[("ok", V::b(true)), ("n", V::i(-2))]);
        assert!(b > a);
        let dump = j.render_jsonl();
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.req_str("event").unwrap(), "first");
        assert_eq!(first.req_str("component").unwrap(), "test");
        assert_eq!(first.req_usize("shard").unwrap(), 3);
        assert!(first.get("ts_ms").is_some());
        let second = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(second.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(second.get("n").unwrap().as_f64(), Some(-2.0));
    }

    #[test]
    fn ring_keeps_the_latest_capacity_events() {
        let j = Journal::new(4);
        for i in 0..10u64 {
            j.record("test", "tick", &[("i", V::u(i))]);
        }
        assert_eq!(j.events(), 10);
        assert_eq!(j.dropped(), 6);
        let dump = j.render_jsonl();
        let seqs: Vec<u64> = dump
            .lines()
            .map(|l| {
                crate::util::json::parse(l).unwrap().req_usize("seq").unwrap() as u64
            })
            .collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest events evicted, order kept");
    }

    #[test]
    fn u64_fields_render_exactly() {
        let j = Journal::new(2);
        j.record("test", "big", &[("trace", V::u(u64::MAX))]);
        let dump = j.render_jsonl();
        assert!(
            dump.contains(&format!("\"trace\":{}", u64::MAX)),
            "exact integer rendering, got: {dump}"
        );
    }

    #[test]
    fn empty_journal_renders_empty() {
        let j = Journal::new(4);
        assert_eq!(j.render_jsonl(), "");
        assert_eq!(j.events(), 0);
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn global_journal_accumulates() {
        let seq = record("test", "global_probe", &[("marker", V::u(42))]);
        assert!(events() > seq);
        assert!(render_jsonl().contains("\"event\":\"global_probe\""));
    }
}
