//! Lock-free log-linear latency histograms.
//!
//! [`ObsHistogram`] is the serving-path latency recorder: a fixed array
//! of atomic buckets, so `record` is a handful of relaxed atomic
//! increments — no mutex, no allocation, no unbounded growth — and the
//! struct is safely shared across every worker thread behind one `Arc`.
//! It replaces the old `Mutex<LatencyStats>` pair in
//! `coordinator::Metrics`, which buffered every sample in a `Vec<f64>`
//! forever (a memory leak on a long-running server) behind a lock on the
//! hot path.
//!
//! **Bucket scheme** (log-linear, HdrHistogram-style): values are
//! recorded in integer microseconds. The first 16 buckets are linear
//! (1 µs wide); above that each power-of-two octave is split into 16
//! linear sub-buckets, so the relative quantization error is at most
//! 1/16 ≈ 6.25 % everywhere. The top octave runs to `u64::MAX` µs, so
//! nothing is ever dropped or clamped. 976 buckets × 8 bytes ≈ 7.6 KiB
//! per histogram, fixed at construction.
//!
//! Quantiles are computed by walking the bucket counts and reporting the
//! *upper* edge of the bucket containing the target rank — "q of the
//! samples were at most this" — which is the conservative direction for
//! latency SLOs. Bucket counts themselves are exact (only the position
//! within a bucket is quantized), which is what the Prometheus
//! exposition renders (see [`super::prom`]).
//!
//! Histograms are mergeable ([`ObsHistogram::merge_from`]): buckets of
//! equal index add, so per-worker or per-node histograms can be folded
//! into a fleet view without losing bucket exactness.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear buckets below this value (µs); also the sub-buckets per octave.
const LINEAR: u64 = 16;
/// log2(LINEAR): octave index shift.
const SUB_BITS: u32 = 4;
/// Total bucket count: 16 linear + 60 octaves × 16 sub-buckets
/// (msb 4..=63 of a u64 microsecond value).
pub const NUM_BUCKETS: usize = 976;

/// Bucket index for a microsecond value. Total order: every value maps
/// to exactly one bucket and bucket lower bounds are strictly
/// increasing with the index.
#[inline]
pub fn bucket_index(us: u64) -> usize {
    if us < LINEAR {
        us as usize
    } else {
        let msb = 63 - us.leading_zeros(); // >= SUB_BITS
        let shift = msb - SUB_BITS;
        let sub = ((us >> shift) & (LINEAR - 1)) as usize;
        (LINEAR as usize) * (msb - SUB_BITS) as usize + sub + LINEAR as usize
    }
}

/// Inclusive lower edge (µs) of bucket `i` — the inverse of
/// [`bucket_index`] on bucket boundaries.
#[inline]
pub fn bucket_lower(i: usize) -> u64 {
    if i < 2 * LINEAR as usize {
        i as u64
    } else {
        let octave = (i - LINEAR as usize) / LINEAR as usize; // msb - SUB_BITS
        let sub = ((i - LINEAR as usize) % LINEAR as usize) as u64;
        (LINEAR + sub) << octave
    }
}

/// Exclusive upper edge (µs) of bucket `i` (`u64::MAX` for the last).
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= NUM_BUCKETS {
        u64::MAX
    } else {
        bucket_lower(i + 1)
    }
}

/// A point-in-time, non-atomic copy of a histogram, for rendering.
/// `total` is recomputed from the copied buckets (not the live counter),
/// so cumulative-bucket invariants hold exactly on the snapshot even
/// while recording continues concurrently.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub total: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl HistogramSnapshot {
    /// Cumulative counts at the given ascending µs edges: entry `j` is
    /// the number of samples strictly below `edges_us[j]`. Edges that
    /// are exact bucket boundaries (powers of two ≥ 16, or any value
    /// ≤ 16) make this exact.
    pub fn cumulative(&self, edges_us: &[u64]) -> Vec<u64> {
        let mut out = Vec::with_capacity(edges_us.len());
        for &edge in edges_us {
            // samples strictly below `edge`: all buckets whose upper
            // edge is <= edge, i.e. indexes < bucket_index(edge)
            let cut = if edge == 0 { 0 } else { bucket_index(edge) };
            out.push(self.buckets[..cut.min(NUM_BUCKETS)].iter().sum());
        }
        out
    }

    /// Sum of recorded values, in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_us as f64 / 1e6
    }

    /// Quantile in seconds (upper bucket edge; 0.0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_us(&self.buckets, self.total, q) as f64 / 1e6
    }
}

fn quantile_us(buckets: &[u64], total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        cum += c;
        if cum >= target {
            return bucket_upper(i);
        }
    }
    bucket_upper(NUM_BUCKETS - 1)
}

/// Lock-free log-linear histogram (see the module docs). All methods
/// take `&self`; recording is wait-free (relaxed atomic adds).
pub struct ObsHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for ObsHistogram {
    fn default() -> Self {
        Self::new()
    }
}

// Terse by hand — deriving would dump every bucket into the output of
// any containing struct's `{:?}`.
impl std::fmt::Debug for ObsHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsHistogram")
            .field("count", &self.count.load(Ordering::Relaxed))
            .field("sum_us", &self.sum_us.load(Ordering::Relaxed))
            .field("max_us", &self.max_us.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl ObsHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one latency in integer microseconds.
    #[inline]
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Record one latency in seconds (negative values clamp to 0).
    #[inline]
    pub fn record_secs(&self, secs: f64) {
        self.record_us((secs.max(0.0) * 1e6).round() as u64);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Largest recorded value, in seconds.
    pub fn max_secs(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Mean of recorded values, in seconds (0.0 when empty).
    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    /// Quantile in seconds: the upper edge of the bucket holding the
    /// q-th ranked sample ("q of samples were at most this"). 0.0 when
    /// empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = buckets.iter().sum();
        quantile_us(&buckets, total, q) as f64 / 1e6
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    /// Samples strictly below `us` (exact when `us` is a bucket edge —
    /// any power of two ≥ 16, or any value ≤ 16; otherwise rounded down
    /// to the nearest edge).
    pub fn count_below_us(&self, us: u64) -> u64 {
        let cut = if us == 0 { 0 } else { bucket_index(us) };
        self.buckets[..cut.min(NUM_BUCKETS)]
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    /// Fold another histogram's counts into this one (bucket-exact).
    pub fn merge_from(&self, other: &ObsHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us
            .fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Point-in-time copy for rendering (see [`HistogramSnapshot`]).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total = buckets.iter().sum();
        HistogramSnapshot {
            buckets,
            total,
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_edges_are_consistent() {
        // every bucket's lower edge maps back to that bucket, and edges
        // strictly increase
        for i in 0..NUM_BUCKETS {
            assert_eq!(bucket_index(bucket_lower(i)), i, "bucket {i}");
            if i + 1 < NUM_BUCKETS {
                assert!(bucket_lower(i) < bucket_lower(i + 1));
            }
        }
        // spot values land between their bucket's edges
        for v in [0u64, 1, 15, 16, 17, 31, 32, 63, 999, 1000, 1024, 1 << 20, u64::MAX] {
            let i = bucket_index(v);
            assert!(bucket_lower(i) <= v, "v={v} i={i}");
            if i + 1 < NUM_BUCKETS {
                assert!(v < bucket_upper(i), "v={v} i={i}");
            }
        }
    }

    #[test]
    fn quantiles_report_upper_bucket_edges() {
        let h = ObsHistogram::new();
        assert_eq!(h.p50(), 0.0, "empty histogram");
        // 100 samples: 1..=100 µs
        for us in 1..=100u64 {
            h.record_us(us);
        }
        assert_eq!(h.count(), 100);
        // p50 = 50th sample = 50 µs -> its bucket [48,56) -> upper 56
        let p50_us = h.p50() * 1e6;
        assert!((48.0..=56.0).contains(&p50_us), "p50 {p50_us}");
        // relative error stays within one sub-bucket (1/16)
        let p99_us = h.p99() * 1e6;
        assert!(p99_us >= 99.0 && p99_us <= 99.0 * (1.0 + 1.0 / 16.0) + 8.0);
        assert!(h.max_secs() >= 100e-6);
        assert!(h.mean_secs() > 0.0);
    }

    #[test]
    fn record_secs_rounds_to_microseconds() {
        let h = ObsHistogram::new();
        h.record_secs(0.002); // 2000 µs -> bucket [1984, 2048)
        assert_eq!(h.count(), 1);
        let p = h.p50() * 1e6;
        assert!((1984.0..=2048.0).contains(&p), "p50 {p}");
        h.record_secs(-1.0); // clamps to 0, never panics
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn merge_adds_bucket_counts() {
        let a = ObsHistogram::new();
        let b = ObsHistogram::new();
        for us in [10u64, 100, 1000] {
            a.record_us(us);
            b.record_us(us);
            b.record_us(us);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 9);
        let snap = a.snapshot();
        assert_eq!(snap.total, 9);
        assert_eq!(snap.buckets[bucket_index(10)], 3);
    }

    #[test]
    fn count_below_is_exact_at_power_of_two_edges() {
        let h = ObsHistogram::new();
        for us in [1u64, 2, 100, 1023, 1024, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count_below_us(1024), 4); // 1, 2, 100, 1023
        assert_eq!(h.count_below_us(16), 2);
        assert_eq!(h.count_below_us(0), 0);
        assert_eq!(h.count_below_us(1 << 30), 6);
    }

    #[test]
    fn concurrent_recording_is_lossless() {
        let h = std::sync::Arc::new(ObsHistogram::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..10_000u64 {
                        h.record_us((t * 7 + i) % 4096);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
        assert_eq!(h.snapshot().total, 40_000);
    }

    #[test]
    fn snapshot_cumulative_matches_count_below() {
        let h = ObsHistogram::new();
        for us in 0..2000u64 {
            h.record_us(us * 3);
        }
        let snap = h.snapshot();
        let edges = [64u64, 1024, 65536];
        let cum = snap.cumulative(&edges);
        for (j, &e) in edges.iter().enumerate() {
            assert_eq!(cum[j], h.count_below_us(e), "edge {e}");
        }
        // monotone in the edge
        assert!(cum[0] <= cum[1] && cum[1] <= cum[2]);
    }
}
