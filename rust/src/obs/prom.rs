//! Prometheus text exposition (format version 0.0.4).
//!
//! Renders the flat `stats` fields plus the stage histograms into the
//! standard text format so off-the-shelf scrapers work against any
//! node, primary or follower. Conventions:
//!
//! - every metric is prefixed `cabin_`;
//! - monotone counters are suffixed `_total` and typed `counter`;
//! - point-in-time values (queue depths, lags, config, `*_ms`
//!   summaries) are typed `gauge` and keep their name;
//! - per-shard flat families (`<base>_shard<i>` in `stats`) render as
//!   one labeled family — `cabin_repl_lag{shard="3"}`,
//!   `cabin_executor_queue_hwm{shard="0"}` — instead of name-suffixed
//!   scalars. Only the exposition changes shape: the flat `stats` wire
//!   names stay grow-only for compat;
//! - histograms render as `cabin_<name>_seconds` families with
//!   cumulative `_bucket{le="…"}` series at power-of-two microsecond
//!   edges (which are exact [`ObsHistogram`](super::ObsHistogram)
//!   bucket boundaries — no re-quantization), plus `_sum` and
//!   `_count`. The `+Inf` bucket and `_count` are computed from the
//!   same snapshot total, so cumulativity holds exactly even while the
//!   server is recording.
//!
//! `stage_*` flat fields are skipped here: the same data is exposed in
//! full fidelity as native histogram families.

use super::histogram::HistogramSnapshot;

/// Cumulative bucket edges for exposition, in µs: powers of 4 from
/// 64 µs to ~16.8 s. All are powers of two ≥ 16, hence exact
/// `ObsHistogram` bucket boundaries.
const EDGES_US: [u64; 10] = [
    64,
    256,
    1024,
    4096,
    16_384,
    65_536,
    262_144,
    1_048_576,
    4_194_304,
    16_777_216,
];

/// Substrings/suffixes marking a flat stats field as a gauge rather
/// than a monotone counter.
fn is_gauge(name: &str) -> bool {
    const GAUGE_MARKS: [&str; 14] = [
        "queue_depth",
        "queue_hwm",
        "busy_workers",
        "generation",
        "_lag",
        "applied_seq",
        "caught_up",
        "diverged",
        "_role",
        "live_bytes",
        "next_seq",
        "dead_frames",
        "recovery_ms",
        "kernel_isa",
    ];
    // `cfg_` appears prefixed (`index_cfg_*`, `persist_cfg_*`): configs
    // are point-in-time values, never monotone
    name.contains("cfg_")
        || name.ends_with("_ms")
        || GAUGE_MARKS.iter().any(|m| name.contains(m))
}

fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn fmt_le(us: u64) -> String {
    // seconds with enough precision to be exact for our µs edges
    let secs = us as f64 / 1e6;
    let s = format!("{secs:.6}");
    let s = s.trim_end_matches('0');
    let s = s.trim_end_matches('.');
    if s.is_empty() {
        "0".to_string()
    } else {
        s.to_string()
    }
}

/// Split a per-shard flat stats name (`<base>_shard<i>`, the grow-only
/// wire spelling) into its family base and shard index.
fn shard_family(name: &str) -> Option<(&str, u64)> {
    let (base, idx) = name.rsplit_once("_shard")?;
    if base.is_empty() || idx.is_empty() || !idx.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some((base, idx.parse().ok()?))
}

/// Render the exposition. `flat` is `Metrics::snapshot()`-shaped
/// `(name, value)` pairs; `hists` is `(base_name, snapshot)` pairs
/// (e.g. `("stage_write_wal", …)`, `("query_latency", …)`).
pub fn render(flat: &[(String, f64)], hists: &[(String, HistogramSnapshot)]) -> String {
    let mut out = String::with_capacity(4096 + hists.len() * 1024);
    let mut emitted_families = std::collections::BTreeSet::new();
    for (name, value) in flat {
        if name.starts_with("stage_") {
            continue; // exposed as native histogram families below
        }
        if let Some((base, _)) = shard_family(name) {
            if !emitted_families.insert(base.to_string()) {
                continue; // family already rendered in full
            }
            // Emit the whole family at the first member: one TYPE line,
            // then every shard's sample sorted by index.
            let mut members: Vec<(u64, f64)> = flat
                .iter()
                .filter_map(|(n, v)| {
                    shard_family(n).filter(|(b, _)| *b == base).map(|(_, si)| (si, *v))
                })
                .collect();
            members.sort_unstable_by_key(|&(si, _)| si);
            let (fam, kind) = if is_gauge(base) {
                (format!("cabin_{base}"), "gauge")
            } else {
                (format!("cabin_{base}_total"), "counter")
            };
            out.push_str(&format!("# TYPE {fam} {kind}\n"));
            for (si, v) in members {
                out.push_str(&format!("{fam}{{shard=\"{si}\"}} {}\n", fmt_value(v)));
            }
            continue;
        }
        if is_gauge(name) {
            out.push_str(&format!("# TYPE cabin_{name} gauge\n"));
            out.push_str(&format!("cabin_{name} {}\n", fmt_value(*value)));
        } else {
            out.push_str(&format!("# TYPE cabin_{name}_total counter\n"));
            out.push_str(&format!("cabin_{name}_total {}\n", fmt_value(*value)));
        }
    }
    for (base, snap) in hists {
        let fam = format!("cabin_{base}_seconds");
        out.push_str(&format!("# TYPE {fam} histogram\n"));
        let cum = snap.cumulative(&EDGES_US);
        for (edge, below) in EDGES_US.iter().zip(&cum) {
            out.push_str(&format!(
                "{fam}_bucket{{le=\"{}\"}} {below}\n",
                fmt_le(*edge)
            ));
        }
        out.push_str(&format!("{fam}_bucket{{le=\"+Inf\"}} {}\n", snap.total));
        out.push_str(&format!("{fam}_sum {}\n", snap.sum_secs()));
        out.push_str(&format!("{fam}_count {}\n", snap.total));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsHistogram;

    #[test]
    fn counters_get_total_suffix_and_gauges_do_not() {
        let flat = vec![
            ("inserts".to_string(), 42.0),
            ("executor_queue_depth".to_string(), 3.0),
            ("index_cfg_bands".to_string(), 4.0),
            ("insert_p50_ms".to_string(), 1.5),
            ("kernel_isa".to_string(), 1.0),
        ];
        let text = render(&flat, &[]);
        assert!(text.contains("# TYPE cabin_inserts_total counter\n"));
        assert!(text.contains("cabin_inserts_total 42\n"));
        assert!(text.contains("# TYPE cabin_executor_queue_depth gauge\n"));
        assert!(text.contains("cabin_executor_queue_depth 3\n"));
        // the selected kernel ISA is a point-in-time value, never a counter
        assert!(text.contains("# TYPE cabin_kernel_isa gauge\n"));
        assert!(text.contains("cabin_kernel_isa 1\n"));
        assert!(text.contains("cabin_index_cfg_bands 4\n"));
        assert!(text.contains("cabin_insert_p50_ms 1.5\n"));
        assert!(!text.contains("cabin_insert_p50_ms_total"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_consistent() {
        let h = ObsHistogram::new();
        for us in [10u64, 100, 5_000, 500_000, 30_000_000] {
            h.record_us(us);
        }
        let text = render(&[], &[("stage_write_wal".to_string(), h.snapshot())]);
        assert!(text.contains("# TYPE cabin_stage_write_wal_seconds histogram\n"));
        // parse bucket counts back out and check monotonicity + count match
        let mut last = 0u64;
        let mut inf = None;
        let mut count = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("cabin_stage_write_wal_seconds_bucket{le=\"") {
                let (le, v) = rest.split_once("\"}").unwrap();
                let v: u64 = v.trim().parse().unwrap();
                assert!(v >= last, "bucket not cumulative at le={le}");
                last = v;
                if le == "+Inf" {
                    inf = Some(v);
                }
            } else if let Some(v) = line.strip_prefix("cabin_stage_write_wal_seconds_count ") {
                count = Some(v.trim().parse::<u64>().unwrap());
            }
        }
        assert_eq!(inf, Some(5));
        assert_eq!(count, Some(5));
        // 10 and 100 µs fall below the 1024 µs edge
        assert!(text.contains("_bucket{le=\"0.001024\"} 2\n"));
        // the 30 s sample exceeds every finite edge but lands in +Inf
        assert!(text.contains("_bucket{le=\"16.777216\"} 4\n"));
    }

    #[test]
    fn per_shard_families_render_with_labels() {
        let flat = vec![
            ("repl_lag_shard0".to_string(), 5.0),
            ("repl_lag_shard10".to_string(), 2.0),
            ("repl_lag_shard2".to_string(), 0.0),
            ("executor_queue_hwm_shard1".to_string(), 7.0),
            ("inserts".to_string(), 1.0),
        ];
        let text = render(&flat, &[]);
        // one TYPE line per family; samples sorted numerically by shard
        assert_eq!(text.matches("# TYPE cabin_repl_lag gauge\n").count(), 1);
        let at = |s: &str| text.find(s).unwrap_or_else(|| panic!("missing {s:?} in:\n{text}"));
        assert!(at("cabin_repl_lag{shard=\"0\"} 5\n") < at("cabin_repl_lag{shard=\"2\"} 0\n"));
        assert!(at("cabin_repl_lag{shard=\"2\"} 0\n") < at("cabin_repl_lag{shard=\"10\"} 2\n"));
        // the name-suffixed scalar spelling is gone from the exposition
        assert!(!text.contains("cabin_repl_lag_shard0"));
        // queue high-water is a point-in-time value, not a counter
        assert!(text.contains("# TYPE cabin_executor_queue_hwm gauge\n"));
        assert!(text.contains("cabin_executor_queue_hwm{shard=\"1\"} 7\n"));
        assert!(!text.contains("executor_queue_hwm_total"));
        // unlabeled scalars are untouched
        assert!(text.contains("cabin_inserts_total 1\n"));
    }

    #[test]
    fn shard_family_parsing_is_strict() {
        assert_eq!(shard_family("repl_lag_shard3"), Some(("repl_lag", 3)));
        assert_eq!(
            shard_family("repl_visibility_age_ms_shard12"),
            Some(("repl_visibility_age_ms", 12))
        );
        assert_eq!(shard_family("num_shards"), None);
        assert_eq!(shard_family("repl_lag"), None);
        assert_eq!(shard_family("_shard5"), None);
        assert_eq!(shard_family("persist_wal_live_bytes"), None);
    }

    #[test]
    fn le_labels_render_exact_seconds() {
        assert_eq!(fmt_le(64), "0.000064");
        assert_eq!(fmt_le(1024), "0.001024");
        assert_eq!(fmt_le(1_048_576), "1.048576");
        assert_eq!(fmt_le(16_777_216), "16.777216");
    }
}
