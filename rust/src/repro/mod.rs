//! Paper-reproduction drivers: one function per table/figure of the
//! evaluation section (see DESIGN.md §4 for the index). Each driver prints
//! the paper-style rows and writes `results/<id>.csv` (+ `.pgm` heatmaps).
//!
//! Scales: our testbed is a laptop-class container, not the authors' Xeon
//! server, so each dataset twin is sampled (`--points` overrides). The
//! *shape* of every comparison (who wins, rough factors, crossovers) is the
//! reproduction target; EXPERIMENTS.md records paper-vs-measured.

pub mod ablations;
pub mod clustering;
pub mod quality;
pub mod speed;
pub mod table1;
pub mod variance;

use crate::data::registry::DatasetSpec;
#[cfg(test)]
use crate::data::registry::TABLE1;
use crate::data::CategoricalDataset;
use crate::util::cli::Args;
use anyhow::{bail, Result};

/// Default per-dataset sample sizes for repro runs (kept small enough that
/// the full `repro all` sweep finishes in minutes; crank with --points).
pub fn default_points(key: &str) -> usize {
    match key {
        "kos" => 400,
        "nips" => 300,
        "enron" => 400,
        "nytimes" => 300,
        "pubmed" => 300,
        "braincell" => 150,
        _ => 300,
    }
}

/// Datasets selected by `--datasets kos,nips,...` (default: all six).
pub fn selected_specs(args: &Args) -> Vec<&'static DatasetSpec> {
    let keys = args.str_list_or(
        "datasets",
        &["kos", "nips", "enron", "nytimes", "pubmed", "braincell"],
    );
    keys.iter()
        .filter_map(|k| DatasetSpec::by_key(k))
        .collect()
}

/// Load (or synthesise) one dataset at repro scale.
pub fn load(spec: &DatasetSpec, args: &Args) -> CategoricalDataset {
    let pts = args.usize_or("points", default_points(spec.key));
    let seed = args.u64_or("seed", 42);
    spec.load_or_synth(&args.str_or("data-dir", "data/uci"), pts, seed)
}

/// Reduced-dimension sweep (Figure 2/3/6-9 x-axis).
pub fn dims(args: &Args) -> Vec<usize> {
    args.usize_list_or("dims", &[100, 300, 500, 1000, 2000])
}

/// Per-baseline wall-clock budget before we declare DNS (paper: 20 hours;
/// here scaled to the testbed).
pub fn budget_secs(args: &Args) -> f64 {
    args.f64_or("budget-secs", 120.0)
}

/// Dispatch `repro <id>`.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => table1::run(args),
        "table3" => speed::table3(args),
        "fig2" => speed::fig2(args),
        "fig3" => quality::fig3_rmse(args),
        "table4" => quality::table4_mae(args),
        "fig11" => quality::fig11_heatmaps(args),
        "fig12" => quality::fig12_error_heatmaps(args),
        "fig4" => variance::fig4_binem(args),
        "fig5" => variance::fig5_stage2(args),
        "fig6" | "fig7" | "fig8" => clustering::fig678_quality(args),
        "fig9" => clustering::fig9_nips(args),
        "fig10" => clustering::fig10_speedup(args),
        "ablation-estimator" => ablations::estimator(args),
        "ablation-psi" => ablations::psi_modes(args),
        "ablation-onehot" => ablations::onehot(args),
        "all" => {
            for id in [
                "table1", "fig4", "fig5", "fig3", "table4", "fig11", "fig12", "fig2", "table3",
                "fig6", "fig9", "fig10", "ablation-estimator", "ablation-psi", "ablation-onehot",
            ] {
                println!("\n================ repro {id} ================");
                run(id, args)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown repro id '{other}' (try table1|table3|table4|fig2..fig12|ablation-*|all)"
        ),
    }
}

/// Pretty-print a table: header + rows of (label, cells).
pub fn print_table(title: &str, header: &[&str], rows: &[(String, Vec<String>)]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for (label, cells) in rows {
        widths[0] = widths[0].max(label.len());
        for (i, c) in cells.iter().enumerate() {
            if i + 1 < widths.len() {
                widths[i + 1] = widths[i + 1].max(c.len());
            }
        }
    }
    let fmt_row = |label: &str, cells: &[String]| {
        let mut line = format!("{:<w$}", label, w = widths[0] + 2);
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:>w$}", c, w = widths.get(i + 1).copied().unwrap_or(8) + 2));
        }
        line
    };
    println!(
        "{}",
        fmt_row(header[0], &header[1..].iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for (label, cells) in rows {
        println!("{}", fmt_row(label, cells));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_rejects_unknown() {
        let args = Args::default();
        assert!(run("not-a-figure", &args).is_err());
    }

    #[test]
    fn selected_specs_filters() {
        let args = Args::parse(["--datasets", "kos,braincell"].iter().map(|s| s.to_string()));
        let specs = selected_specs(&args);
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].key, "kos");
    }

    #[test]
    fn defaults_cover_all_datasets() {
        for s in &TABLE1 {
            assert!(default_points(s.key) > 0);
        }
    }
}
