//! Figures 6–10: clustering quality (purity / NMI / ARI) and speed.
//!
//! Protocol (paper Section 5.4): ground truth = k-mode on the *full*
//! categorical data; each method reduces to dimension d and is clustered —
//! k-mode (binary variant) for discrete sketches, k-means for real-valued
//! embeddings — from the same seeded initial centres; quality is scored
//! against the ground truth.

use crate::analysis::write_csv;
use crate::baselines::{by_key, Reduced};
use crate::bench::{time_budgeted, time_once};
use crate::cluster::{
    adjusted_rand_index, kmeans, kmode, kmode_binary, normalized_mutual_information, purity,
};
use crate::data::CategoricalDataset;
use crate::util::cli::Args;
use anyhow::Result;
use std::sync::Arc;

fn cluster_reduced(red: &Reduced, k: usize, iters: usize, seed: u64) -> Vec<usize> {
    if let Some(bits) = red.as_bits() {
        kmode_binary(bits, k, iters, seed).assignments
    } else {
        kmeans(&red.to_matrix(), k, iters, seed).assignments
    }
}

/// Figures 6, 7, 8 (and the quality part of 9): per dataset × dimension ×
/// method, all three quality metrics in one CSV.
pub fn fig678_quality(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let k = args.usize_or("k", 5);
    let iters = args.usize_or("cluster-iters", 25);
    let dims = super::dims(args);
    let methods = args.str_list_or(
        "methods",
        &["cabin", "bcs", "hlsh", "fh", "sh", "lsa", "pca", "lda", "nnmf"],
    );
    let budget = super::budget_secs(args);
    let mut csv = Vec::new();
    for spec in super::selected_specs(args) {
        let ds = Arc::new(super::load(spec, args));
        let truth = kmode(&ds, k, iters, seed).assignments;
        for &dim in &dims {
            for m in &methods {
                if super::speed::oom_guard(m, &ds, dim).is_some() {
                    csv.push(format!("{},{},{},OOM,OOM,OOM", spec.key, dim, m));
                    continue;
                }
                let reducer = match by_key(m) {
                    Some(r) => r,
                    None => continue,
                };
                let ds2 = Arc::clone(&ds);
                let m_owned = m.clone();
                let result = time_budgeted(budget, move || {
                    let red = by_key(&m_owned).unwrap().reduce(&ds2, dim, seed);
                    cluster_reduced(&red, k, iters, seed)
                });
                drop(reducer);
                match result {
                    Some((assign, _)) => {
                        let p = purity(&truth, &assign);
                        let nmi = normalized_mutual_information(&truth, &assign);
                        let ari = adjusted_rand_index(&truth, &assign);
                        println!(
                            "[fig678] {} d={} {}: purity={:.3} nmi={:.3} ari={:.3}",
                            spec.key, dim, m, p, nmi, ari
                        );
                        csv.push(format!(
                            "{},{},{},{:.4},{:.4},{:.4}",
                            spec.key, dim, m, p, nmi, ari
                        ));
                    }
                    None => {
                        println!("[fig678] {} d={} {}: DNS", spec.key, dim, m);
                        csv.push(format!("{},{},{},DNS,DNS,DNS", spec.key, dim, m));
                    }
                }
            }
        }
    }
    let path = write_csv("fig678", "dataset,dim,method,purity,nmi,ari", &csv)?;
    println!("[fig678] wrote {path} (fig6=purity, fig7=nmi, fig8=ari)");
    Ok(())
}

/// Figure 9: the NIPS-twin clustering across all three metrics.
pub fn fig9_nips(args: &Args) -> Result<()> {
    let mut forced = args.clone();
    forced
        .options
        .insert("datasets".to_string(), "nips".to_string());
    fig678_quality(&forced)
}

/// Figure 10: clustering wall-time on the full-dimension data vs on the
/// 1000-dimension Cabin sketches.
pub fn fig10_speedup(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let k = args.usize_or("k", 5);
    let iters = args.usize_or("cluster-iters", 25);
    let dim = args.usize_or("dim", 1000);
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for spec in super::selected_specs(args) {
        let ds: CategoricalDataset = super::load(spec, args);
        let (_, t_full) = time_once(|| kmode(&ds, k, iters, seed));
        let red = by_key("cabin").unwrap().reduce(&ds, dim, seed);
        let (_, t_sketch_cluster) = time_once(|| {
            let bits = red.as_bits().unwrap();
            kmode_binary(bits, k, iters, seed)
        });
        let speedup = t_full / t_sketch_cluster.max(1e-9);
        rows.push((
            spec.name.to_string(),
            vec![
                format!("{:.3}s", t_full),
                format!("{:.3}s", t_sketch_cluster),
                format!("{:.1}x", speedup),
            ],
        ));
        csv.push(format!(
            "{},{:.6},{:.6},{:.3}",
            spec.key, t_full, t_sketch_cluster, speedup
        ));
    }
    super::print_table(
        &format!("Figure 10 — clustering time: full data vs {dim}-d Cabin sketches"),
        &["dataset", "full", "sketch", "speedup"],
        &rows,
    );
    let path = write_csv("fig10", "dataset,full_secs,sketch_secs,speedup", &csv)?;
    println!("[fig10] wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig678_small() {
        let args = Args::parse(
            [
                "--datasets", "kos", "--points", "36", "--dims", "64", "--methods",
                "cabin,lsa", "--k", "3", "--cluster-iters", "8", "--budget-secs", "60",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        fig678_quality(&args).unwrap();
        let content = std::fs::read_to_string("results/fig678.csv").unwrap();
        assert!(content.contains("cabin"));
        assert!(content.contains("lsa"));
        // cabin purity at moderate dim should be decent
        for line in content.lines().skip(1) {
            let f: Vec<&str> = line.split(',').collect();
            if f[2] == "cabin" {
                let p: f64 = f[3].parse().unwrap();
                assert!(p > 0.4, "cabin purity {p}");
            }
        }
    }

    #[test]
    fn fig10_small() {
        let args = Args::parse(
            [
                "--datasets", "kos", "--points", "30", "--dim", "128", "--k", "3",
                "--cluster-iters", "5",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        fig10_speedup(&args).unwrap();
        assert!(std::path::Path::new("results/fig10.csv").exists());
    }
}
