//! Figures 4–5: variance analysis of the two Cabin stages (box plots as
//! five-number summaries in CSV + console).

use crate::analysis::stats::BoxStats;
use crate::analysis::variance::{binem_avg_abs_errors, binem_pair_errors, stage2_pair_errors};
use crate::analysis::write_csv;
use crate::util::cli::Args;
use crate::util::rng::Xoshiro256;
use anyhow::Result;

/// Figure 4: BinEm variance — single-pair signed errors (top row) and
/// per-run average absolute errors (bottom row), on two dataset twins.
pub fn fig4_binem(args: &Args) -> Result<()> {
    let trials = args.usize_or("trials", 1000);
    let runs = args.usize_or("runs", 100);
    let seed = args.u64_or("seed", 42);
    let mut csv = Vec::new();
    for spec in super::selected_specs(args).iter().take(2) {
        let ds = super::load(spec, args);
        let mut rng = Xoshiro256::new(seed);
        let i = rng.usize_in(0, ds.len());
        let j = (i + 1 + rng.usize_in(0, ds.len() - 1)) % ds.len();
        let pair_errs = binem_pair_errors(&ds, i, j, trials, seed);
        let pair_box = BoxStats::from_samples(&pair_errs);
        let avg_errs = binem_avg_abs_errors(&ds.sample(40.min(ds.len()), &mut rng), runs, seed);
        let avg_box = BoxStats::from_samples(&avg_errs);
        println!(
            "[fig4] {} pair({},{}) truth={} signed-err box: {}",
            spec.key,
            i,
            j,
            ds.points[i].hamming(&ds.points[j]),
            pair_box.csv_row("pair")
        );
        println!("[fig4] {} avg-abs-err box: {}", spec.key, avg_box.csv_row("avg"));
        csv.push(format!("{},{}", spec.key, pair_box.csv_row("pair")));
        csv.push(format!("{},{}", spec.key, avg_box.csv_row("avg")));
    }
    let path = write_csv("fig4", &format!("dataset,{}", BoxStats::CSV_HEADER), &csv)?;
    println!("[fig4] wrote {path}");
    Ok(())
}

/// Figure 5: second-stage compressor error box plots on one random pair
/// (paper uses Enron) across reduced dimensions.
pub fn fig5_stage2(args: &Args) -> Result<()> {
    let trials = args.usize_or("trials", 300);
    let seed = args.u64_or("seed", 42);
    let dims = args.usize_list_or("dims", &[200, 500, 1000, 2000]);
    let methods = args.str_list_or("methods", &["cabin", "bcs", "hlsh", "fh", "sh"]);
    let key = args
        .str_list_or("datasets", &["enron"])
        .first()
        .cloned()
        .unwrap_or_else(|| "enron".into());
    let spec = crate::data::registry::DatasetSpec::by_key(&key)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {key}"))?;
    let ds = super::load(spec, args);
    let mut rng = Xoshiro256::new(seed);
    let i = rng.usize_in(0, ds.len());
    let j = (i + 1 + rng.usize_in(0, ds.len() - 1)) % ds.len();
    println!(
        "[fig5] {} pair ({}, {}), truth HD = {}",
        spec.key,
        i,
        j,
        ds.points[i].hamming(&ds.points[j])
    );
    let mut csv = Vec::new();
    for &dim in &dims {
        for m in &methods {
            let errs = stage2_pair_errors(&ds, m, dim, i, j, trials, seed);
            let b = BoxStats::from_samples(&errs);
            println!("[fig5] d={dim} {m}: {}", b.csv_row(m));
            csv.push(format!("{},{},{}", dim, m, b.csv_row(m)));
        }
    }
    let path = write_csv(
        "fig5",
        &format!("dim,method,{}", BoxStats::CSV_HEADER),
        &csv,
    )?;
    println!("[fig5] wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_small() {
        let args = Args::parse(
            [
                "--datasets", "kos", "--points", "20", "--trials", "50", "--runs", "10",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        fig4_binem(&args).unwrap();
        assert!(std::path::Path::new("results/fig4.csv").exists());
    }

    #[test]
    fn fig5_small() {
        let args = Args::parse(
            [
                "--datasets", "kos", "--points", "16", "--trials", "20", "--dims", "64",
                "--methods", "cabin,fh",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        fig5_stage2(&args).unwrap();
        let content = std::fs::read_to_string("results/fig5.csv").unwrap();
        assert!(content.contains("cabin"));
        assert!(content.contains("fh"));
    }
}
