//! Design-choice ablations called out in DESIGN.md §4 (A1–A3).

use crate::analysis::rmse::rmse;
use crate::analysis::write_csv;
use crate::baselines::by_key;
use crate::data::CategoricalDataset;
use crate::linalg::sparse::Csr;
use crate::sketch::{cham, BinEm, BinSketch, PsiMode};
use crate::util::cli::Args;
use crate::util::rng::Xoshiro256;
use anyhow::Result;

/// A1 — occupancy-inversion vs the Algorithm-2 box exactly as printed.
/// Sweeps sketch density (via d) and reports mean absolute error of both
/// estimators against the true binary Hamming distance.
pub fn estimator(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let n = args.usize_or("n", 20_000);
    let density = args.usize_or("density", 300);
    let dims = args.usize_list_or("dims", &[512, 1024, 2048, 4096, 8192]);
    let pairs = args.usize_or("pairs", 50);
    let mut rng = Xoshiro256::new(seed);
    let mut csv = Vec::new();
    let mut rows = Vec::new();
    for &d in &dims {
        let bs = BinSketch::new(n, d, seed);
        let (mut occ_err, mut lit_err) = (0.0, 0.0);
        for _ in 0..pairs {
            let u = crate::sketch::BitVec::from_indices(n, rng.sample_indices(n, density));
            let v = crate::sketch::BitVec::from_indices(n, rng.sample_indices(n, density));
            let truth = u.xor_count(&v) as f64;
            let (su, sv) = (bs.compress(&u), bs.compress(&v));
            occ_err += (cham::binhamming_occupancy(&su, &sv) - truth).abs();
            lit_err += (cham::binhamming_literal(&su, &sv) - truth).abs();
        }
        occ_err /= pairs as f64;
        lit_err /= pairs as f64;
        rows.push((
            format!("d={d}"),
            vec![format!("{:.2}", occ_err), format!("{:.2}", lit_err)],
        ));
        csv.push(format!("{d},{occ_err:.4},{lit_err:.4}"));
    }
    super::print_table(
        &format!("Ablation A1 — estimator MAE, n={n} density={density} (binary level)"),
        &["dim", "occupancy-inversion", "paper-literal"],
        &rows,
    );
    let path = write_csv("ablation_estimator", "dim,occupancy_mae,literal_mae", &csv)?;
    println!("[A1] wrote {path} — the printed Alg. 2 box (no log) is unusable; see DESIGN.md §1");
    Ok(())
}

/// A2 — shared ψ (as printed in the paper) vs per-attribute ψ (our
/// default): RMSE on a BoW-like twin where category values concentrate on
/// small counts. Shared ψ couples all coordinates holding equal values and
/// blows up the variance that Lemma 2 assumes away.
pub fn psi_modes(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let spec = crate::data::registry::DatasetSpec::by_key(
        args.str_list_or("datasets", &["kos"]).first().map(|s| s.as_str()).unwrap_or("kos"),
    )
    .unwrap();
    let ds = super::load(spec, args);
    let trials = args.usize_or("trials", 30);
    let mut csv = Vec::new();
    let mut rows = Vec::new();
    for (mode, label) in [(PsiMode::Shared, "shared"), (PsiMode::PerAttribute, "per-attribute")] {
        // measure at the BinEm level (isolating stage 1): mean |HD − 2·HD'|
        let mut total = 0.0;
        let mut cnt = 0usize;
        for t in 0..trials {
            let be = BinEm::new(ds.dim(), ds.num_categories(), mode, seed + t as u64);
            let encs: Vec<_> = ds.points.iter().take(20).map(|p| be.encode(p)).collect();
            for i in 0..encs.len() {
                for j in (i + 1)..encs.len() {
                    let truth = ds.points[i].hamming(&ds.points[j]) as f64;
                    total += (truth - 2.0 * encs[i].xor_count(&encs[j]) as f64).abs();
                    cnt += 1;
                }
            }
        }
        let mae = total / cnt as f64;
        rows.push((label.to_string(), vec![format!("{:.2}", mae)]));
        csv.push(format!("{label},{mae:.4}"));
    }
    super::print_table(
        &format!("Ablation A2 — ψ construction, BinEm-level MAE on {} twin", spec.key),
        &["psi mode", "mean |HD − 2·HD'|"],
        &rows,
    );
    let path = write_csv("ablation_psi", "mode,mae", &csv)?;
    println!("[A2] wrote {path}");
    Ok(())
}

/// A3 — Cabin vs the naive one-hot + BinSketch pipeline the paper's
/// introduction warns about: equal estimation quality, c× memory blow-up
/// in the intermediate representation.
pub fn onehot(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let dim = args.usize_or("dim", 512);
    let spec = crate::data::registry::DatasetSpec::by_key("kos").unwrap();
    let ds: CategoricalDataset = super::load(spec, args);

    // Cabin path
    let red = by_key("cabin").unwrap().reduce(&ds, dim, seed);
    let cabin_rmse = rmse(&ds, &red);
    let cabin_mem = ds
        .points
        .iter()
        .map(|p| p.nnz() * 6) // sparse (u32, u16) pairs
        .sum::<usize>();

    // One-hot intermediate (what the naive pipeline materialises)
    let oh = Csr::one_hot_from_dataset(&ds);
    let onehot_mem = oh.memory_bytes();
    let blowup_cols = oh.cols as f64 / ds.dim() as f64;

    let rows = vec![
        (
            "cabin".to_string(),
            vec![
                format!("{:.2}", cabin_rmse),
                crate::util::human_bytes(cabin_mem),
                format!("n={} cols", ds.dim()),
            ],
        ),
        (
            "one-hot+binsketch".to_string(),
            vec![
                format!("≈{:.2}", cabin_rmse), // same estimator downstream
                crate::util::human_bytes(onehot_mem),
                format!("n·c={} cols ({}x)", oh.cols, blowup_cols as usize),
            ],
        ),
    ];
    super::print_table(
        "Ablation A3 — one-hot intermediate blow-up (paper §1/§2 argument)",
        &["pipeline", "rmse", "intermediate mem", "width"],
        &rows,
    );
    let csv = vec![
        format!("cabin,{cabin_rmse:.4},{cabin_mem},{}", ds.dim()),
        format!("onehot,{cabin_rmse:.4},{onehot_mem},{}", oh.cols),
    ];
    let path = write_csv("ablation_onehot", "pipeline,rmse,mem_bytes,cols", &csv)?;
    println!("[A3] wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a1_shows_literal_is_broken() {
        let args = Args::parse(
            ["--n", "5000", "--density", "100", "--dims", "1024", "--pairs", "10"]
                .iter()
                .map(|s| s.to_string()),
        );
        estimator(&args).unwrap();
        let content = std::fs::read_to_string("results/ablation_estimator.csv").unwrap();
        let line = content.lines().nth(1).unwrap();
        let f: Vec<f64> = line.split(',').map(|x| x.parse().unwrap()).collect();
        assert!(f[1] < f[2], "occupancy {} should beat literal {}", f[1], f[2]);
    }

    #[test]
    fn a2_shared_psi_is_worse_on_bow() {
        let args = Args::parse(
            ["--datasets", "kos", "--points", "24", "--trials", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        psi_modes(&args).unwrap();
        let content = std::fs::read_to_string("results/ablation_psi.csv").unwrap();
        let mut vals = std::collections::HashMap::new();
        for line in content.lines().skip(1) {
            let f: Vec<&str> = line.split(',').collect();
            vals.insert(f[0].to_string(), f[1].parse::<f64>().unwrap());
        }
        assert!(
            vals["per-attribute"] < vals["shared"],
            "per-attr {} shared {}",
            vals["per-attribute"],
            vals["shared"]
        );
    }

    #[test]
    fn a3_reports_blowup() {
        let args = Args::parse(
            ["--points", "20", "--dim", "128"].iter().map(|s| s.to_string()),
        );
        onehot(&args).unwrap();
        assert!(std::path::Path::new("results/ablation_onehot.csv").exists());
    }
}
