//! Table 1: dataset statistics — target (from the paper) vs measured on
//! the synthetic twins, auditing the substitution documented in DESIGN.md.

use crate::analysis::write_csv;
use crate::util::cli::Args;
use anyhow::Result;

pub fn run(args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for spec in super::selected_specs(args) {
        let ds = super::load(spec, args);
        let measured_sparsity = 100.0 * ds.sparsity();
        rows.push((
            spec.name.to_string(),
            vec![
                format!("{}", spec.categories),
                format!("{}", ds.num_categories()),
                format!("{}", spec.dimension),
                format!("{}", ds.dim()),
                format!("{:.2}", spec.sparsity_pct),
                format!("{:.2}", measured_sparsity),
                format!("{}", spec.density),
                format!("{}", ds.max_density()),
                format!("{}", ds.len()),
            ],
        ));
        csv.push(format!(
            "{},{},{},{},{},{:.4},{:.4},{},{},{}",
            spec.key,
            spec.categories,
            ds.num_categories(),
            spec.dimension,
            ds.dim(),
            spec.sparsity_pct,
            measured_sparsity,
            spec.density,
            ds.max_density(),
            ds.len()
        ));
    }
    super::print_table(
        "Table 1 — dataset twins (target | measured)",
        &[
            "dataset", "c*", "c", "dim*", "dim", "spars*%", "spars%", "dens*", "dens", "points",
        ],
        &rows,
    );
    let path = write_csv(
        "table1",
        "key,categories_target,categories,dim_target,dim,sparsity_target,sparsity,density_target,density,points",
        &csv,
    )?;
    println!("[table1] wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_on_two_small_twins() {
        let args = crate::util::cli::Args::parse(
            ["--datasets", "kos,nips", "--points", "50"]
                .iter()
                .map(|s| s.to_string()),
        );
        run(&args).unwrap();
        assert!(std::path::Path::new("results/table1.csv").exists());
    }
}
