//! Table 3 + Figure 2: dimensionality-reduction speed.
//!
//! Table 3: per-dataset wall-time ratio `time(baseline)/time(Cabin)` at
//! d = 1000, with OOM/DNS reported when a baseline exceeds the budget (the
//! paper's 20-hour wall, scaled). Figure 2: DR time vs reduced dimension.

use crate::analysis::write_csv;
use crate::baselines::{by_key, ALL_KEYS};
use crate::bench::{time_budgeted, time_once};
use crate::data::CategoricalDataset;
use crate::util::cli::Args;
use anyhow::Result;
use std::sync::Arc;

/// Time one reducer with a DNS budget. Returns seconds, or None for DNS.
fn time_reducer(
    key: &'static str,
    ds: &Arc<CategoricalDataset>,
    dim: usize,
    seed: u64,
    budget: f64,
) -> Option<f64> {
    // memory guard: refuse obviously-OOM configurations up front, like the
    // paper reports (MCA one-hot > ~2^31 nnz cells, VAE dense layers).
    let r = by_key(key)?;
    let ds2 = Arc::clone(ds);
    time_budgeted(budget, move || {
        let red = r.reduce(&ds2, dim, seed);
        // force materialisation
        red.len()
    })
    .map(|(_, t)| t)
}

/// Static OOM model mirroring the paper's reported failure modes
/// (Section 5.5 "Errors during dimensionality reduction"): VAE OOMs on
/// everything but KOS (dense n×h encoder/decoder + Adam state), KT and MCA
/// OOM on the ≥10⁵-dimension datasets (feature×feature correlation matrix;
/// n·c indicator), PCA OOMs when densifying the centered matrix exceeds
/// the container. Calibrated against a reference implementation's working
/// set at full (unsampled) dataset scale — see DESIGN.md §5.
pub fn oom_guard(key: &str, ds: &CategoricalDataset, dim: usize) -> Option<&'static str> {
    let n = ds.dim() as f64;
    let m = ds.len() as f64;
    let gb = 1e9;
    let oom = match key {
        // dense n×h encoder + n×h decoder + grads + Adam m/v, h≈1024 in
        // the reference implementation ⇒ OOM beyond ~10⁴ features
        "vae" => n > 10_000.0,
        // pandas corr: dense feature×feature τ matrix
        "kt" => n * n * 8.0 > 8.0 * gb,
        // one-hot indicator SVD: the randomized-range matrices are dense
        // (n·c) × (k+p) f64 — the allocation that OOMs (we guard rather
        // than let the allocator abort; time_budgeted cannot contain an
        // allocation failure)
        "mca" => {
            let k = (dim.min(ds.len().saturating_sub(1)) + 8) as f64;
            n * ds.num_categories() as f64 * k * 8.0 > 2.0 * gb
        }
        // sklearn PCA densifies the centered matrix
        "pca" => m * n * 8.0 > 8.0 * gb,
        _ => false,
    };
    if oom {
        Some("OOM")
    } else {
        None
    }
}

pub fn table3(args: &Args) -> Result<()> {
    let d = args.usize_or("dim", 1000);
    let seed = args.u64_or("seed", 42);
    let budget = super::budget_secs(args);
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let baselines: Vec<&'static str> = ALL_KEYS.iter().copied().filter(|k| *k != "cabin").collect();

    for spec in super::selected_specs(args) {
        let ds = Arc::new(super::load(spec, args));
        let (_, cabin_t) = time_once(|| by_key("cabin").unwrap().reduce(&ds, d, seed).len());
        let mut cells = vec![format!("{:.3}s", cabin_t)];
        let mut csv_cells = vec![format!("{:.6}", cabin_t)];
        for key in &baselines {
            let cell = if let Some(tag) = oom_guard(key, &ds, d) {
                tag.to_string()
            } else {
                match time_reducer(key, &ds, d, seed, budget) {
                    Some(t) => format!("{:.2}x", t / cabin_t),
                    None => "DNS".to_string(),
                }
            };
            csv_cells.push(cell.clone());
            cells.push(cell);
        }
        csv.push(format!("{},{}", spec.key, csv_cells.join(",")));
        rows.push((spec.name.to_string(), cells));
    }

    let mut header = vec!["dataset", "cabin"];
    header.extend(baselines.iter().copied());
    super::print_table(
        &format!("Table 3 — speedup of Cabin vs baselines at d={d} (ratio = t_baseline/t_cabin)"),
        &header,
        &rows,
    );
    let path = write_csv(
        "table3",
        &format!("dataset,cabin_secs,{}", baselines.join(",")),
        &csv,
    )?;
    println!("[table3] wrote {path} (budget {budget}s ⇒ DNS)");
    Ok(())
}

pub fn fig2(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let budget = super::budget_secs(args);
    let dims = super::dims(args);
    let methods = args.str_list_or("methods", &ALL_KEYS);
    let mut csv = Vec::new();
    for spec in super::selected_specs(args) {
        let ds = Arc::new(super::load(spec, args));
        for &dim in &dims {
            let mut row_cells = Vec::new();
            for key in &methods {
                // PCA/MCA/LSA cannot exceed min(m, n) components — the
                // "missing values beyond a certain point" in Figure 2.
                let rank_bound = ds.len().min(ds.dim());
                let cell = if matches!(key.as_str(), "pca" | "lsa" | "mca") && dim > rank_bound {
                    "NA".to_string()
                } else if let Some(tag) = oom_guard(key, &ds, dim) {
                    tag.to_string()
                } else {
                    let k: &'static str = ALL_KEYS
                        .iter()
                        .copied()
                        .find(|x| x == key)
                        .unwrap_or("cabin");
                    match time_reducer(k, &ds, dim, seed, budget) {
                        Some(t) => format!("{:.6}", t),
                        None => "DNS".to_string(),
                    }
                };
                row_cells.push(cell);
            }
            csv.push(format!("{},{},{}", spec.key, dim, row_cells.join(",")));
            println!(
                "[fig2] {} d={} → {}",
                spec.key,
                dim,
                row_cells.join(" ")
            );
        }
    }
    let path = write_csv(
        "fig2",
        &format!("dataset,dim,{}", methods.join(",")),
        &csv,
    )?;
    println!("[fig2] wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn oom_guard_triggers_for_vae_at_braincell_scale() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 4;
        let mut ds = spec.generate(1);
        // pretend brain-cell dimension
        ds = CategoricalDataset::new("big", 1_306_127, 64, vec![]);
        assert_eq!(oom_guard("vae", &ds, 1000), Some("OOM"));
        assert_eq!(oom_guard("cabin", &ds, 1000), None);
    }

    #[test]
    fn table3_small_run() {
        let args = crate::util::cli::Args::parse(
            [
                "--datasets",
                "kos",
                "--points",
                "40",
                "--dim",
                "64",
                "--budget-secs",
                "30",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        table3(&args).unwrap();
        assert!(std::path::Path::new("results/table3.csv").exists());
    }
}
