//! Figure 3 (RMSE vs dimension), Table 4 (heatmap MAE), Figures 11–12
//! (heatmaps, exact vs estimated vs per-method error maps).

use crate::analysis::heatmap::Heatmap;
use crate::analysis::rmse::rmse;
use crate::analysis::write_csv;
use crate::baselines::{by_key, DISCRETE_KEYS};
use crate::util::cli::Args;
use anyhow::Result;

/// Figure 3: all-pairs RMSE of the discrete-sketch methods per dataset and
/// reduced dimension.
pub fn fig3_rmse(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let dims = super::dims(args);
    let methods = args.str_list_or("methods", &DISCRETE_KEYS);
    let budget = super::budget_secs(args);
    let mut csv = Vec::new();
    for spec in super::selected_specs(args) {
        let ds = std::sync::Arc::new(super::load(spec, args));
        for &dim in &dims {
            let mut cells = Vec::new();
            for key in &methods {
                // OOM/DNS handling mirrors the paper (KT OOMs on the big
                // datasets; Figure 3 notes it couldn't finish on Enron).
                let cell = if super::speed::oom_guard(key, &ds, dim).is_some() {
                    "OOM".to_string()
                } else {
                    let ds2 = std::sync::Arc::clone(&ds);
                    let key2 = key.clone();
                    match crate::bench::time_budgeted(budget, move || {
                        let red = by_key(&key2).expect("method").reduce(&ds2, dim, seed);
                        rmse(&ds2, &red)
                    }) {
                        Some((e, _)) => format!("{:.3}", e),
                        None => "DNS".to_string(),
                    }
                };
                cells.push(cell);
            }
            println!("[fig3] {} d={}: {}", spec.key, dim, cells.join(" "));
            csv.push(format!("{},{},{}", spec.key, dim, cells.join(",")));
        }
    }
    let path = write_csv("fig3", &format!("dataset,dim,{}", methods.join(",")), &csv)?;
    println!("[fig3] wrote {path}");
    Ok(())
}

/// Table 4 + Figures 11/12: heatmaps on the BrainCell twin (or --datasets),
/// MAE per method, PGM renderings of exact / estimated / error maps.
pub fn table4_mae(args: &Args) -> Result<()> {
    heatmap_suite(args, false)
}

pub fn fig11_heatmaps(args: &Args) -> Result<()> {
    heatmap_suite(args, true)
}

pub fn fig12_error_heatmaps(args: &Args) -> Result<()> {
    heatmap_suite(args, true)
}

fn heatmap_suite(args: &Args, write_images: bool) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let dim = args.usize_or("dim", 1000);
    let methods = args.str_list_or("methods", &["cabin", "bcs", "hlsh", "fh", "sh"]);
    let specs = {
        let sel = args.str_list_or("datasets", &["braincell"]);
        sel
    };
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for key in &specs {
        let spec = match crate::data::registry::DatasetSpec::by_key(key) {
            Some(s) => s,
            None => continue,
        };
        let ds = super::load(spec, args);
        let exact = Heatmap::exact(&ds);
        if write_images {
            exact.write_pgm(&format!("results/fig11_{}_exact.pgm", spec.key))?;
        }
        let mut cells = Vec::new();
        for m in &methods {
            let red = by_key(m).expect("method").reduce(&ds, dim, seed);
            let est = Heatmap::estimated(&red);
            let mae = est.mae_vs(&exact);
            cells.push(format!("{:.2}", mae));
            csv.push(format!("{},{},{:.6}", spec.key, m, mae));
            if write_images {
                est.write_pgm(&format!("results/fig11_{}_{}.pgm", spec.key, m))?;
                est.error_vs(&exact)
                    .write_pgm(&format!("results/fig12_{}_{}_error.pgm", spec.key, m))?;
            }
        }
        rows.push((spec.name.to_string(), cells));
    }
    let mut header = vec!["dataset"];
    header.extend(methods.iter().map(|s| s.as_str()));
    super::print_table(
        &format!("Table 4 — heatmap MAE at d={dim} (lower is better)"),
        &header,
        &rows,
    );
    let path = write_csv("table4", "dataset,method,mae", &csv)?;
    println!("[table4] wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_args(extra: &[&str]) -> Args {
        let mut v = vec!["--datasets", "kos", "--points", "40", "--dims", "64,128"];
        v.extend_from_slice(extra);
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn fig3_runs_and_cabin_wins_vs_hlsh() {
        fig3_rmse(&small_args(&["--methods", "cabin,hlsh"])).unwrap();
        let content = std::fs::read_to_string("results/fig3.csv").unwrap();
        let last = content.lines().last().unwrap();
        let f: Vec<&str> = last.split(',').collect();
        let cabin: f64 = f[2].parse().unwrap();
        let hlsh: f64 = f[3].parse().unwrap();
        assert!(cabin < hlsh, "cabin {cabin} hlsh {hlsh}");
    }

    #[test]
    fn table4_runs_small() {
        let args = Args::parse(
            [
                "--datasets", "kos", "--points", "30", "--dim", "128", "--methods", "cabin,fh",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        table4_mae(&args).unwrap();
        let content = std::fs::read_to_string("results/table4.csv").unwrap();
        assert!(content.lines().count() >= 3);
    }
}
