//! Deterministic fault injection: a process-wide failpoint registry.
//!
//! Grown out of `WalWriter::fail_next_commit` (PR 5's single-site,
//! single-shot injector): the failover work needs *many* sites — socket
//! accept/read/write, fsync, snapshot rotation, shipper frame
//! boundaries — armed from *outside* the process (the two-process chaos
//! soaks partition a live primary by flipping its failpoints at
//! runtime), so the mechanism becomes a named registry with three
//! arming paths:
//!
//! * **Programmatic** — [`arm`]/[`disarm`] from in-process tests.
//! * **Environment** — `CABIN_FAILPOINTS="site=action,site=action"`
//!   parsed once at first [`check`]; fixed for the process lifetime.
//! * **File** — `CABIN_FAILPOINTS_FILE=/path` names a spec file
//!   (one `site=action` per line, `#` comments) that is re-read
//!   whenever its mtime/length changes, letting a test harness
//!   partition and heal a *running* server by rewriting one file.
//!
//! Actions: `err` (fail every hit), `err:N` (fail the next N hits,
//! then disarm), `sleep:MS` (delay every hit — the "slow, not dead"
//! simulation), `sleep:MS:N`, `off`.
//!
//! Registered sites: `accept`, `conn_read`, `conn_write` (server socket
//! seams), `fsync`, `snapshot_rotate` (persistence), `ship_frames`,
//! `ship_snapshot_shard` (replication shipper), `ttl_sweep` (skip one
//! sweep pass), `executor_submit` (delay-only — stall the scatter
//! path), `batcher_flush` (defer one batch flush to the next tick).
//!
//! **Zero-cost when disabled.** [`check`] is a relaxed atomic load and
//! a branch unless something is armed; the registry lock, the spec
//! parse and the file stat are all behind it. Production binaries run
//! with the flag permanently false unless an operator sets the env
//! vars, which is the explicit opt-in.
//!
//! Sites fail *politely*: a tripped failpoint returns an error the
//! call site maps onto its ordinary failure path (a dropped
//! connection, a failed fsync, a torn transfer) — injection explores
//! real error-handling code, it never introduces new behaviour.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, Once, OnceLock, PoisonError};
use std::time::{Duration, SystemTime};

/// Fast-path gate: false ⇒ no site is armed and [`check`] returns
/// immediately. Kept true for the whole process lifetime in file mode
/// (the file may gain sites at any moment).
static ARMED: AtomicBool = AtomicBool::new(false);

#[derive(Clone, Debug, PartialEq)]
enum Kind {
    /// Return an injected error from the site.
    Err,
    /// Delay the site by this many milliseconds, then succeed.
    Sleep(u64),
}

#[derive(Clone, Debug, PartialEq)]
struct Action {
    kind: Kind,
    /// `None` = every hit; `Some(n)` = the next `n` hits, then disarm.
    remaining: Option<u64>,
}

struct Registry {
    /// Programmatic + env-armed sites.
    sites: HashMap<String, Action>,
    /// File-armed sites, kept apart so a file reload replaces exactly
    /// what the file armed and never clobbers programmatic arming.
    file_sites: HashMap<String, Action>,
    /// `CABIN_FAILPOINTS_FILE` source, with the (mtime, len) stamp of
    /// the last parse so an unchanged file is never re-read (count
    /// decrements would otherwise be reset every hit).
    file: Option<(std::path::PathBuf, Option<(SystemTime, u64)>)>,
}

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| {
        Mutex::new(Registry {
            sites: HashMap::new(),
            file_sites: HashMap::new(),
            file: None,
        })
    })
}

fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One-time env arming, run from the first [`check`] of the process.
fn init_from_env() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        let mut reg = lock_recover(registry());
        if let Ok(spec) = std::env::var("CABIN_FAILPOINTS") {
            match parse_spec(&spec) {
                Ok(sites) => reg.sites.extend(sites),
                Err(e) => eprintln!("[fault] ignoring CABIN_FAILPOINTS: {e}"),
            }
        }
        if let Ok(path) = std::env::var("CABIN_FAILPOINTS_FILE") {
            if !path.is_empty() {
                reg.file = Some((path.into(), None));
            }
        }
        if !reg.sites.is_empty() || reg.file.is_some() {
            ARMED.store(true, Ordering::SeqCst);
        }
    });
}

/// Parse `site=action[,site=action...]` (commas or newlines separate
/// entries; `#` starts a comment; blank entries ignored).
fn parse_spec(spec: &str) -> Result<Vec<(String, Action)>, String> {
    let mut out = Vec::new();
    for raw in spec.split(|c| c == ',' || c == '\n') {
        let entry = raw.split('#').next().unwrap_or("").trim();
        if entry.is_empty() {
            continue;
        }
        let (site, action) = entry
            .split_once('=')
            .ok_or_else(|| format!("'{entry}' is not site=action"))?;
        let site = site.trim();
        if site.is_empty() {
            return Err(format!("'{entry}' has an empty site name"));
        }
        if let Some(action) = parse_action(action.trim())? {
            out.push((site.to_string(), action));
        }
    }
    Ok(out)
}

/// Parse one action; `Ok(None)` for `off`.
fn parse_action(s: &str) -> Result<Option<Action>, String> {
    let mut parts = s.split(':');
    let head = parts.next().unwrap_or("");
    let parse_n = |p: Option<&str>, what: &str| -> Result<Option<u64>, String> {
        match p {
            None => Ok(None),
            Some(n) => n
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("{what} '{n}' is not a u64")),
        }
    };
    let action = match head {
        "off" => return Ok(None),
        "err" => Action {
            kind: Kind::Err,
            remaining: parse_n(parts.next(), "err count")?,
        },
        "sleep" => Action {
            kind: Kind::Sleep(
                parse_n(parts.next(), "sleep millis")?
                    .ok_or_else(|| "sleep needs millis: sleep:MS[:N]".to_string())?,
            ),
            remaining: parse_n(parts.next(), "sleep count")?,
        },
        other => return Err(format!("unknown failpoint action '{other}'")),
    };
    if parts.next().is_some() {
        return Err(format!("trailing fields in action '{s}'"));
    }
    Ok(Some(action))
}

/// Re-parse the spec file if its stamp moved. Holding the lock across
/// the stat/read is fine: this only runs while something is armed.
fn refresh_from_file(reg: &mut Registry) {
    let Some((path, stamp)) = &mut reg.file else {
        return;
    };
    let new_stamp = std::fs::metadata(&*path)
        .ok()
        .and_then(|m| Some((m.modified().ok()?, m.len())));
    if new_stamp == *stamp {
        return;
    }
    *stamp = new_stamp;
    // the file owns its own sites: an emptied/removed file heals
    // every site it armed, and nothing armed another way
    let text = std::fs::read_to_string(&*path).unwrap_or_default();
    match parse_spec(&text) {
        Ok(sites) => reg.file_sites = sites.into_iter().collect(),
        Err(e) => eprintln!("[fault] ignoring failpoint file: {e}"),
    }
}

fn hit_slow(site: &str) -> Result<(), String> {
    let decision = {
        let mut reg = lock_recover(registry());
        refresh_from_file(&mut reg);
        let from_file = reg.file_sites.contains_key(site);
        let action = if from_file {
            reg.file_sites.get_mut(site)
        } else {
            reg.sites.get_mut(site)
        };
        let Some(action) = action else {
            return Ok(());
        };
        let kind = action.kind.clone();
        if let Some(n) = &mut action.remaining {
            *n -= 1;
            if *n == 0 {
                if from_file {
                    reg.file_sites.remove(site);
                } else {
                    reg.sites.remove(site);
                }
                if reg.sites.is_empty() && reg.file.is_none() {
                    ARMED.store(false, Ordering::SeqCst);
                }
            }
        }
        kind
    }; // lock dropped before any sleep
    match decision {
        Kind::Err => Err(format!("failpoint '{site}' injected an error")),
        Kind::Sleep(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
    }
}

/// Hit a failpoint site: `Ok` (possibly after an injected delay)
/// unless the site is armed to fail. The no-failpoints fast path is
/// one relaxed atomic load.
pub fn check(site: &str) -> Result<(), String> {
    init_from_env();
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    hit_slow(site)
}

/// [`check`] adapted to I/O call sites: an injected failure becomes an
/// ordinary `io::Error`, taking the same propagation path a real
/// syscall failure would.
pub fn check_io(site: &str) -> std::io::Result<()> {
    check(site).map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))
}

/// Programmatically arm `site` with an action spec (`err`, `err:N`,
/// `sleep:MS`, `sleep:MS:N`, `off`).
pub fn arm(site: &str, spec: &str) -> Result<(), String> {
    init_from_env();
    let action = parse_action(spec)?;
    let mut reg = lock_recover(registry());
    match action {
        Some(a) => {
            reg.sites.insert(site.to_string(), a);
            ARMED.store(true, Ordering::SeqCst);
        }
        None => {
            reg.sites.remove(site);
            if reg.sites.is_empty() && reg.file.is_none() {
                ARMED.store(false, Ordering::SeqCst);
            }
        }
    }
    Ok(())
}

/// Disarm `site` (equivalent to `arm(site, "off")`).
pub fn disarm(site: &str) {
    let _ = arm(site, "off");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_grammar() {
        assert_eq!(parse_action("off").unwrap(), None);
        assert_eq!(
            parse_action("err").unwrap(),
            Some(Action {
                kind: Kind::Err,
                remaining: None
            })
        );
        assert_eq!(
            parse_action("err:3").unwrap(),
            Some(Action {
                kind: Kind::Err,
                remaining: Some(3)
            })
        );
        assert_eq!(
            parse_action("sleep:25").unwrap(),
            Some(Action {
                kind: Kind::Sleep(25),
                remaining: None
            })
        );
        assert_eq!(
            parse_action("sleep:25:2").unwrap(),
            Some(Action {
                kind: Kind::Sleep(25),
                remaining: Some(2)
            })
        );
        assert!(parse_action("sleep").unwrap_err().contains("needs millis"));
        assert!(parse_action("explode").unwrap_err().contains("unknown"));
        assert!(parse_action("err:x").unwrap_err().contains("not a u64"));
        assert!(parse_action("err:1:2:3").unwrap_err().contains("trailing"));
    }

    #[test]
    fn spec_grammar_commas_newlines_comments() {
        let sites = parse_spec("a=err:1, b=sleep:5\n# partition\nc=err\n\n").unwrap();
        assert_eq!(sites.len(), 3);
        assert_eq!(sites[0].0, "a");
        assert_eq!(sites[2].1.kind, Kind::Err);
        assert!(parse_spec("nope").is_err());
        assert!(parse_spec("=err").is_err());
        // `off` entries parse and arm nothing
        assert_eq!(parse_spec("a=off").unwrap().len(), 0);
    }

    // Registry tests use unique site names: the registry is process
    // global and the test harness runs tests concurrently.

    #[test]
    fn unarmed_site_is_ok() {
        assert!(check("test_unarmed_site_never_used").is_ok());
    }

    #[test]
    fn err_countdown_disarms_itself() {
        arm("test_fault_countdown", "err:2").unwrap();
        assert!(check("test_fault_countdown").is_err());
        assert!(check("test_fault_countdown").is_err());
        assert!(check("test_fault_countdown").is_ok(), "count exhausted");
    }

    #[test]
    fn persistent_err_until_disarmed() {
        arm("test_fault_persistent", "err").unwrap();
        for _ in 0..5 {
            assert!(check("test_fault_persistent").is_err());
        }
        disarm("test_fault_persistent");
        assert!(check("test_fault_persistent").is_ok());
    }

    #[test]
    fn sleep_delays_and_succeeds() {
        arm("test_fault_sleep", "sleep:30:1").unwrap();
        let t0 = std::time::Instant::now();
        assert!(check("test_fault_sleep").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(25), "delay injected");
        let t0 = std::time::Instant::now();
        assert!(check("test_fault_sleep").is_ok());
        assert!(t0.elapsed() < Duration::from_millis(25), "count exhausted");
    }

    #[test]
    fn check_io_maps_to_io_error() {
        arm("test_fault_io", "err:1").unwrap();
        let e = check_io("test_fault_io").unwrap_err();
        assert!(e.to_string().contains("failpoint 'test_fault_io'"));
        assert!(check_io("test_fault_io").is_ok());
    }

    #[test]
    fn file_source_rearms_on_change() {
        let dir = crate::testing::TempDir::new("fault-file");
        let path = dir.path().join("failpoints");
        std::fs::write(&path, "test_fault_file=err\n").unwrap();
        {
            let mut reg = lock_recover(registry());
            reg.file = Some((path.clone(), None));
        }
        ARMED.store(true, Ordering::SeqCst);
        assert!(check("test_fault_file").is_err());
        // rewrite → heal; each rewrite changes the length, so the
        // (mtime, len) stamp flips even within mtime granularity
        std::fs::write(&path, "").unwrap();
        assert!(check("test_fault_file").is_ok());
        std::fs::write(&path, "test_fault_file=err:1\n").unwrap();
        assert!(check("test_fault_file").is_err());
        {
            let mut reg = lock_recover(registry());
            reg.file = None;
            reg.file_sites.clear();
        }
    }
}
