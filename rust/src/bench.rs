//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage in a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = cabin::bench::Bench::from_env("bench_cham");
//! b.bench("cham/allpairs/2000x1000", || { ...work... });
//! b.finish();
//! ```
//!
//! The harness warms up, then runs timed iterations until both a minimum
//! iteration count and a minimum measurement time are reached, and reports
//! mean/p50/p95 plus throughput when provided. Results are also appended to
//! `results/bench_<name>.csv` so the paper-table drivers can consume them.

use crate::util::timer::{LatencyStats, Stopwatch, Summary};
use std::io::Write;

pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_secs: f64,
    /// Overall wall-clock cap per benchmark (e.g. DNS cut-off in repro runs).
    pub max_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            min_secs: 0.5,
            max_secs: 30.0,
        }
    }
}

impl BenchConfig {
    /// Fast profile for CI / `--fast` runs.
    pub fn fast() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 20,
            min_secs: 0.05,
            max_secs: 5.0,
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub throughput_units: Option<f64>,
}

pub struct Bench {
    pub suite: String,
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(suite: &str, config: BenchConfig) -> Self {
        Self {
            suite: suite.to_string(),
            config,
            results: Vec::new(),
        }
    }

    /// Honour `CABIN_BENCH_FAST=1` (used by `cargo bench` in CI).
    pub fn from_env(suite: &str) -> Self {
        let cfg = if std::env::var("CABIN_BENCH_FAST").ok().as_deref() == Some("1") {
            BenchConfig::fast()
        } else {
            BenchConfig::default()
        };
        Self::new(suite, cfg)
    }

    /// Time `f` repeatedly; returns mean seconds per iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> f64 {
        self.bench_with_throughput(name, None, f)
    }

    /// Like [`Bench::bench`] but reports `units/sec` (e.g. points, pairs).
    pub fn bench_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        units: Option<f64>,
        mut f: F,
    ) -> f64 {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut stats = LatencyStats::new();
        let total = Stopwatch::start();
        let mut iters = 0usize;
        loop {
            let sw = Stopwatch::start();
            f();
            stats.record(sw.elapsed_secs());
            iters += 1;
            let t = total.elapsed_secs();
            let enough = iters >= self.config.min_iters && t >= self.config.min_secs;
            let capped = iters >= self.config.max_iters || t >= self.config.max_secs;
            if enough || capped {
                break;
            }
        }
        let summary = stats.summary();
        println!(
            "{:<52} {}",
            format!("{}/{}", self.suite, name),
            summary.format_line(units)
        );
        let mean = summary.mean;
        self.results.push(BenchResult {
            name: name.to_string(),
            summary,
            throughput_units: units,
        });
        mean
    }

    /// Write accumulated results to `results/bench_<suite>.csv`.
    pub fn finish(&self) {
        if self.results.is_empty() {
            return;
        }
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/bench_{}.csv", self.suite);
        let mut out = String::from("name,iters,mean_s,p50_s,p95_s,p99_s,max_s,thrpt_per_s\n");
        for r in &self.results {
            let thrpt = match r.throughput_units {
                Some(u) if r.summary.mean > 0.0 => format!("{:.3}", u / r.summary.mean),
                _ => String::new(),
            };
            out.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9},{:.9},{:.9},{}\n",
                r.name,
                r.summary.count,
                r.summary.mean,
                r.summary.p50,
                r.summary.p95,
                r.summary.p99,
                r.summary.max,
                thrpt
            ));
        }
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = f.write_all(out.as_bytes());
        }
        println!("[bench] wrote {}", path);
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single closure once (used by the repro drivers where algorithms
/// are too slow to iterate, mirroring the paper's one-shot DR timings).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let sw = Stopwatch::start();
    let v = f();
    (v, sw.elapsed_secs())
}

/// Run `f` with a wall-clock budget; `None` means it exceeded the budget
/// (the paper's "DNS — did not stop"). The closure is run on a worker
/// thread; on timeout the thread is left to finish in the background
/// (detached) — callers should only use this at process scope.
pub fn time_budgeted<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(
    budget_secs: f64,
    f: F,
) -> Option<(T, f64)> {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let sw = Stopwatch::start();
        let v = f();
        let _ = tx.send((v, sw.elapsed_secs()));
    });
    rx.recv_timeout(std::time::Duration::from_secs_f64(budget_secs))
        .ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new(
            "testsuite",
            BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 5,
                min_secs: 0.0,
                max_secs: 1.0,
            },
        );
        let mut count = 0usize;
        b.bench("noop", || {
            count += 1;
        });
        assert!(count >= 3);
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].summary.count >= 3);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn budget_times_out() {
        let r = time_budgeted(0.05, || {
            std::thread::sleep(std::time::Duration::from_millis(500));
            1
        });
        assert!(r.is_none());
        let r = time_budgeted(5.0, || 7);
        assert_eq!(r.unwrap().0, 7);
    }
}
