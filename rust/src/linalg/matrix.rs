//! Row-major dense matrix with a blocked, thread-parallel matmul.

use crate::util::parallel;
use crate::util::rng::Xoshiro256;

/// Row-major `rows × cols` matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Standard-normal random matrix (for randomized SVD / VAE init).
    pub fn randn(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Self {
        Self {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal()).collect(),
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// `self @ other` — blocked, parallel over row stripes of the output.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        let threads = parallel::default_threads().min(m.max(1));
        let rows_per = m.div_ceil(threads).max(1);
        let a = &self.data;
        let b = &other.data;
        std::thread::scope(|s| {
            for (ti, chunk) in out.data.chunks_mut(rows_per * n).enumerate() {
                let r0 = ti * rows_per;
                s.spawn(move || {
                    for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
                        let r = r0 + ri;
                        // ikj loop: stream rows of b, accumulate into out_row
                        for kk in 0..k {
                            let aval = a[r * k + kk];
                            if aval == 0.0 {
                                continue;
                            }
                            let brow = &b[kk * n..(kk + 1) * n];
                            for (o, &bv) in out_row.iter_mut().zip(brow) {
                                *o += aval * bv;
                            }
                        }
                    }
                });
            }
        });
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| dot(self.row(r), x))
            .collect()
    }

    /// Mean of each column (for PCA centering).
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (mc, &v) in m.iter_mut().zip(self.row(r)) {
                *mc += v;
            }
        }
        let inv = 1.0 / self.rows.max(1) as f64;
        for v in m.iter_mut() {
            *v *= inv;
        }
        m
    }

    /// Subtract a row vector from every row.
    pub fn sub_row_vector(&mut self, v: &[f64]) {
        assert_eq!(v.len(), self.cols);
        for r in 0..self.rows {
            for (x, &m) in self.row_mut(r).iter_mut().zip(v) {
                *x -= m;
            }
        }
    }

    pub fn scale(&mut self, s: f64) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let n = a.len();
    let mut i = 0;
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = s0 + s1 + s2 + s3;
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Xoshiro256::new(1);
        let a = Matrix::randn(17, 23, &mut rng);
        let b = Matrix::randn(23, 9, &mut rng);
        let c = a.matmul(&b);
        for r in 0..17 {
            for cc in 0..9 {
                let mut s = 0.0;
                for k in 0..23 {
                    s += a.get(r, k) * b.get(k, cc);
                }
                assert!((c.get(r, cc) - s).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::new(2);
        let a = Matrix::randn(40, 70, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(3, 7), a.get(7, 3));
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Xoshiro256::new(3);
        let a = Matrix::randn(11, 13, &mut rng);
        let x: Vec<f64> = (0..13).map(|i| i as f64).collect();
        let xm = Matrix::from_vec(13, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        for i in 0..11 {
            assert!((via_mm.get(i, 0) - via_mv[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn centering() {
        let mut a = Matrix::from_rows(vec![vec![1.0, 10.0], vec![3.0, 20.0]]);
        let m = a.col_means();
        assert_eq!(m, vec![2.0, 15.0]);
        a.sub_row_vector(&m);
        assert_eq!(a.col_means(), vec![0.0, 0.0]);
    }
}
