//! Thin QR + randomized truncated SVD (Halko–Martinsson–Tropp 2011).
//!
//! `randomized_svd(A, k, oversample, power_iters)`:
//! 1. `Y = (A Aᵀ)^q A Ω` for a Gaussian `Ω ∈ R^{n×(k+p)}` (power iterations
//!    sharpen the spectrum),
//! 2. thin QR of `Y` gives an orthonormal range basis `Q`,
//! 3. SVD of the small `B = Qᵀ A` via one-sided Jacobi on `B Bᵀ`.
//!
//! Accuracy is more than enough for the PCA/LSA/MCA *baselines* — the paper
//! itself only uses them as comparison points.

use super::matrix::{dot, norm2, Matrix};
use crate::util::rng::Xoshiro256;

/// Truncated SVD result: `A ≈ U diag(s) Vᵀ` with `U: m×k`, `V: n×k`.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub v: Matrix,
}

/// Thin QR via modified Gram–Schmidt with one re-orthogonalisation pass.
/// Returns Q (m×k) with orthonormal columns; rank-deficient columns are
/// replaced with zeros (harmless for the randomized-range use).
pub fn thin_qr_q(a: &Matrix) -> Matrix {
    let (m, k) = (a.rows, a.cols);
    // work in column-major for column ops
    let mut cols: Vec<Vec<f64>> = (0..k)
        .map(|c| (0..m).map(|r| a.get(r, c)).collect())
        .collect();
    for j in 0..k {
        for _pass in 0..2 {
            for i in 0..j {
                let proj = dot(&cols[j], &cols[i]);
                let (ci, cj) = if i < j {
                    let (lo, hi) = cols.split_at_mut(j);
                    (&lo[i], &mut hi[0])
                } else {
                    unreachable!()
                };
                for (x, &y) in cj.iter_mut().zip(ci.iter()) {
                    *x -= proj * y;
                }
            }
        }
        let nrm = norm2(&cols[j]);
        if nrm > 1e-12 {
            let inv = 1.0 / nrm;
            for x in cols[j].iter_mut() {
                *x *= inv;
            }
        } else {
            for x in cols[j].iter_mut() {
                *x = 0.0;
            }
        }
    }
    let mut q = Matrix::zeros(m, k);
    for (c, col) in cols.iter().enumerate() {
        for (r, &v) in col.iter().enumerate() {
            q.set(r, c, v);
        }
    }
    q
}

/// Eigendecomposition of a small symmetric PSD matrix via cyclic Jacobi.
/// Returns (eigenvalues desc, eigenvectors as columns).
pub fn jacobi_eigh(a: &Matrix, max_sweeps: usize) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Matrix::zeros(n, n);
    for i in 0..n {
        v.set(i, i, 1.0);
    }
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m.get(p, q).abs();
            }
        }
        if off < 1e-11 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for i in 0..n {
                    let mip = m.get(i, p);
                    let miq = m.get(i, q);
                    m.set(i, p, c * mip - s * miq);
                    m.set(i, q, s * mip + c * miq);
                }
                for i in 0..n {
                    let mpi = m.get(p, i);
                    let mqi = m.get(q, i);
                    m.set(p, i, c * mpi - s * mqi);
                    m.set(q, i, s * mpi + c * mqi);
                }
                for i in 0..n {
                    let vip = v.get(i, p);
                    let viq = v.get(i, q);
                    v.set(i, p, c * vip - s * viq);
                    v.set(i, q, s * vip + c * viq);
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let vals: Vec<f64> = pairs.iter().map(|&(val, _)| val.max(0.0)).collect();
    let mut vecs = Matrix::zeros(n, n);
    for (new_c, &(_, old_c)) in pairs.iter().enumerate() {
        for r in 0..n {
            vecs.set(r, new_c, v.get(r, old_c));
        }
    }
    (vals, vecs)
}

/// Randomized truncated SVD. `a` is accessed via matmuls only.
pub fn randomized_svd(a: &Matrix, k: usize, oversample: usize, power_iters: usize, seed: u64) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = k.min(m.min(n));
    let l = (k + oversample).min(m.min(n)).max(1);
    let mut rng = Xoshiro256::new(seed);
    let omega = Matrix::randn(n, l, &mut rng);
    let mut y = a.matmul(&omega); // m × l
    let at = a.transpose();
    for _ in 0..power_iters {
        // re-orthonormalise between powers for stability
        y = thin_qr_q(&y);
        let z = at.matmul(&y); // n × l
        let zq = thin_qr_q(&z);
        y = a.matmul(&zq);
    }
    let q = thin_qr_q(&y); // m × l, orthonormal columns
    let b = q.transpose().matmul(a); // l × n
    // SVD of small B via eigh(B Bᵀ): B = Ub S Vᵀ, B Bᵀ = Ub S² Ubᵀ
    let bbt = b.matmul(&b.transpose()); // l × l
    let (evals, evecs) = jacobi_eigh(&bbt, 60);
    let mut s: Vec<f64> = evals.iter().take(k).map(|&e| e.max(0.0).sqrt()).collect();
    // U = Q · Ub[:, :k]
    let mut ub_k = Matrix::zeros(b.rows, k);
    for c in 0..k {
        for r in 0..b.rows {
            ub_k.set(r, c, evecs.get(r, c));
        }
    }
    let u = q.matmul(&ub_k); // m × k
    // V = Bᵀ Ub S⁻¹
    let mut v = b.transpose().matmul(&ub_k); // n × k
    for c in 0..k {
        let inv = if s[c] > 1e-12 { 1.0 / s[c] } else { 0.0 };
        for r in 0..n {
            let val = v.get(r, c) * inv;
            v.set(r, c, val);
        }
    }
    while s.len() < k {
        s.push(0.0);
    }
    Svd { u, s, v }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_orthonormal_columns() {
        let mut rng = Xoshiro256::new(1);
        let a = Matrix::randn(50, 8, &mut rng);
        let q = thin_qr_q(&a);
        for i in 0..8 {
            let ci: Vec<f64> = (0..50).map(|r| q.get(r, i)).collect();
            assert!((norm2(&ci) - 1.0).abs() < 1e-8, "col {} norm", i);
            for j in (i + 1)..8 {
                let cj: Vec<f64> = (0..50).map(|r| q.get(r, j)).collect();
                assert!(dot(&ci, &cj).abs() < 1e-8, "cols {} {}", i, j);
            }
        }
    }

    #[test]
    fn jacobi_recovers_diagonal() {
        let a = Matrix::from_rows(vec![
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ]);
        let (vals, _) = jacobi_eigh(&a, 30);
        assert!((vals[0] - 3.0).abs() < 1e-9);
        assert!((vals[1] - 2.0).abs() < 1e-9);
        assert!((vals[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn jacobi_known_2x2() {
        // eigenvalues of [[2,1],[1,2]] are 3 and 1
        let a = Matrix::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = jacobi_eigh(&a, 30);
        assert!((vals[0] - 3.0).abs() < 1e-9);
        assert!((vals[1] - 1.0).abs() < 1e-9);
        // eigenvector for 3 is (1,1)/√2 up to sign
        let ratio = vecs.get(0, 0) / vecs.get(1, 0);
        assert!((ratio - 1.0).abs() < 1e-6);
    }

    #[test]
    fn svd_reconstructs_low_rank() {
        // A = outer products of 3 random rank-1 terms; rank-3 SVD must
        // reconstruct it nearly exactly.
        let mut rng = Xoshiro256::new(7);
        let u = Matrix::randn(40, 3, &mut rng);
        let v = Matrix::randn(3, 30, &mut rng);
        let a = u.matmul(&v);
        let svd = randomized_svd(&a, 3, 6, 2, 11);
        // reconstruct
        let mut us = svd.u.clone();
        for c in 0..3 {
            for r in 0..40 {
                let val = us.get(r, c) * svd.s[c];
                us.set(r, c, val);
            }
        }
        let recon = us.matmul(&svd.v.transpose());
        let mut err = 0.0;
        for i in 0..a.data.len() {
            err += (a.data[i] - recon.data[i]).powi(2);
        }
        let rel = err.sqrt() / a.frobenius_norm();
        assert!(rel < 1e-6, "rel err {}", rel);
    }

    #[test]
    fn svd_singular_values_ordered() {
        let mut rng = Xoshiro256::new(9);
        let a = Matrix::randn(30, 20, &mut rng);
        let svd = randomized_svd(&a, 5, 5, 2, 3);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "not sorted: {:?}", svd.s);
        }
        assert!(svd.s[0] > 0.0);
    }
}
