//! Adam optimiser (Kingma & Ba 2015) — drives the manual-backprop VAE
//! baseline. Operates on flat parameter slices.

/// Adam state for one parameter tensor.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(num_params: usize, lr: f64) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; num_params],
            v: vec![0.0; num_params],
            t: 0,
        }
    }

    /// One update step: `params -= lr * m̂ / (√v̂ + ε)`.
    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimises_quadratic() {
        // f(x) = (x-3)², gradient 2(x-3)
        let mut x = vec![0.0f64];
        let mut adam = Adam::new(1, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            adam.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "x = {}", x[0]);
    }

    #[test]
    fn minimises_2d_anisotropic() {
        // f(x,y) = x² + 100y²
        let mut p = vec![5.0, 1.0];
        let mut adam = Adam::new(2, 0.05);
        for _ in 0..2000 {
            let g = vec![2.0 * p[0], 200.0 * p[1]];
            adam.step(&mut p, &g);
        }
        assert!(p[0].abs() < 1e-2 && p[1].abs() < 1e-2, "{:?}", p);
    }
}
