//! CSR sparse matrices — the representation behind the one-hot encoding
//! ablation (A3) and the MCA baseline's indicator matrix, where densifying
//! would reproduce exactly the OOM failure mode the paper reports.

use super::matrix::Matrix;
use crate::data::CategoricalDataset;

/// Compressed sparse row matrix (f64 values).
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl Csr {
    pub fn from_triplets(rows: usize, cols: usize, mut t: Vec<(u32, u32, f64)>) -> Self {
        t.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(t.len());
        let mut values = Vec::with_capacity(t.len());
        for &(r, c, v) in &t {
            assert!((r as usize) < rows && (c as usize) < cols);
            indptr[r as usize + 1] += 1;
            indices.push(c);
            values.push(v);
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        Self {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Categorical dataset → label-encoded sparse matrix (value = category).
    pub fn from_dataset(ds: &CategoricalDataset) -> Self {
        let mut t = Vec::new();
        for (r, p) in ds.points.iter().enumerate() {
            for &(c, v) in p.entries() {
                t.push((r as u32, c, v as f64));
            }
        }
        Self::from_triplets(ds.len(), ds.dim(), t)
    }

    /// Categorical dataset → **one-hot** indicator matrix of dimension
    /// `n·(c+1)` (the blow-up the paper's introduction warns about; used by
    /// the MCA baseline and ablation A3).
    pub fn one_hot_from_dataset(ds: &CategoricalDataset) -> Self {
        let c = ds.num_categories() as usize;
        let cols = ds.dim().checked_mul(c).expect("one-hot dimension overflow");
        let mut t = Vec::new();
        for (r, p) in ds.points.iter().enumerate() {
            for &(i, v) in p.entries() {
                let col = i as usize * c + (v as usize - 1);
                t.push((r as u32, col as u32, 1.0));
            }
        }
        Self::from_triplets(ds.len(), cols, t)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.indptr[r]..self.indptr[r + 1]
    }

    /// `self · x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| {
                let rg = self.row_range(r);
                self.indices[rg.clone()]
                    .iter()
                    .zip(&self.values[rg])
                    .map(|(&c, &v)| v * x[c as usize])
                    .sum()
            })
            .collect()
    }

    /// `selfᵀ · x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            let rg = self.row_range(r);
            for (&c, &v) in self.indices[rg.clone()].iter().zip(&self.values[rg]) {
                out[c as usize] += v * xr;
            }
        }
        out
    }

    /// `self · B` for dense `B` (cols × k) → dense (rows × k).
    pub fn matmul_dense(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let k = b.cols;
        let mut out = Matrix::zeros(self.rows, k);
        for r in 0..self.rows {
            let rg = self.row_range(r);
            let orow = out.row_mut(r);
            for (&c, &v) in self.indices[rg.clone()].iter().zip(&self.values[rg]) {
                let brow = b.row(c as usize);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
        out
    }

    /// `selfᵀ · B` for dense `B` (rows × k) → dense (cols × k).
    pub fn matmul_t_dense(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows);
        let k = b.cols;
        let mut out = Matrix::zeros(self.cols, k);
        for r in 0..self.rows {
            let rg = self.row_range(r);
            let brow = b.row(r);
            for (&c, &v) in self.indices[rg.clone()].iter().zip(&self.values[rg]) {
                let orow = out.row_mut(c as usize);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
        out
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let rg = self.row_range(r);
            for (&c, &v) in self.indices[rg.clone()].iter().zip(&self.values[rg]) {
                m.set(r, c as usize, v);
            }
        }
        m
    }

    pub fn memory_bytes(&self) -> usize {
        self.indices.len() * 4 + self.values.len() * 8 + self.indptr.len() * 8
    }
}

/// Randomized truncated SVD over a CSR matrix (same HMT scheme as the dense
/// version but all products go through the sparse kernels — this is what
/// lets LSA run on the 100k-dim twins without densifying).
pub fn sparse_randomized_svd(
    a: &Csr,
    k: usize,
    oversample: usize,
    power_iters: usize,
    seed: u64,
) -> super::svd::Svd {
    use super::svd::{jacobi_eigh, thin_qr_q, Svd};
    use crate::util::rng::Xoshiro256;
    let (m, n) = (a.rows, a.cols);
    let k = k.min(m.min(n));
    let l = (k + oversample).min(m.min(n)).max(1);
    let mut rng = Xoshiro256::new(seed);
    let omega = Matrix::randn(n, l, &mut rng);
    let mut y = a.matmul_dense(&omega);
    for _ in 0..power_iters {
        y = thin_qr_q(&y);
        let z = a.matmul_t_dense(&y);
        let zq = thin_qr_q(&z);
        y = a.matmul_dense(&zq);
    }
    let q = thin_qr_q(&y); // m × l
    let b = a.matmul_t_dense(&q).transpose(); // l × n  (B = Qᵀ A)
    let bbt = b.matmul(&b.transpose());
    let (evals, evecs) = jacobi_eigh(&bbt, 60);
    let mut s: Vec<f64> = evals.iter().take(k).map(|&e| e.max(0.0).sqrt()).collect();
    let mut ub_k = Matrix::zeros(b.rows, k);
    for c in 0..k {
        for r in 0..b.rows {
            ub_k.set(r, c, evecs.get(r, c));
        }
    }
    let u = q.matmul(&ub_k);
    let mut v = b.transpose().matmul(&ub_k);
    for c in 0..k {
        let inv = if s[c] > 1e-12 { 1.0 / s[c] } else { 0.0 };
        for r in 0..n {
            let val = v.get(r, c) * inv;
            v.set(r, c, val);
        }
    }
    while s.len() < k {
        s.push(0.0);
    }
    Svd { u, s, v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn triplets_and_dense_agree() {
        let t = vec![(0u32, 1u32, 2.0), (1, 0, 3.0), (1, 2, 4.0)];
        let a = Csr::from_triplets(2, 3, t);
        let d = a.to_dense();
        assert_eq!(d.get(0, 1), 2.0);
        assert_eq!(d.get(1, 0), 3.0);
        assert_eq!(d.get(1, 2), 4.0);
        assert_eq!(d.get(0, 0), 0.0);
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Xoshiro256::new(1);
        let mut t = Vec::new();
        for _ in 0..60 {
            t.push((
                rng.gen_range(10) as u32,
                rng.gen_range(15) as u32,
                rng.next_f64(),
            ));
        }
        t.sort_unstable_by_key(|&(r, c, _)| (r, c));
        t.dedup_by_key(|&mut (r, c, _)| (r, c));
        let a = Csr::from_triplets(10, 15, t);
        let d = a.to_dense();
        let x: Vec<f64> = (0..15).map(|i| i as f64 * 0.5).collect();
        let (sv, dv) = (a.matvec(&x), d.matvec(&x));
        for i in 0..10 {
            assert!((sv[i] - dv[i]).abs() < 1e-9);
        }
        let y: Vec<f64> = (0..10).map(|i| 1.0 - i as f64).collect();
        let st = a.matvec_t(&y);
        let dt = d.transpose().matvec(&y);
        for i in 0..15 {
            assert!((st[i] - dt[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn matmul_dense_matches() {
        let mut rng = Xoshiro256::new(2);
        let t = vec![(0u32, 0u32, 1.0), (0, 4, 2.0), (2, 3, -1.5)];
        let a = Csr::from_triplets(3, 5, t);
        let b = Matrix::randn(5, 4, &mut rng);
        let s = a.matmul_dense(&b);
        let d = a.to_dense().matmul(&b);
        for i in 0..s.data.len() {
            assert!((s.data[i] - d.data[i]).abs() < 1e-9);
        }
        let bt = Matrix::randn(3, 4, &mut rng);
        let st = a.matmul_t_dense(&bt);
        let dt = a.to_dense().transpose().matmul(&bt);
        for i in 0..st.data.len() {
            assert!((st.data[i] - dt.data[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn one_hot_blowup_dimensions() {
        let mut spec = SynthSpec::small_demo();
        spec.num_points = 10;
        spec.dim = 100;
        spec.num_categories = 8;
        spec.max_density = 20;
        spec.mean_density = 10.0;
        let ds = spec.generate(3);
        let oh = Csr::one_hot_from_dataset(&ds);
        assert_eq!(oh.cols, 100 * 8); // the c× blow-up
        // every row has exactly nnz ones
        for (r, p) in ds.points.iter().enumerate() {
            assert_eq!(oh.row_range(r).len(), p.nnz());
        }
    }

    #[test]
    fn sparse_svd_matches_dense_svd_values() {
        let mut rng = Xoshiro256::new(5);
        let u = Matrix::randn(25, 2, &mut rng);
        let v = Matrix::randn(2, 18, &mut rng);
        let dense = u.matmul(&v);
        let mut t = Vec::new();
        for r in 0..25 {
            for c in 0..18 {
                t.push((r as u32, c as u32, dense.get(r, c)));
            }
        }
        let csr = Csr::from_triplets(25, 18, t);
        let s1 = sparse_randomized_svd(&csr, 2, 5, 2, 9);
        let s2 = super::super::svd::randomized_svd(&dense, 2, 5, 2, 9);
        for i in 0..2 {
            assert!(
                (s1.s[i] - s2.s[i]).abs() < 1e-6 * s2.s[0].max(1.0),
                "{:?} vs {:?}",
                s1.s,
                s2.s
            );
        }
    }
}
