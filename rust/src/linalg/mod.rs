//! Dense + sparse linear-algebra substrate.
//!
//! No BLAS/LAPACK is available offline, so the spectral baselines
//! (PCA/LSA/MCA) and factorisation baselines (NNMF, VAE) run on this
//! from-scratch kit: a row-major [`Matrix`], a blocked parallel matmul,
//! thin QR (modified Gram–Schmidt with re-orthogonalisation), randomized
//! truncated SVD (Halko–Martinsson–Tropp), CSR sparse matrices for the
//! one-hot/MCA paths, and an Adam optimiser for the VAE.

pub mod matrix;
pub mod opt;
pub mod sparse;
pub mod svd;

pub use matrix::Matrix;
pub use sparse::Csr;
pub use svd::{randomized_svd, Svd};
