//! Follower runtime: snapshot bootstrap, the WAL-tail puller thread, and
//! promotion.
//!
//! A follower is an ordinary durable coordinator whose corpus arrives
//! over the wire instead of through the batcher: bootstrap materialises
//! the primary's newest snapshot (+ manifest anchoring) into the local
//! `--data-dir`, the ordinary recovery path loads it, and the puller
//! thread then applies live frames continuously via
//! [`crate::coordinator::store::ShardedStore::apply_replicated`]. Every
//! applied chunk is mirrored into the follower's own WAL before its
//! cursor advances, so follower restarts resume from a consistent prefix
//! with no re-shipping of already-applied history.
//!
//! Under `--auto-promote` the runtime also runs a probe supervisor
//! ([`probe_loop`]): ping the primary every `probe_interval`, and after
//! `probe_failures` *consecutive* probes that miss the `probe_timeout`
//! budget, drive [`ReplicaRuntime::promote`] unattended — which bumps
//! the durable failover epoch before the first write can be acked, so a
//! revived old primary is fenceable (see [`crate::persist::Persistence::set_epoch`]).

use super::{seq_field, ReplCounters, ReplicaConfig};
use crate::coordinator::protocol::StreamRequest;
use crate::coordinator::store::ShardedStore;
use crate::obs::{journal, log as obs_log};
use crate::persist::manifest::{snap_path, sync_dir, wal_path, Manifest};
use crate::persist::wal::{scan_frames, WalRecord};
use crate::persist::{snapshot, Fingerprint, FsyncPolicy};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Consecutive deferrals of one shard's stream at an unordered `MoveOut`
/// before the safety valve applies the chunk anyway. The primary commits
/// a move's destination frame before its source frame, so an unpaired
/// `MoveOut` normally resolves within a sweep or two; the valve exists
/// for streams whose pairing state is unknowable (e.g. the `MoveIn` was
/// applied before a follower restart) — there the deferral degrades to
/// the pre-ordering behaviour (a transiently missing row) instead of
/// wedging replication.
const MOVE_DEFER_LIMIT: u32 = 64;

/// Per-syscall socket timeout for the replication client. A silently
/// dead primary (host power-off, network partition — no FIN/RST ever
/// arrives) must surface as an I/O error the puller can retry, because
/// `promote` and shutdown JOIN the puller thread: an unbounded blocking
/// read would hang failover exactly when it is needed. Timeouts are
/// per-read, so a large snapshot transfer just has to keep making
/// progress, not finish within the window.
const IO_TIMEOUT: Duration = Duration::from_secs(5);

/// Blocking client for the replication sub-protocol: JSON header lines
/// followed by raw payload bytes (see [`super::shipper`]).
pub struct ReplClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Session trace id attached to every request this client sends
    /// (0 = untraced). The serving side logs it, so one grep joins a
    /// replication session across both nodes' logs.
    trace: u64,
}

/// A `repl_snapshot` header: the primary's seq/epoch anchoring plus the
/// per-shard payload sizes still waiting on the connection. The shard
/// bytes themselves are *streamed* (see [`ReplClient::read_payload_into`])
/// straight to disk — bootstrap never buffers a corpus image.
pub struct SnapshotMeta {
    pub generation: u64,
    /// The primary's failover epoch at the cut; the follower's manifest
    /// adopts it so a later `promote` provably exceeds the primary's term.
    pub epoch: u64,
    pub base_seqs: Vec<u64>,
    pub fingerprint: Fingerprint,
    pub shard_bytes: Vec<usize>,
}

/// A fetched `repl_wal_tail` answer.
pub enum TailChunk {
    /// Raw frame bytes (re-validated locally frame-by-frame) plus the
    /// primary's durable horizon for lag accounting and its current
    /// failover epoch (0 from a pre-epoch server).
    Frames {
        bytes: Vec<u8>,
        frames: u64,
        live_seq: u64,
        epoch: u64,
        /// The primary's wall clock as the frames left it (0 from a
        /// pre-`commit_ms` server) — the minuend of the follower's
        /// `repl_visibility_lag` measurement.
        commit_ms: u64,
    },
    /// The primary rotated past our position: only a fresh snapshot can
    /// re-seed this follower.
    SnapshotNeeded,
    /// We hold frames the primary never wrote; replication must halt.
    Diverged { message: String },
}

impl ReplClient {
    pub fn connect(addr: &str) -> Result<ReplClient> {
        use std::net::ToSocketAddrs;
        let target = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {addr}"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("{addr} resolved to no address"))?;
        let stream = TcpStream::connect_timeout(&target, IO_TIMEOUT)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
        stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
        Ok(ReplClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            trace: 0,
        })
    }

    /// Attach a session trace id to every subsequent request (0 clears).
    pub fn set_trace(&mut self, trace: u64) {
        self.trace = trace;
    }

    fn opt_trace(&self) -> Option<u64> {
        (self.trace != 0).then_some(self.trace)
    }

    /// Send one request line, read one header line.
    fn round_trip(&mut self, line: &str) -> Result<Json> {
        writeln!(self.writer, "{line}")?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            bail!("primary closed the connection");
        }
        crate::util::json::parse(reply.trim()).context("parsing replication header")
    }

    fn read_payload(&mut self, len: usize) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; len];
        self.reader
            .read_exact(&mut buf)
            .context("reading replication payload")?;
        Ok(buf)
    }

    /// Stream `len` payload bytes into `out` in bounded chunks, never
    /// holding more than one chunk in memory.
    pub fn read_payload_into<W: Write>(&mut self, len: usize, out: &mut W) -> Result<()> {
        let mut chunk = vec![0u8; len.clamp(1, 256 << 10)];
        let mut left = len;
        while left > 0 {
            let want = left.min(chunk.len());
            self.reader
                .read_exact(&mut chunk[..want])
                .context("reading replication payload")?;
            out.write_all(&chunk[..want])
                .context("spilling replication payload")?;
            left -= want;
        }
        Ok(())
    }

    /// Fetch the primary's newest snapshot header; the caller then
    /// drains `shard_bytes[i]` payload bytes per shard, in shard order.
    pub fn fetch_snapshot_meta(&mut self) -> Result<SnapshotMeta> {
        let req = StreamRequest::ReplSnapshot {
            trace: self.opt_trace(),
        };
        let header = self.round_trip(&req.to_json_line())?;
        if !header.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
            bail!(
                "repl_snapshot refused: {}",
                header.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        }
        let fingerprint = Fingerprint {
            sketch_dim: header.req_usize("sketch_dim")?,
            seed: header
                .req_str("seed")?
                .parse()
                .context("primary seed is not a u64")?,
            num_shards: header.req_usize("num_shards")?,
            input_dim: header.req_usize("input_dim")?,
            num_categories: header.req_usize("num_categories")? as u16,
        };
        let base_seqs = header
            .req_arr("base_seqs")?
            .iter()
            .map(|v| {
                v.as_str()
                    .and_then(|s| s.parse::<u64>().ok())
                    .ok_or_else(|| anyhow::anyhow!("base_seqs entry is not a u64"))
            })
            .collect::<Result<Vec<u64>>>()?;
        let sizes: Vec<usize> = header
            .req_arr("shard_bytes")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        if sizes.len() != fingerprint.num_shards || base_seqs.len() != fingerprint.num_shards {
            bail!("repl_snapshot header arity does not match num_shards");
        }
        Ok(SnapshotMeta {
            generation: header.req_usize("generation")? as u64,
            // absent from a pre-epoch (manifest ≤ v4) primary: term 1
            epoch: match header.get("epoch") {
                Some(_) => seq_field(&header, "epoch")?,
                None => 1,
            },
            base_seqs,
            fingerprint,
            shard_bytes: sizes,
        })
    }

    /// Fetch a shard's WAL tail starting at `from_seq`. `epoch` is this
    /// follower's own failover epoch — a primary serving a request that
    /// names a higher epoch than its own knows it has been superseded
    /// and fences itself (`None` omits the field).
    pub fn fetch_tail(
        &mut self,
        shard: usize,
        from_seq: u64,
        max_bytes: usize,
        epoch: Option<u64>,
    ) -> Result<TailChunk> {
        let req = StreamRequest::ReplWalTail {
            shard,
            from_seq,
            max_bytes,
            epoch,
            trace: self.opt_trace(),
        };
        let header = self.round_trip(&req.to_json_line())?;
        if !header.get("ok").and_then(|b| b.as_bool()).unwrap_or(false) {
            let message = header
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("?")
                .to_string();
            if header.get("snapshot_needed").is_some() {
                return Ok(TailChunk::SnapshotNeeded);
            }
            if header.get("diverged").is_some() {
                return Ok(TailChunk::Diverged { message });
            }
            bail!("repl_wal_tail refused: {message}");
        }
        let frames = header.req_usize("frames")? as u64;
        let live_seq = seq_field(&header, "live_seq")?;
        let epoch = match header.get("epoch") {
            Some(_) => seq_field(&header, "epoch")?,
            None => 0,
        };
        let commit_ms = match header.get("commit_ms") {
            Some(_) => seq_field(&header, "commit_ms")?,
            None => 0,
        };
        let bytes = self.read_payload(header.req_usize("bytes")?)?;
        Ok(TailChunk::Frames {
            bytes,
            frames,
            live_seq,
            epoch,
            commit_ms,
        })
    }
}

/// What a bootstrap pass did — logged at follower startup.
pub struct BootstrapReport {
    /// An existing local manifest was found: no shipping happened, the
    /// ordinary recovery path resumes from the local prefix.
    pub resumed: bool,
    pub generation: u64,
    /// Snapshot payload bytes written (0 when resumed or at generation 0).
    pub snapshot_bytes: u64,
}

impl BootstrapReport {
    pub fn describe(&self) -> String {
        if self.resumed {
            format!(
                "resuming from the local data dir (generation {})",
                self.generation
            )
        } else {
            format!(
                "seeded from primary snapshot generation {} ({} payload bytes)",
                self.generation, self.snapshot_bytes
            )
        }
    }
}

/// Atomic file materialisation (tmp + rename; the caller dir-syncs once).
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("write {}", tmp.display()))?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {} into place", path.display()))?;
    Ok(())
}

/// Seed `data_dir` from the primary's newest snapshot, unless a local
/// manifest already exists (restart → resume). Ordering makes a killed
/// bootstrap harmless: snapshot and (empty) WAL files land first, each
/// validated after the transfer, and the local MANIFEST — the commit
/// point the recovery path keys on — is written last. No manifest ⇒ the
/// next start simply re-bootstraps over the leftovers.
pub fn bootstrap(primary: &str, expect: &Fingerprint, data_dir: &Path) -> Result<BootstrapReport> {
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("create replica data dir {}", data_dir.display()))?;
    if let Some(m) = Manifest::load(data_dir)? {
        // fingerprint-checked here for a clear startup error; recovery
        // re-checks identically either way
        m.fingerprint.check(expect)?;
        return Ok(BootstrapReport {
            resumed: true,
            generation: m.generation,
            snapshot_bytes: 0,
        });
    }
    let mut client = ReplClient::connect(primary)
        .with_context(|| format!("connecting to replication primary {primary}"))?;
    // bootstrap session trace: rides the snapshot request, so the
    // primary's `snapshot_served` log line and this follower's
    // `repl_bootstrap` line below join on one grep
    let session_trace = crate::coordinator::server::now_ms();
    client.set_trace(session_trace);
    obs_log::info(
        "replica",
        "repl_bootstrap",
        &[
            ("primary", obs_log::V::s(primary.to_string())),
            ("trace", obs_log::V::u(session_trace)),
        ],
    );
    let meta = client.fetch_snapshot_meta()?;
    meta.fingerprint
        .check(expect)
        .context("primary's corpus configuration does not match this replica's flags")?;
    if meta.shard_bytes.len() != expect.num_shards {
        bail!(
            "primary shipped {} snapshot shards for {} configured shards",
            meta.shard_bytes.len(),
            expect.num_shards
        );
    }
    let mut snapshot_bytes = 0u64;
    if meta.generation > 0 {
        for (si, len) in meta.shard_bytes.iter().copied().enumerate() {
            // stream the shard payload straight to its tmp file (tmp +
            // fsync + rename, like write_atomic, without a buffered
            // corpus image), then validate BEFORE committing the
            // manifest: a damaged transfer must re-bootstrap on the
            // next start, not wedge recovery
            let path = snap_path(data_dir, meta.generation, si);
            let tmp = path.with_extension("tmp");
            {
                let f = std::fs::File::create(&tmp)
                    .with_context(|| format!("create {}", tmp.display()))?;
                let mut w = std::io::BufWriter::new(f);
                client
                    .read_payload_into(len, &mut w)
                    .with_context(|| format!("shipping snapshot shard {si}"))?;
                let f = w
                    .into_inner()
                    .map_err(|e| anyhow::anyhow!("flushing {}: {}", tmp.display(), e.error()))?;
                f.sync_all()?;
            }
            std::fs::rename(&tmp, &path)
                .with_context(|| format!("rename {} into place", path.display()))?;
            snapshot::load_shard(&path, expect.sketch_dim, si)
                .with_context(|| format!("validating shipped snapshot for shard {si}"))?;
            snapshot_bytes += len as u64;
        }
        for si in 0..expect.num_shards {
            // recovery at generation > 0 requires the live segment to
            // exist; it starts empty and the puller fills it
            crate::persist::wal::WalWriter::create(
                &wal_path(data_dir, meta.generation, si),
                FsyncPolicy::Never,
            )
            .with_context(|| format!("creating empty WAL segment for shard {si}"))?;
        }
    }
    Manifest {
        generation: meta.generation,
        fingerprint: *expect,
        // adopt the primary's failover epoch: promotion bumps past it
        epoch: meta.epoch,
        base_seqs: meta.base_seqs,
        // no retained segment: a fresh follower bootstraps at the cut
        prev: None,
    }
    .save(data_dir)?;
    sync_dir(data_dir);
    Ok(BootstrapReport {
        resumed: false,
        generation: meta.generation,
        snapshot_bytes,
    })
}

/// Sidecar file persisting the puller's `seen_move_ins` set (one move
/// id per line). Without it, a follower restart forgets which `MoveIn`
/// frames it already applied, so the next unpaired `MoveOut` rides the
/// 64-deferral valve and a moved row reads as transiently missing; with
/// it, the pairing state survives restarts. Loss of the file is safe —
/// it only re-opens the pre-persistence window.
const MOVE_INS_FILE: &str = "MOVE_INS";

fn load_move_ins(dir: &Path) -> HashSet<u64> {
    let mut out = HashSet::new();
    if let Ok(text) = std::fs::read_to_string(dir.join(MOVE_INS_FILE)) {
        for line in text.lines() {
            if let Ok(id) = line.trim().parse::<u64>() {
                out.insert(id);
            }
        }
    }
    out
}

/// Best-effort atomic rewrite (the set is bounded by in-flight moves,
/// so this is a handful of lines); a write failure only degrades back
/// to the pre-persistence deferral behaviour, so it warns, not errors.
fn save_move_ins(dir: &Path, set: &HashSet<u64>) {
    let mut ids: Vec<u64> = set.iter().copied().collect();
    ids.sort_unstable();
    let mut text = String::new();
    for id in ids {
        text.push_str(&id.to_string());
        text.push('\n');
    }
    if let Err(e) = write_atomic(&dir.join(MOVE_INS_FILE), text.as_bytes()) {
        obs_log::warn(
            "replica",
            "move_ins_persist_failed",
            &[("error", obs_log::V::s(format!("{e:#}")))],
        );
    }
}

/// The live follower runtime: the puller thread, the optional probe
/// supervisor (`--auto-promote`), and the writable flag the server's
/// insert gate reads. Dropping it stops and joins both threads.
pub struct ReplicaRuntime {
    primary: String,
    writable: AtomicBool,
    stop: Arc<AtomicBool>,
    store: Arc<ShardedStore>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    probe_handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Serialises [`ReplicaRuntime::promote`] callers (manual op racing
    /// the supervisor): the second caller must observe the first one's
    /// writable flip, not race it to a second epoch bump.
    promote_lock: Mutex<()>,
}

impl ReplicaRuntime {
    /// Spawn the puller (and, under `cfg.auto_promote`, the probe
    /// supervisor) over an already-recovered (bootstrapped) store.
    pub fn start(
        store: Arc<ShardedStore>,
        cfg: ReplicaConfig,
        counters: Arc<ReplCounters>,
        failover: Arc<super::FailoverCounters>,
    ) -> Arc<ReplicaRuntime> {
        assert!(
            store.persistence().is_some(),
            "a replica store must be durable (the shipped log lives in its data dir)"
        );
        let stop = Arc::new(AtomicBool::new(false));
        let primary = cfg.primary.clone();
        let thread_store = store.clone();
        let thread_stop = stop.clone();
        let thread_cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("cabin-replica-pull".into())
            .spawn(move || puller_loop(&thread_store, &thread_cfg, &counters, &thread_stop))
            .expect("spawn replica puller");
        let rt = Arc::new(ReplicaRuntime {
            primary,
            writable: AtomicBool::new(false),
            stop,
            store,
            handle: Mutex::new(Some(handle)),
            probe_handle: Mutex::new(None),
            promote_lock: Mutex::new(()),
        });
        if cfg.auto_promote {
            // the supervisor holds only a Weak: a strong clone would
            // keep the runtime (and its threads) alive past the server
            let weak = Arc::downgrade(&rt);
            let probe_stop = rt.stop.clone();
            let probe = std::thread::Builder::new()
                .name("cabin-replica-probe".into())
                .spawn(move || probe_loop(&weak, &cfg, &failover, &probe_stop))
                .expect("spawn failover probe");
            *super::lock_recover(&rt.probe_handle) = Some(probe);
        }
        rt
    }

    /// The primary this replica follows (used by the insert redirect).
    pub fn primary(&self) -> &str {
        &self.primary
    }

    /// Whether promotion has made this replica writable.
    pub fn is_writable(&self) -> bool {
        self.writable.load(Ordering::SeqCst)
    }

    /// Stop replication, flush every applied frame durable, persist the
    /// bumped failover epoch, and flip writable; returns the per-shard
    /// applied (now durable) sequences and the new epoch. A flush or
    /// epoch-persist failure is an `Err` and leaves the replica
    /// READ-ONLY — promoting would otherwise report sequences a crash
    /// could revoke (or ack writes under a term a crash would roll
    /// back), silently breaking the "promoted node loses no acked
    /// insert" contract. The operator can retry `promote` once the disk
    /// recovers. Idempotent on success — a second promote just reports
    /// the sequences and epoch again without bumping twice.
    pub fn promote(&self) -> anyhow::Result<(Vec<u64>, u64)> {
        let _g = super::lock_recover(&self.promote_lock);
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = super::lock_recover(&self.handle).take() {
            let _ = h.join();
        }
        let p = self
            .store
            .persistence()
            .expect("replica stores are durable (asserted at start)");
        p.flush_all()
            .context("flushing applied frames before promotion; replica remains read-only")?;
        let seqs = (0..self.store.num_shards()).map(|si| p.committed_seq(si)).collect();
        let first = !self.writable.load(Ordering::SeqCst);
        if first {
            // the epoch lands durably BEFORE the first write can be
            // acked: the old primary's manifest tops out at the epoch
            // this follower adopted while pulling, so the bump makes
            // this side's term strictly the highest that ever acked
            p.set_epoch(p.epoch() + 1)
                .context("persisting the bumped failover epoch; replica remains read-only")?;
        }
        self.writable.store(true, Ordering::SeqCst);
        if first {
            // one canonical journal event per actual promotion (manual
            // and auto both land here; the idempotent re-promote does not)
            journal::record("replica", "promoted", &[("epoch", obs_log::V::u(p.epoch()))]);
        }
        Ok((seqs, p.epoch()))
    }
}

impl Drop for ReplicaRuntime {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = super::lock_recover(&self.handle).take() {
            let _ = h.join();
        }
        if let Some(h) = super::lock_recover(&self.probe_handle).take() {
            let _ = h.join();
        }
    }
}

/// One health probe: TCP connect + `ping` round trip, each bounded by
/// `timeout`. Returns the observed round-trip time. The probe's verdict
/// is deliberately binary — *answered within the budget* or not: a slow
/// primary that still answers inside `probe_timeout` is healthy (and
/// never promoted over), while "dead" requires `probe_failures`
/// *consecutive* budget misses, so a single GC pause or dropped packet
/// cannot trigger failover.
fn probe_primary(addr: &str, timeout: Duration) -> Result<Duration> {
    use std::net::ToSocketAddrs;
    let start = std::time::Instant::now();
    let target = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("{addr} resolved to no address"))?;
    let stream = TcpStream::connect_timeout(&target, timeout).context("connect")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).ok();
    stream.set_write_timeout(Some(timeout)).ok();
    let mut writer = stream.try_clone().context("clone probe socket")?;
    writeln!(
        writer,
        "{}",
        crate::coordinator::protocol::Request::Ping { epoch: None }.to_json_line()
    )
    .context("send ping")?;
    let mut line = String::new();
    BufReader::new(stream)
        .read_line(&mut line)
        .context("read pong")?;
    let reply = crate::util::json::parse(line.trim()).context("parse pong")?;
    if reply.get("pong").and_then(|b| b.as_bool()) != Some(true) {
        bail!("primary answered, but not with a pong");
    }
    Ok(start.elapsed())
}

/// The failover supervisor (`--auto-promote`): probe the primary every
/// `probe_interval`; after `probe_failures` consecutive failed probes,
/// drive [`ReplicaRuntime::promote`] and exit. A failed promotion
/// (e.g. the local disk refused the flush) resets the count and keeps
/// probing — the replica stays read-only rather than overstating what
/// it holds.
fn probe_loop(
    rt: &std::sync::Weak<ReplicaRuntime>,
    cfg: &ReplicaConfig,
    failover: &super::FailoverCounters,
    stop: &AtomicBool,
) {
    let mut consecutive: u32 = 0;
    while !stop.load(Ordering::Relaxed) {
        sleep_unless_stop(stop, cfg.probe_interval);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        failover.probes.fetch_add(1, Ordering::Relaxed);
        match probe_primary(&cfg.primary, cfg.probe_timeout) {
            Ok(_rtt) => {
                consecutive = 0;
                failover.consecutive_failures.store(0, Ordering::Relaxed);
            }
            Err(e) => {
                consecutive += 1;
                failover.probe_failures.fetch_add(1, Ordering::Relaxed);
                failover
                    .consecutive_failures
                    .store(consecutive as u64, Ordering::Relaxed);
                obs_log::warn(
                    "failover",
                    "probe_failed",
                    &[
                        ("primary", obs_log::V::s(cfg.primary.clone())),
                        ("consecutive", obs_log::V::u(consecutive as u64)),
                        ("threshold", obs_log::V::u(cfg.probe_failures as u64)),
                        ("error", obs_log::V::s(format!("{e:#}"))),
                    ],
                );
                journal::record(
                    "failover",
                    "probe_failed",
                    &[
                        ("consecutive", obs_log::V::u(consecutive as u64)),
                        ("threshold", obs_log::V::u(cfg.probe_failures as u64)),
                    ],
                );
            }
        }
        if consecutive < cfg.probe_failures {
            continue;
        }
        let Some(rt) = rt.upgrade() else {
            return; // runtime dropped under us: server is going down
        };
        if rt.is_writable() {
            return; // already promoted (manually, or a prior pass)
        }
        match rt.promote() {
            Ok((applied_seqs, epoch)) => {
                failover.promotions.fetch_add(1, Ordering::Relaxed);
                failover.last_epoch.store(epoch, Ordering::Relaxed);
                // the structured `failover` record: one line an operator
                // (or a postmortem) can key on
                obs_log::info(
                    "failover",
                    "auto_promoted",
                    &[
                        ("primary", obs_log::V::s(cfg.primary.clone())),
                        ("probe_failures", obs_log::V::u(consecutive as u64)),
                        (
                            "probe_interval_ms",
                            obs_log::V::u(cfg.probe_interval.as_millis() as u64),
                        ),
                        (
                            "probe_timeout_ms",
                            obs_log::V::u(cfg.probe_timeout.as_millis() as u64),
                        ),
                        ("epoch", obs_log::V::u(epoch)),
                        (
                            "applied_seqs",
                            obs_log::V::s(
                                applied_seqs
                                    .iter()
                                    .map(|s| s.to_string())
                                    .collect::<Vec<_>>()
                                    .join(","),
                            ),
                        ),
                    ],
                );
                journal::record(
                    "failover",
                    "auto_promoted",
                    &[
                        ("epoch", obs_log::V::u(epoch)),
                        ("probe_failures", obs_log::V::u(consecutive as u64)),
                    ],
                );
                return; // we are the primary now; nothing left to probe
            }
            Err(e) => {
                obs_log::error(
                    "failover",
                    "auto_promote_failed",
                    &[
                        ("error", obs_log::V::s(format!("{e:#}"))),
                        ("action", obs_log::V::s("replica stays read-only; re-probing")),
                    ],
                );
                consecutive = 0;
            }
        }
    }
}

/// Sleep in small slices so stop/drop stays responsive.
fn sleep_unless_stop(stop: &AtomicBool, total: Duration) {
    let slice = Duration::from_millis(20);
    let mut left = total;
    while !stop.load(Ordering::Relaxed) && left > Duration::ZERO {
        let step = left.min(slice);
        std::thread::sleep(step);
        left = left.saturating_sub(step);
    }
}

/// The puller: per-shard tail requests from the local applied seq, apply,
/// repeat; reconnect with backoff on transport errors; halt loudly on
/// divergence. Gap handling is positional — a short/torn transfer applies
/// only whole frames and the next request re-asks from the advanced
/// cursor, so nothing is ever skipped or double-applied.
fn puller_loop(
    store: &ShardedStore,
    cfg: &ReplicaConfig,
    counters: &ReplCounters,
    stop: &AtomicBool,
) {
    let Some(p) = store.persistence() else {
        return; // unreachable: start() asserts durability
    };
    let num_shards = store.num_shards();
    let wpr = p.words_per_row();
    let min_wait = cfg.poll.max(Duration::from_millis(10));
    let mut reconnect_wait = min_wait;
    // Cross-shard move ordering: move ids whose MoveIn this runtime has
    // applied but whose paired MoveOut it has not yet seen. A MoveOut
    // removes its id on apply (move ids are never reused), so the set is
    // bounded by the number of in-flight moves. Persisted in a sidecar
    // file so a follower restart keeps its pairing state instead of
    // riding the deferral valve (transiently missing rows).
    let mut seen_move_ins: HashSet<u64> = load_move_ins(p.data_dir());
    let mut defers_by_shard = vec![0u32; num_shards];
    while !stop.load(Ordering::Relaxed) {
        let mut client = match ReplClient::connect(&cfg.primary) {
            Ok(mut c) => {
                counters.connects.fetch_add(1, Ordering::Relaxed);
                reconnect_wait = min_wait;
                // session trace: rides every pull this session sends, so
                // the primary's shipper logs carry an id greppable in
                // this follower's own log line below
                let session_trace = crate::coordinator::server::now_ms();
                c.set_trace(session_trace);
                obs_log::info(
                    "replica",
                    "repl_session",
                    &[
                        ("primary", obs_log::V::s(cfg.primary.clone())),
                        ("trace", obs_log::V::u(session_trace)),
                    ],
                );
                c
            }
            Err(_) => {
                counters.stalls.fetch_add(1, Ordering::Relaxed);
                sleep_unless_stop(stop, reconnect_wait);
                reconnect_wait = (reconnect_wait * 2).min(Duration::from_secs(1));
                continue;
            }
        };
        'session: while !stop.load(Ordering::Relaxed) {
            let mut progressed = false;
            let mut all_caught_up = true;
            for shard in 0..num_shards {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                let from = p.next_seq(shard);
                match client.fetch_tail(shard, from, cfg.max_bytes, Some(p.epoch())) {
                    Ok(TailChunk::Frames {
                        bytes,
                        frames,
                        live_seq,
                        epoch,
                        commit_ms,
                    }) => {
                        // adopt the primary's (strictly newer) failover
                        // epoch durably, so our own later promotion
                        // provably exceeds every term the primary acked
                        if epoch > p.epoch() {
                            journal::record(
                                "replica",
                                "epoch_observed",
                                &[
                                    ("own_epoch", obs_log::V::u(p.epoch())),
                                    ("primary_epoch", obs_log::V::u(epoch)),
                                ],
                            );
                            if let Err(e) = p.set_epoch(epoch) {
                                obs_log::warn(
                                    "replica",
                                    "epoch_adopt_failed",
                                    &[("error", obs_log::V::s(format!("{e:#}")))],
                                );
                            }
                        }
                        if frames > 0 {
                            let replay = scan_frames(&bytes, wpr);
                            if replay.records.is_empty() {
                                // nothing whole arrived; re-request later
                                counters.stalls.fetch_add(1, Ordering::Relaxed);
                            } else {
                                // dst-before-src move ordering: stop this
                                // chunk before a MoveOut whose paired
                                // MoveIn has not been applied yet — the
                                // unapplied suffix is re-requested (the
                                // cursor only advances past what applies)
                                let mut take = replay.records.len();
                                for (i, r) in replay.records.iter().enumerate() {
                                    if let WalRecord::MoveOut { move_id } = r {
                                        if !seen_move_ins.contains(move_id) {
                                            take = i;
                                            break;
                                        }
                                    }
                                }
                                if take < replay.records.len() {
                                    defers_by_shard[shard] += 1;
                                    counters.move_defers.fetch_add(1, Ordering::Relaxed);
                                    if defers_by_shard[shard] > MOVE_DEFER_LIMIT {
                                        take = replay.records.len(); // safety valve
                                    }
                                } else {
                                    defers_by_shard[shard] = 0;
                                }
                                let valid = match take {
                                    0 => &[][..],
                                    t => &bytes[..replay.frame_ends[t - 1] as usize],
                                };
                                // take == 0: the whole chunk is blocked —
                                // skip it; later shards in this sweep may
                                // apply the pairing MoveIn
                                let recs = &replay.records[..take];
                                if !recs.is_empty() {
                                    match store.apply_replicated(shard, valid, recs) {
                                        Ok(()) => {
                                            let mut moves_changed = false;
                                            for r in recs {
                                                match r {
                                                    WalRecord::MoveIn { move_id, .. } => {
                                                        moves_changed |=
                                                            seen_move_ins.insert(*move_id);
                                                    }
                                                    WalRecord::MoveOut { move_id } => {
                                                        moves_changed |=
                                                            seen_move_ins.remove(move_id);
                                                    }
                                                    _ => {}
                                                }
                                            }
                                            if moves_changed {
                                                save_move_ins(p.data_dir(), &seen_move_ins);
                                            }
                                            if take == replay.records.len() {
                                                defers_by_shard[shard] = 0;
                                            }
                                            let n = recs.len() as u64;
                                            counters.frames_applied.fetch_add(n, Ordering::Relaxed);
                                            let b = valid.len() as u64;
                                            counters.bytes_applied.fetch_add(b, Ordering::Relaxed);
                                            // wall-clock visibility lag:
                                            // apply time minus the
                                            // primary's commit_ms stamp
                                            // (clock skew and all — that
                                            // is the operator's question)
                                            if commit_ms > 0 {
                                                let age_ms = crate::coordinator::server::now_ms()
                                                    .saturating_sub(commit_ms);
                                                counters.record_visibility(shard, age_ms);
                                            }
                                            progressed = true;
                                        }
                                        Err(e) => {
                                            // commit-side failures are retried by the
                                            // next chunk's commit (next_seq counts the
                                            // pending frames); infeasible chunks keep
                                            // erroring visibly here
                                            obs_log::error(
                                                "replica",
                                                "apply_failed",
                                                &[
                                                    ("shard", obs_log::V::u(shard as u64)),
                                                    ("from_seq", obs_log::V::u(from)),
                                                    ("error", obs_log::V::s(format!("{e:#}"))),
                                                ],
                                            );
                                            counters.stalls.fetch_add(1, Ordering::Relaxed);
                                        }
                                    }
                                }
                            }
                        }
                        let applied = p.next_seq(shard);
                        let lag = live_seq.saturating_sub(applied);
                        counters.record_shard(shard, applied, lag);
                        if lag > 0 {
                            all_caught_up = false;
                        }
                    }
                    Ok(TailChunk::SnapshotNeeded) => {
                        all_caught_up = false;
                        counters.stalls.fetch_add(1, Ordering::Relaxed);
                        obs_log::warn(
                            "replica",
                            "rotated_past_position",
                            &[
                                ("shard", obs_log::V::u(shard as u64)),
                                ("from_seq", obs_log::V::u(from)),
                                (
                                    "action",
                                    obs_log::V::s(
                                        "re-seed this follower: restart with a fresh --data-dir",
                                    ),
                                ),
                            ],
                        );
                        sleep_unless_stop(stop, Duration::from_secs(1));
                    }
                    Ok(TailChunk::Diverged { message }) => {
                        counters.diverged.store(1, Ordering::Relaxed);
                        counters.caught_up.store(0, Ordering::Relaxed);
                        journal::record(
                            "replica",
                            "diverged",
                            &[("shard", obs_log::V::u(shard as u64))],
                        );
                        obs_log::error(
                            "replica",
                            "diverged",
                            &[
                                ("detail", obs_log::V::s(message)),
                                (
                                    "action",
                                    obs_log::V::s(
                                        "replication halted; serving last consistent prefix",
                                    ),
                                ),
                            ],
                        );
                        return;
                    }
                    Err(e) => {
                        counters.stalls.fetch_add(1, Ordering::Relaxed);
                        obs_log::warn(
                            "replica",
                            "tail_fetch_failed",
                            &[
                                ("error", obs_log::V::s(format!("{e:#}"))),
                                ("action", obs_log::V::s("will reconnect")),
                            ],
                        );
                        break 'session;
                    }
                }
            }
            counters
                .caught_up
                .store(u64::from(all_caught_up), Ordering::Relaxed);
            if !progressed {
                sleep_unless_stop(stop, cfg.poll);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn move_ins_sidecar_roundtrips_and_tolerates_absence() {
        let dir = crate::testing::TempDir::new("move-ins");
        assert!(load_move_ins(dir.path()).is_empty(), "no file yet");
        let mut set = HashSet::new();
        set.insert(7u64);
        set.insert(u64::MAX);
        set.insert(0);
        save_move_ins(dir.path(), &set);
        assert_eq!(load_move_ins(dir.path()), set);
        // shrink: the rewrite replaces, not appends
        set.remove(&7);
        save_move_ins(dir.path(), &set);
        assert_eq!(load_move_ins(dir.path()), set);
        // garbage lines are skipped, valid ones still load
        std::fs::write(dir.path().join(MOVE_INS_FILE), "12\nnope\n\n9\n").unwrap();
        let loaded = load_move_ins(dir.path());
        assert_eq!(loaded, [12u64, 9].into_iter().collect());
    }

    #[test]
    fn probe_against_nothing_fails_within_budget() {
        // an unroutable/refused port must come back as a probe failure,
        // not a hang — promote() joins threads that depend on this
        let t0 = std::time::Instant::now();
        let err = probe_primary("127.0.0.1:1", Duration::from_millis(400));
        assert!(err.is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "probe must respect its timeout budget"
        );
    }
}
