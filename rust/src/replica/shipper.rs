//! Primary-side replication shipper: serves the `repl_snapshot` and
//! `repl_wal_tail` wire ops.
//!
//! Both ops reply with a JSON header *line* followed by raw binary
//! payload bytes (exactly `bytes`/`shard_bytes` long), which the ordinary
//! `Request`/`Response` enums cannot represent — they are *stream ops*,
//! parsed by the unified
//! [`StreamRequest`](crate::coordinator::protocol::StreamRequest)
//! envelope and routed by the server into [`serve_snapshot`] /
//! [`serve_wal_tail`] here. The payloads are
//! self-checking: snapshot payloads are verbatim snapshot files (magic +
//! trailing checksum), WAL payloads are verbatim frame bytes
//! (length-prefixed, per-frame checksums), so transfer integrity needs no
//! extra framing.
//!
//! Rotation races: a snapshot rotation can slide under a shipping request
//! (its files GC'd mid-read, its bases re-anchored). Every serve path
//! therefore captures one consistent [`Persistence::seq_view`], opens the
//! files it addresses, and retries when the live generation moved —
//! never blocking rotation, never serving a generation's file against
//! another generation's bases. Snapshot payloads then *stream* from the
//! open handles in bounded chunks (an unlinked open file keeps its
//! immutable contents), so a bootstrap of any corpus size costs one
//! [`SNAPSHOT_CHUNK`] of primary memory, not a corpus image.
//!
//! Both headers carry the serving side's failover `epoch` (see
//! [`crate::persist::Persistence::set_epoch`]): a follower adopts it so
//! that its own `promote` provably exceeds the primary's term, and the
//! server routing these ops fences itself when a *request* names a
//! higher epoch than its own (epoch checks live in
//! `coordinator::server`, which owns the fence state). Tail headers
//! additionally stamp `commit_ms` — the primary's wall clock at serve
//! time — which the follower subtracts from its own apply time to get
//! the wall-clock visibility lag (`repl_visibility_lag`). Requests may
//! carry the follower's session `trace` id, logged on the serving side
//! so one grep correlates a pull across both nodes.
//!
//! Tail-offset cache: serving a tail means translating a frame index
//! into a byte offset inside a variable-length-frame file. Instead of
//! walking the segment from byte 0 on every poll (O(file) per request —
//! quadratic over a follower's catch-up), the shipper stores each
//! reply's end position back into the persistence layer's per-shard
//! `(generation, frame, offset)` memo and passes it as the next read's
//! starting hint, making steady-state polls O(chunk). The memo is
//! invalidated by generation (rotation and compaction both cut a new
//! one), and a stale or too-far hint is simply ignored by
//! [`read_wal_tail`] — correctness never depends on the cache.

use super::ReplCounters;
use crate::coordinator::store::ShardedStore;
use crate::obs::log as obs_log;
use crate::persist::manifest::{snap_path, wal_path};
use crate::persist::wal::read_wal_tail;
use crate::persist::Persistence;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::sync::atomic::Ordering;

/// Retries against a rotation sliding under a serve path. Rotations take
/// milliseconds and are at least a full snapshot interval apart, so two
/// in one request is already pathological; eight means something is
/// rewriting the data dir under us and we should error out.
const ROTATION_RACE_RETRIES: usize = 8;

/// Copy-buffer size for streaming snapshot shards to the wire — the
/// whole resident cost of serving a bootstrap, however large the corpus.
const SNAPSHOT_CHUNK: usize = 256 << 10;

/// A consistent snapshot *source*: open file handles on the
/// generation's arenas plus the seq/epoch anchoring a follower needs to
/// start pulling the tail. Holding open handles (rather than buffered
/// bytes) is what makes serving O(chunk) in memory: a rotation may
/// unlink these files mid-transfer, but an unlinked open file keeps its
/// (immutable, fully-fsynced) contents until the handle drops.
pub struct SnapshotStream {
    pub generation: u64,
    pub epoch: u64,
    pub base_seqs: Vec<u64>,
    /// Per-shard `snap-G-shard-i.bin` handles with their byte sizes
    /// (`None`/0 at generation 0 — a fresh primary has no snapshot and
    /// the follower starts empty).
    files: Vec<Option<std::fs::File>>,
    sizes: Vec<u64>,
}

/// Open a consistent [`SnapshotStream`] over the live data dir. The
/// generation re-check after the opens rejects a mid-open rotation
/// before the header commits to any sizes; once the handles exist the
/// transfer cannot race anything (see [`SnapshotStream`]).
fn snapshot_stream(p: &Persistence) -> Result<SnapshotStream> {
    let num_shards = p.num_shards();
    for _ in 0..ROTATION_RACE_RETRIES {
        let view = p.seq_view();
        let mut files = Vec::with_capacity(num_shards);
        let mut sizes = Vec::with_capacity(num_shards);
        if view.generation > 0 {
            let mut raced = false;
            for si in 0..num_shards {
                match std::fs::File::open(snap_path(p.data_dir(), view.generation, si)) {
                    Ok(f) => {
                        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
                        files.push(Some(f));
                        sizes.push(len);
                    }
                    Err(_) => {
                        raced = true; // rotation GC'd this generation
                        break;
                    }
                }
            }
            if raced {
                continue;
            }
        } else {
            files = (0..num_shards).map(|_| None).collect();
            sizes = vec![0; num_shards];
        }
        if p.generation() == view.generation {
            return Ok(SnapshotStream {
                generation: view.generation,
                epoch: p.epoch(),
                base_seqs: view.base_seqs,
                files,
                sizes,
            });
        }
    }
    bail!("snapshot stream raced repeated rotations; ask again")
}

/// One `repl_wal_tail` answer.
pub enum Tail {
    /// Frames `[from_seq, from_seq + frames)` as raw bytes; `live_seq` is
    /// the shard's durable sequence horizon for lag accounting.
    Frames {
        from_seq: u64,
        frames: u64,
        bytes: Vec<u8>,
        live_seq: u64,
    },
    /// `from_seq` predates every segment still on disk: the follower
    /// lagged more than one rotation and must re-seed from a snapshot.
    SnapshotNeeded { base_seq: u64 },
    /// `from_seq` is beyond the primary's durable horizon: the follower
    /// holds frames this primary never wrote. Divergence — not served.
    Diverged { live_seq: u64 },
}

/// Serve a shard's WAL tail starting at `from_seq`, from the live segment
/// or the one retained previous-generation segment.
pub fn wal_tail(p: &Persistence, shard: usize, from_seq: u64, max_bytes: usize) -> Result<Tail> {
    anyhow::ensure!(
        shard < p.num_shards(),
        "shard {shard} out of range (0..{})",
        p.num_shards()
    );
    let wpr = p.words_per_row();
    for _ in 0..ROTATION_RACE_RETRIES {
        let view = p.seq_view();
        let base = view.base_seqs[shard];
        if from_seq >= base {
            // ship only up to the crash-surviving horizon: frames
            // write_all'd but not yet fsynced could be revoked by a
            // primary power loss, and a follower holding revoked frames
            // would wrongly read as diverged afterwards. (The horizon is
            // an absolute seq, monotone across rotations, so computing it
            // before the file read can only under-serve, never over.)
            let durable_seq = p.durable_seq(shard);
            if from_seq > durable_seq {
                return Ok(Tail::Diverged {
                    live_seq: durable_seq,
                });
            }
            let path = wal_path(p.data_dir(), view.generation, shard);
            let budget = durable_seq - from_seq;
            let hint = p.tail_hint(shard, view.generation);
            let Ok(tail) = read_wal_tail(&path, wpr, from_seq - base, max_bytes, budget, hint)
            else {
                continue; // rotation swapped the live segment under us
            };
            if p.generation() != view.generation {
                continue;
            }
            // memoise where this reply ended so the follower's next poll
            // seeks instead of re-walking the segment from byte 0
            p.note_tail_offset(shard, view.generation, tail.end_frame, tail.end_offset);
            return Ok(Tail::Frames {
                from_seq,
                frames: tail.frames,
                bytes: tail.bytes,
                live_seq: durable_seq,
            });
        }
        if let Some((prev_gen, prev_bases)) = &view.prev {
            let prev_base = prev_bases[shard];
            if from_seq >= prev_base {
                // the retained segment is frozen — fully committed and
                // fsynced by the rotation that retired it, so every frame
                // is within the durable horizon and no re-check is needed;
                // it may expire under us, which downgrades to re-seed
                // frozen segment, read rarely (one catch-up pass per
                // lagging follower): no offset memo, hintless walk
                let path = wal_path(p.data_dir(), *prev_gen, shard);
                match read_wal_tail(&path, wpr, from_seq - prev_base, max_bytes, u64::MAX, None) {
                    Ok(tail) if tail.frames > 0 => {
                        return Ok(Tail::Frames {
                            from_seq,
                            frames: tail.frames,
                            bytes: tail.bytes,
                            live_seq: p.durable_seq(shard),
                        });
                    }
                    _ => return Ok(Tail::SnapshotNeeded { base_seq: base }),
                }
            }
        }
        return Ok(Tail::SnapshotNeeded { base_seq: base });
    }
    bail!("wal tail raced repeated rotations; ask again")
}

fn seq_strings(seqs: &[u64]) -> Json {
    Json::Arr(seqs.iter().map(|s| Json::Str(s.to_string())).collect())
}

fn write_error<W: Write>(
    writer: &mut W,
    message: &str,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(message.to_string())),
    ];
    pairs.extend(extra);
    writeln!(writer, "{}", Json::obj(pairs))
}

/// Answer with the shared "serving side is not durable" error line and
/// return `None` when the store has no persistence layer. Any durable
/// server can ship (a follower can feed further followers).
fn persistence_for<'a, W: Write>(
    store: &'a ShardedStore,
    writer: &mut W,
) -> std::io::Result<Option<&'a Persistence>> {
    match store.persistence() {
        Some(p) => Ok(Some(p)),
        None => {
            write_error(
                writer,
                "replication requires persistence on the serving side (start it with --data-dir)",
                Vec::new(),
            )?;
            Ok(None)
        }
    }
}

/// Serve a parsed `repl_snapshot` stream op: header line + the shard
/// snapshot files concatenated in shard order (or an error line). The
/// server routes here from the unified
/// [`StreamRequest`](crate::coordinator::protocol::StreamRequest)
/// envelope; transport failures bubble as `io::Error` like any
/// connection write.
pub fn serve_snapshot<W: Write>(
    store: &ShardedStore,
    counters: &ReplCounters,
    trace: u64,
    writer: &mut W,
) -> std::io::Result<()> {
    let Some(p) = persistence_for(store, writer)? else {
        return Ok(());
    };
    if trace != 0 {
        // the follower's session trace id rode the request: one grep for
        // it now finds the bootstrap on both sides of the wire
        obs_log::info("shipper", "snapshot_served", &[("trace", obs_log::V::u(trace))]);
    }
    match snapshot_stream(p) {
        Ok(mut stream) => {
            let fp = p.fingerprint();
            let shard_bytes: Vec<usize> = stream.sizes.iter().map(|b| *b as usize).collect();
            let header = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("generation", Json::Num(stream.generation as f64)),
                ("epoch", Json::Str(stream.epoch.to_string())),
                ("num_shards", Json::Num(fp.num_shards as f64)),
                ("sketch_dim", Json::Num(fp.sketch_dim as f64)),
                ("seed", Json::Str(fp.seed.to_string())),
                ("input_dim", Json::Num(fp.input_dim as f64)),
                ("num_categories", Json::Num(fp.num_categories as f64)),
                ("base_seqs", seq_strings(&stream.base_seqs)),
                ("shard_bytes", Json::from_usizes(&shard_bytes)),
            ]);
            writeln!(writer, "{header}")?;
            // stream shard-by-shard in bounded chunks: resident cost is
            // one chunk, not one corpus image per concurrent bootstrap
            let mut chunk = vec![0u8; SNAPSHOT_CHUNK];
            for (si, file) in stream.files.iter_mut().enumerate() {
                // chaos site: a torn snapshot transfer — die between
                // shards, after the header promised all their sizes
                crate::fault::check_io("ship_snapshot_shard")?;
                let Some(f) = file else { continue };
                let mut left = stream.sizes[si] as usize;
                while left > 0 {
                    let want = left.min(chunk.len());
                    f.read_exact(&mut chunk[..want])?;
                    writer.write_all(&chunk[..want])?;
                    left -= want;
                }
            }
            writer.flush()?;
            counters.snapshots_served.fetch_add(1, Ordering::Relaxed);
        }
        Err(e) => write_error(writer, &format!("{e:#}"), Vec::new())?,
    }
    Ok(())
}

/// Serve a parsed `repl_wal_tail` stream op: header line + raw frame
/// bytes (or an error line carrying the `snapshot_needed`/`diverged`
/// markers the follower dispatches on). Same routing and error contract
/// as [`serve_snapshot`].
pub fn serve_wal_tail<W: Write>(
    store: &ShardedStore,
    counters: &ReplCounters,
    shard: usize,
    from_seq: u64,
    max_bytes: usize,
    trace: u64,
    writer: &mut W,
) -> std::io::Result<()> {
    let Some(p) = persistence_for(store, writer)? else {
        return Ok(());
    };
    match wal_tail(p, shard, from_seq, max_bytes) {
        Ok(Tail::Frames {
            from_seq,
            frames,
            bytes,
            live_seq,
        }) => {
            if trace != 0 && frames > 0 {
                // steady-state polls are frequent: log traced pulls only
                // when they actually ship frames, and at debug
                obs_log::debug(
                    "shipper",
                    "tail_served",
                    &[
                        ("trace", obs_log::V::u(trace)),
                        ("shard", obs_log::V::u(shard as u64)),
                        ("frames", obs_log::V::u(frames)),
                    ],
                );
            }
            // `commit_ms`: the primary's wall clock as these frames leave
            // for the follower — the minuend of the follower's
            // `repl_visibility_lag` (apply-time − commit-time). Stamped
            // here, not in the WAL, so the frame format is unchanged and
            // the lag measures the full ship→apply pipeline.
            let header = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("shard", Json::Num(shard as f64)),
                ("from_seq", Json::Str(from_seq.to_string())),
                ("frames", Json::Num(frames as f64)),
                ("bytes", Json::Num(bytes.len() as f64)),
                ("live_seq", Json::Str(live_seq.to_string())),
                ("epoch", Json::Str(p.epoch().to_string())),
                (
                    "commit_ms",
                    Json::Str(crate::coordinator::server::now_ms().to_string()),
                ),
            ]);
            writeln!(writer, "{header}")?;
            // chaos site: a torn frame transfer — ship half the
            // promised bytes, then die. The follower applies only the
            // whole frames it can checksum and re-requests the rest.
            if let Err(e) = crate::fault::check("ship_frames") {
                writer.write_all(&bytes[..bytes.len() / 2])?;
                writer.flush()?;
                return Err(std::io::Error::new(std::io::ErrorKind::Other, e));
            }
            writer.write_all(&bytes)?;
            writer.flush()?;
            counters.tails_served.fetch_add(1, Ordering::Relaxed);
            counters.frames_shipped.fetch_add(frames, Ordering::Relaxed);
            counters
                .bytes_shipped
                .fetch_add(bytes.len() as u64, Ordering::Relaxed);
        }
        Ok(Tail::SnapshotNeeded { base_seq }) => write_error(
            writer,
            &format!(
                "from_seq {from_seq} predates every retained segment of shard \
                 {shard} (live base {base_seq}); re-seed this follower from a \
                 fresh repl_snapshot"
            ),
            vec![
                ("snapshot_needed", Json::Bool(true)),
                ("base_seq", Json::Str(base_seq.to_string())),
            ],
        )?,
        Ok(Tail::Diverged { live_seq }) => write_error(
            writer,
            &format!(
                "from_seq {from_seq} is beyond shard {shard}'s durable horizon \
                 {live_seq} — the follower holds frames this primary never \
                 wrote (diverged)"
            ),
            vec![
                ("diverged", Json::Bool(true)),
                ("live_seq", Json::Str(live_seq.to_string())),
            ],
        )?,
        Err(e) => write_error(writer, &format!("{e:#}"), Vec::new())?,
    }
    Ok(())
}
