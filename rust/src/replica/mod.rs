//! Replication: WAL shipping, follower catch-up, and read-replica
//! serving — the multi-node layer over the [`crate::persist`] stack.
//!
//! The observation (ROADMAP, and the streaming-sketch literature): once
//! the sketch corpus is a per-shard log of *mutations* — inserts,
//! deletes, upserts, rebalance moves — scaling reads is *log shipping*,
//! not re-sketching. A follower that holds the same snapshot + WAL
//! prefix as the primary holds the same arenas byte-for-byte (the log
//! replays deterministically: swap-remove deletes, in-place upserts, and
//! TTL deadlines all carry their exact effect in the frame), so it
//! answers `query`/`query_batch`/`distance` with results bit-identical
//! to the primary's — the serving tier fans out without the corpus ever
//! being sketched twice.
//!
//! ```text
//!   primary (serve --data-dir A)                follower (serve --data-dir B
//!   ┌────────────────────────────┐                        --replicate-from P)
//!   │ shards + WAL + snapshots   │   repl_snapshot   ┌───────────────────────┐
//!   │  [shipper: serves the two  │ ───────────────►  │ bootstrap: write      │
//!   │   repl_* wire ops from the │  snapshot arenas  │ snap/wal/MANIFEST,    │
//!   │   same TCP protocol]       │                   │ recover via the       │
//!   │                            │  repl_wal_tail    │ ordinary persist path │
//!   │ seq anchoring: manifest v5 │ {shard,from_seq}  │ [puller thread:       │
//!   │ base_seqs + implicit frame │ ───────────────►  │  apply frames, mirror │
//!   │ position = per-shard seq   │  checksummed raw  │  into own WAL, track  │
//!   └────────────────────────────┘  frame bytes      │  applied seq/lag]     │
//!                                                    └───────────────────────┘
//!                                    `promote` stops the puller, flips writable
//! ```
//!
//! **Sequence numbers.** Every WAL frame has an implicit monotonic
//! per-shard sequence: its position in the shard's total frame history.
//! The manifest (v5) anchors each generation with per-shard `base_seqs`
//! (frames absorbed into the snapshot cut), so frame `j` of
//! `wal-G-shard-i` is sequence `base_seqs[i] + j` — the on-disk frame
//! format is unchanged, and a follower's catch-up position is just a
//! `(shard, seq)` pair. Only frames within the primary's
//! *crash-surviving horizon* are ever shipped — never writer-pending
//! ones, and under `fsync = always` never frames written but not yet
//! fdatasync'd (a power loss could revoke those, and a follower holding
//! revoked frames would wrongly read as diverged) — so a follower can
//! never get ahead of what the primary's own restart would recover.
//!
//! **Catch-up protocol.** The follower pulls `repl_wal_tail{shard,
//! from_seq}` per shard, validates each frame's checksum
//! ([`crate::persist::wal::scan_frames`] — also the transfer-integrity
//! check), applies the valid prefix through
//! [`crate::coordinator::store::ShardedStore::apply_replicated`] (arena +
//! LSH index + id index under the primary's exact lock order), mirrors
//! the raw bytes into its *own* WAL, and re-requests from its advanced
//! applied seq — a short or torn transfer is therefore re-requested as a
//! gap, never applied twice and never half-applied. If the follower lags
//! across a snapshot rotation, the primary serves the *retained*
//! previous-generation segment (rotation keeps exactly one); a follower
//! more than one rotation behind gets `snapshot_needed` and must be
//! re-seeded (operator action: restart it with a fresh `--data-dir`). A
//! `from_seq` beyond the primary's durable horizon means the follower has
//! frames the primary never wrote — divergence — and replication halts
//! loudly rather than guessing.
//!
//! **Bootstrap and restarts.** Bootstrap fetches `repl_snapshot` (the
//! primary's snapshot arenas + manifest anchoring, fingerprint-checked
//! against the follower's own configuration), writes the files into the
//! local data dir, and commits the local MANIFEST *last* — a follower
//! killed mid-bootstrap left no manifest and simply re-bootstraps, while
//! one killed after it resumes through the ordinary recovery path and
//! continues pulling from its recovered applied seqs. Because applied
//! chunks are committed to the follower's own WAL before its cursor
//! advances, a follower crash at any point resumes at a consistent
//! prefix.
//!
//! **Serving and promotion.** A follower serves reads from its own
//! `ShardedStore` + LSH indexes and rejects `insert` with a descriptive
//! redirect to the primary. `promote` stops the puller, flushes every
//! applied frame durable (a flush failure errors and leaves the replica
//! read-only rather than overstating its durable state), durably bumps
//! the failover **epoch** past the primary's term, and flips the
//! replica writable — inserts then continue the id/seq line the primary
//! established. Promotion is local: it asserts nothing about the
//! (possibly dead) primary beyond what was already applied, which is
//! exactly the durable prefix the primary acked and shipped.
//!
//! **Failover and fencing.** Under `--auto-promote` a probe supervisor
//! ([`follower::ReplicaRuntime`]) drives `promote` unattended after a
//! configurable run of consecutive failed health probes, counted in
//! [`FailoverCounters`]. The bumped epoch rides every shipped tail
//! header and mutation ack; a revived old primary learns of the higher
//! term on first contact (a client `ping`/write naming it, or a
//! follower's `repl_wal_tail` carrying it) and fences itself read-only
//! — two writable primaries can never both ack (see
//! `coordinator::server` for the fence gate and the `demote` op).
//!
//! **Cross-shard move ordering.** A rebalance move's two frames —
//! `MoveOut` on the source shard, `MoveIn` on the destination — travel
//! in independent per-shard streams but carry a shared move id. The
//! puller defers a chunk at a `MoveOut` whose move id it has not yet
//! seen arrive as a `MoveIn` (applying the already-valid prefix before
//! it), so during catch-up a moved row is at worst transiently
//! *duplicated* for a poll cycle — never missing. The primary commits
//! the destination frame before the source frame, so the deferral always
//! resolves; a safety valve (`repl_move_defers` counts it) applies
//! anyway after ~64 consecutive deferrals rather than wedging on a
//! corrupt stream.
//!
//! Observability: `repl_*` stats fields (shipped frames/bytes on the
//! primary; applied frames/bytes, per-shard applied seq and lag, and
//! role/caught-up/diverged gauges on the follower) via [`ReplCounters`],
//! plus `persist_next_seq_shard{i}` on any durable server — the same
//! field on both sides, so "caught up" is one comparison.

pub mod follower;
pub mod shipper;

pub use follower::{bootstrap, ReplicaRuntime};

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Wire field carrying an exact u64 sequence: string-encoded (the JSON
/// model is f64-backed and seqs must roundtrip exactly), with a plain
/// number accepted for hand-written requests.
pub(crate) fn seq_field(obj: &Json, key: &str) -> anyhow::Result<u64> {
    match obj.get(key) {
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("field '{key}' is not a u64")),
        Some(Json::Num(n)) if *n >= 0.0 => Ok(*n as u64),
        _ => anyhow::bail!("missing/invalid sequence field '{key}'"),
    }
}

/// Follower-side knobs, derived from `serve --replicate-from` flags.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// Primary address (`host:port`) to bootstrap from and pull tails of.
    pub primary: String,
    /// Idle poll interval once caught up (`--repl-poll-ms`).
    pub poll: Duration,
    /// Per-tail-request byte budget; the primary always serves at least
    /// one frame, so this bounds chunk memory without stalling.
    pub max_bytes: usize,
    /// Run the failover probe supervisor (`--auto-promote`).
    pub auto_promote: bool,
    /// Health-probe cadence (`--probe-interval-ms`).
    pub probe_interval: Duration,
    /// Per-probe connect/roundtrip budget (`--probe-timeout-ms`). A
    /// primary that answers within this budget is *slow, not dead* and
    /// is never promoted over.
    pub probe_timeout: Duration,
    /// Consecutive failed probes before auto-promotion fires
    /// (`--probe-failures`).
    pub probe_failures: u32,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            primary: String::new(),
            poll: Duration::from_millis(2),
            max_bytes: 1 << 20,
            auto_promote: false,
            probe_interval: Duration::from_millis(500),
            probe_timeout: Duration::from_millis(1_000),
            probe_failures: 3,
        }
    }
}

/// Failover/fencing counters, surfaced as server-level `failover_*`
/// stats fields (and thence Prometheus gauges) on every server — zero
/// everywhere except the side they describe: probe counters move on a
/// supervised replica, `failover_fence_events` on a fenced ex-primary.
/// Kept separate from [`ReplCounters`] because they are written by the
/// probe supervisor and the server's fence gate, not the shipping path.
#[derive(Debug, Default)]
pub struct FailoverCounters {
    /// Health probes sent by the supervisor.
    pub probes: AtomicU64,
    /// Probes that missed their budget (connect/roundtrip failure).
    pub probe_failures: AtomicU64,
    /// Gauge: current run of consecutive failed probes.
    pub consecutive_failures: AtomicU64,
    /// Auto-promotions driven by the supervisor (0 or 1).
    pub promotions: AtomicU64,
    /// Times this server fenced itself on observing a higher epoch.
    pub fence_events: AtomicU64,
    /// Gauge: the epoch after the last promotion/fence event (0 = none).
    pub last_epoch: AtomicU64,
}

impl FailoverCounters {
    /// Flat `failover_*` stats fields, merged into the `stats` response
    /// by `coordinator::Coordinator::stats_fields`.
    pub fn stats_fields(&self) -> Vec<(String, f64)> {
        vec![
            (
                "failover_probes".into(),
                self.probes.load(Ordering::Relaxed) as f64,
            ),
            (
                "failover_probe_failures".into(),
                self.probe_failures.load(Ordering::Relaxed) as f64,
            ),
            (
                "failover_consecutive_failures".into(),
                self.consecutive_failures.load(Ordering::Relaxed) as f64,
            ),
            (
                "failover_promotions".into(),
                self.promotions.load(Ordering::Relaxed) as f64,
            ),
            (
                "failover_fence_events".into(),
                self.fence_events.load(Ordering::Relaxed) as f64,
            ),
            (
                "failover_last_epoch".into(),
                self.last_epoch.load(Ordering::Relaxed) as f64,
            ),
        ]
    }
}

/// Lock-free replication traffic counters plus per-shard catch-up gauges.
/// One instance is Arc-shared between `coordinator::Metrics` (which
/// surfaces them as `repl_*` stats fields) and whichever side updates
/// them: the shipper (primary) or the puller runtime (follower).
#[derive(Debug, Default)]
pub struct ReplCounters {
    /// Primary side: `repl_snapshot` requests served.
    pub snapshots_served: AtomicU64,
    /// Primary side: `repl_wal_tail` requests served.
    pub tails_served: AtomicU64,
    /// Primary side: WAL frames shipped to followers.
    pub frames_shipped: AtomicU64,
    /// Primary side: WAL payload bytes shipped to followers.
    pub bytes_shipped: AtomicU64,
    /// Follower side: frames applied to the local store.
    pub frames_applied: AtomicU64,
    /// Follower side: frame bytes applied to the local store.
    pub bytes_applied: AtomicU64,
    /// Follower side: connections established to the primary.
    pub connects: AtomicU64,
    /// Follower side: apply/transport stalls (snapshot_needed, apply
    /// errors, connection failures) — a rising value with zero lag
    /// movement is the "operator, look here" signal.
    pub stalls: AtomicU64,
    /// Follower side: chunks deferred at a `MoveOut` whose paired
    /// `MoveIn` had not yet arrived on the destination shard's stream
    /// (dst-before-src ordering during catch-up).
    pub move_defers: AtomicU64,
    /// Follower side gauge: 1 once divergence was detected (replication
    /// halts; reads keep serving the last consistent prefix).
    pub diverged: AtomicU64,
    /// Follower side gauge: 1 while the last full sweep found every shard
    /// at zero lag.
    pub caught_up: AtomicU64,
    /// Follower side: wall-clock visibility lag — apply time minus the
    /// primary's `commit_ms` tail-header stamp, recorded per applied
    /// chunk. Frame-count lag says how far behind the follower is in
    /// *work*; this says how stale its reads are in *time*, which is the
    /// question `--max-read-staleness-ms` budgets answer to.
    pub visibility_lag: crate::obs::ObsHistogram,
    /// Per-shard `(applied_seq, lag)` gauges, sized on first update.
    per_shard: Mutex<Vec<(u64, u64)>>,
    /// Per-shard last-observed visibility age in ms (gauge), sized on
    /// first update — the labeled `repl_visibility_age_ms` Prometheus
    /// family.
    per_shard_age_ms: Mutex<Vec<u64>>,
}

fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl ReplCounters {
    /// Record shard `i`'s applied-seq and lag gauges (follower side).
    pub fn record_shard(&self, shard: usize, applied_seq: u64, lag: u64) {
        let mut g = lock_recover(&self.per_shard);
        if g.len() <= shard {
            g.resize(shard + 1, (0, 0));
        }
        g[shard] = (applied_seq, lag);
    }

    /// Record one applied chunk's wall-clock visibility age (follower
    /// side): into the `repl_visibility_lag` histogram and shard `i`'s
    /// last-observed age gauge.
    pub fn record_visibility(&self, shard: usize, age_ms: u64) {
        self.visibility_lag.record_us(age_ms.saturating_mul(1_000));
        let mut g = lock_recover(&self.per_shard_age_ms);
        if g.len() <= shard {
            g.resize(shard + 1, 0);
        }
        g[shard] = age_ms;
    }

    /// Flat `repl_*` stats fields, merged into the `stats` response by
    /// `coordinator::Metrics::snapshot`.
    pub fn stats_fields(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = vec![
            (
                "repl_snapshots_served".into(),
                self.snapshots_served.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_tails_served".into(),
                self.tails_served.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_frames_shipped".into(),
                self.frames_shipped.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_bytes_shipped".into(),
                self.bytes_shipped.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_frames_applied".into(),
                self.frames_applied.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_bytes_applied".into(),
                self.bytes_applied.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_connects".into(),
                self.connects.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_stalls".into(),
                self.stalls.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_move_defers".into(),
                self.move_defers.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_diverged".into(),
                self.diverged.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_caught_up".into(),
                self.caught_up.load(Ordering::Relaxed) as f64,
            ),
            (
                "repl_visibility_lag_count".into(),
                self.visibility_lag.count() as f64,
            ),
            (
                "repl_visibility_lag_p50_ms".into(),
                self.visibility_lag.quantile(0.50) * 1e3,
            ),
            (
                "repl_visibility_lag_p99_ms".into(),
                self.visibility_lag.quantile(0.99) * 1e3,
            ),
        ];
        for (si, (applied, lag)) in lock_recover(&self.per_shard).iter().enumerate() {
            out.push((format!("repl_applied_seq_shard{si}"), *applied as f64));
            out.push((format!("repl_lag_shard{si}"), *lag as f64));
        }
        for (si, age) in lock_recover(&self.per_shard_age_ms).iter().enumerate() {
            out.push((format!("repl_visibility_age_ms_shard{si}"), *age as f64));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_surface_per_shard_gauges() {
        let c = ReplCounters::default();
        c.frames_shipped.fetch_add(7, Ordering::Relaxed);
        c.record_shard(1, 42, 3);
        let fields = c.stats_fields();
        let get = |k: &str| {
            fields
                .iter()
                .find(|(n, _)| n == k)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| panic!("field '{k}' missing"))
        };
        assert_eq!(get("repl_frames_shipped"), 7.0);
        assert_eq!(get("repl_applied_seq_shard0"), 0.0, "shard 0 backfilled");
        assert_eq!(get("repl_applied_seq_shard1"), 42.0);
        assert_eq!(get("repl_lag_shard1"), 3.0);
        assert!(fields.iter().all(|(n, _)| n.starts_with("repl_")));
        // overwrite, not accumulate: these are gauges
        c.record_shard(1, 50, 0);
        let fields = c.stats_fields();
        let lag = fields
            .iter()
            .find(|(n, _)| n == "repl_lag_shard1")
            .unwrap()
            .1;
        assert_eq!(lag, 0.0);
    }

    #[test]
    fn failover_counters_surface_failover_prefixed_fields() {
        let f = FailoverCounters::default();
        f.probes.fetch_add(9, Ordering::Relaxed);
        f.promotions.fetch_add(1, Ordering::Relaxed);
        f.last_epoch.store(4, Ordering::Relaxed);
        let fields = f.stats_fields();
        assert!(fields.iter().all(|(n, _)| n.starts_with("failover_")));
        let get = |k: &str| fields.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(get("failover_probes"), 9.0);
        assert_eq!(get("failover_promotions"), 1.0);
        assert_eq!(get("failover_last_epoch"), 4.0);
        assert_eq!(get("failover_fence_events"), 0.0);
        assert_eq!(fields.len(), 6);
    }
}
