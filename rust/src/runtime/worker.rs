//! Thread-confined XLA worker (actor pattern).
//!
//! The `xla` crate's PJRT handles are `Rc`/raw-pointer based — not `Send`.
//! [`XlaHandle`] spawns a dedicated thread that owns the [`XlaEngine`] and
//! services jobs over a channel, giving the rest of the coordinator a
//! `Send + Sync + Clone` interface.

use super::artifacts::Manifest;
use super::engine::XlaEngine;
use crate::data::CatVector;
use crate::sketch::BitVec;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

enum Job {
    SketchBatch(Vec<CatVector>, SyncSender<Result<Vec<BitVec>>>),
    AllPairs(Vec<BitVec>, SyncSender<Result<Vec<f64>>>),
    Cross(Vec<BitVec>, Vec<BitVec>, SyncSender<Result<Vec<f64>>>),
    SketchAllPairs(Vec<CatVector>, SyncSender<Result<Vec<f64>>>),
}

/// Cloneable, thread-safe handle to the XLA worker thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: SyncSender<Job>,
    pub manifest: Manifest,
}

impl XlaHandle {
    /// Spawn the worker; loads + compiles the artifacts on the worker
    /// thread and reports the manifest (or the load error) back.
    pub fn spawn(dir: &str) -> Result<XlaHandle> {
        let (tx, rx) = sync_channel::<Job>(64);
        let (ready_tx, ready_rx) = sync_channel::<Result<Manifest>>(1);
        let dir = dir.to_string();
        std::thread::Builder::new()
            .name("cabin-xla".into())
            .spawn(move || worker_loop(&dir, rx, ready_tx))
            .map_err(|e| anyhow!("spawn xla worker: {e}"))?;
        let manifest = ready_rx
            .recv()
            .map_err(|_| anyhow!("xla worker died during load"))??;
        Ok(XlaHandle { tx, manifest })
    }

    /// Try the default artifact locations.
    pub fn try_default() -> Option<XlaHandle> {
        for dir in ["artifacts", "../artifacts"] {
            if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
                match Self::spawn(dir) {
                    Ok(h) => return Some(h),
                    Err(e) => {
                        crate::obs::log::warn(
                            "runtime",
                            "artifacts_unusable",
                            &[
                                ("dir", crate::obs::log::V::s(dir)),
                                ("error", crate::obs::log::V::s(format!("{e:#}"))),
                            ],
                        );
                        return None;
                    }
                }
            }
        }
        None
    }

    fn call<T>(&self, make: impl FnOnce(SyncSender<Result<T>>) -> Job) -> Result<T> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(make(reply_tx))
            .map_err(|_| anyhow!("xla worker stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("xla worker dropped reply"))?
    }

    pub fn cabin_sketch(&self, batch: Vec<CatVector>) -> Result<Vec<BitVec>> {
        self.call(|tx| Job::SketchBatch(batch, tx))
    }

    pub fn cham_allpairs(&self, sketches: Vec<BitVec>) -> Result<Vec<f64>> {
        self.call(|tx| Job::AllPairs(sketches, tx))
    }

    pub fn cham_cross(&self, q: Vec<BitVec>, c: Vec<BitVec>) -> Result<Vec<f64>> {
        self.call(|tx| Job::Cross(q, c, tx))
    }

    pub fn sketch_allpairs(&self, batch: Vec<CatVector>) -> Result<Vec<f64>> {
        self.call(|tx| Job::SketchAllPairs(batch, tx))
    }

    /// Native sketcher configured identically to the artifacts.
    pub fn native_equivalent(&self) -> Result<crate::sketch::CabinSketcher> {
        let cfg = crate::sketch::SketchConfig::new(
            self.manifest.n,
            self.manifest.c,
            self.manifest.d,
            self.manifest.seed,
        );
        let pi = self.manifest.load_pi()?;
        Ok(crate::sketch::CabinSketcher::with_tables(cfg, pi))
    }
}

fn worker_loop(dir: &str, rx: Receiver<Job>, ready: SyncSender<Result<Manifest>>) {
    let engine = match XlaEngine::load(dir) {
        Ok(e) => {
            let _ = ready.send(Ok(e.manifest.clone()));
            e
        }
        Err(err) => {
            let _ = ready.send(Err(err));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        match job {
            Job::SketchBatch(batch, reply) => {
                let _ = reply.send(engine.cabin_sketch(&batch));
            }
            Job::AllPairs(sketches, reply) => {
                let _ = reply.send(engine.cham_allpairs(&sketches));
            }
            Job::Cross(q, c, reply) => {
                let _ = reply.send(engine.cham_cross(&q, &c));
            }
            Job::SketchAllPairs(batch, reply) => {
                let _ = reply.send(engine.sketch_allpairs(&batch));
            }
        }
    }
}
