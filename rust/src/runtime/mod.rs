//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the `xla` crate's PJRT
//! CPU client. Python never runs here; the HLO text is the only interface.
//!
//! * [`artifacts`] — manifest + sidecar (π/ψ) parsing and validation
//!   against the rust-side derivations.
//! * [`engine`] — compile-once executable cache + typed entry points
//!   (sketch a batch, all-pairs estimates, query×corpus estimates).
//!
//! Everything degrades gracefully: if `artifacts/` is absent the engine
//! reports unavailable and callers (coordinator, benches) use the native
//! bit-packed path, which is estimator-identical by construction.

pub mod artifacts;
pub mod engine;
pub mod worker;

pub use artifacts::Manifest;
pub use engine::XlaEngine;
pub use worker::XlaHandle;
