//! Artifact manifest + sidecar handling.
//!
//! `manifest.json` (written by aot.py) records the fixed shapes each HLO
//! module was lowered with, plus the (n, c, d, seed) configuration; the
//! sidecars carry the π table (u32 LE) and the per-attribute ψ matrix
//! (u8, row-major (n, c+1)). [`Manifest::validate_against_native`] checks
//! the sidecars agree bit-for-bit with the rust derivations — the tripwire
//! for cross-language drift.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_file: String,
    /// (dtype, shape) per input, e.g. ("i32", [64, 4096]).
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: String,
    /// Input dimension n.
    pub n: usize,
    /// Category bound c.
    pub c: u16,
    /// Sketch dimension d.
    pub d: usize,
    /// Shared seed for ψ/π.
    pub seed: u64,
    /// Batch sizes: sketch batch m, all-pairs mp, query mq, corpus mc.
    pub m: usize,
    pub mp: usize,
    pub mq: usize,
    pub mc: usize,
    pub pi_file: String,
    pub psi_file: String,
    pub artifacts: Vec<ArtifactSpec>,
}

fn shapes(v: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    let mut out = Vec::new();
    for item in v.as_arr().context("expected array of [dtype, shape]")? {
        let pair = item.as_arr().context("expected [dtype, shape]")?;
        let dtype = pair
            .first()
            .and_then(|d| d.as_str())
            .context("dtype")?
            .to_string();
        let shape = pair
            .get(1)
            .and_then(|s| s.as_arr())
            .context("shape")?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        out.push((dtype, shape));
    }
    Ok(out)
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path}"))?;
        let root = json::parse(&text)?;
        let cfg = root.get("config").context("manifest: config")?;
        let sidecars = root.get("sidecars").context("manifest: sidecars")?;
        let arts = match root.get("artifacts") {
            Some(Json::Obj(m)) => m,
            _ => bail!("manifest: artifacts object missing"),
        };
        let mut artifacts = Vec::new();
        for (name, spec) in arts {
            artifacts.push(ArtifactSpec {
                name: name.clone(),
                hlo_file: spec.req_str("hlo")?.to_string(),
                inputs: shapes(spec.get("inputs").context("inputs")?)?,
                outputs: shapes(spec.get("outputs").context("outputs")?)?,
            });
        }
        Ok(Manifest {
            dir: dir.to_string(),
            n: cfg.req_usize("n")?,
            c: cfg.req_usize("c")? as u16,
            d: cfg.req_usize("d")?,
            seed: cfg.req_usize("seed")? as u64,
            m: cfg.req_usize("m")?,
            mp: cfg.req_usize("mp")?,
            mq: cfg.req_usize("mq")?,
            mc: cfg.req_usize("mc")?,
            pi_file: sidecars.req_str("pi")?.to_string(),
            psi_file: sidecars.req_str("psi")?.to_string(),
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn hlo_path(&self, name: &str) -> Option<String> {
        self.artifact(name).map(|a| format!("{}/{}", self.dir, a.hlo_file))
    }

    /// Load the π sidecar (u32 little-endian).
    pub fn load_pi(&self) -> Result<Vec<u32>> {
        let path = format!("{}/{}", self.dir, self.pi_file);
        let bytes = std::fs::read(&path).with_context(|| format!("read {path}"))?;
        if bytes.len() != self.n * 4 {
            bail!("pi sidecar wrong size: {} != {}", bytes.len(), self.n * 4);
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load the ψ matrix sidecar (row-major (n, c+1) u8).
    pub fn load_psi_matrix(&self) -> Result<Vec<u8>> {
        let path = format!("{}/{}", self.dir, self.psi_file);
        let bytes = std::fs::read(&path).with_context(|| format!("read {path}"))?;
        let expect = self.n * (self.c as usize + 1);
        if bytes.len() != expect {
            bail!("psi sidecar wrong size: {} != {}", bytes.len(), expect);
        }
        Ok(bytes)
    }

    /// Verify the sidecars equal the rust-side derivations bit-for-bit.
    pub fn validate_against_native(&self) -> Result<()> {
        let pi = self.load_pi()?;
        let native_pi = crate::sketch::mappings::derive_pi(self.seed, self.n, self.d);
        if pi != native_pi {
            bail!("pi sidecar diverges from rust derivation");
        }
        let psi = self.load_psi_matrix()?;
        let be = crate::sketch::BinEm::new(
            self.n,
            self.c,
            crate::sketch::PsiMode::PerAttribute,
            self.seed,
        );
        let cw = self.c as usize + 1;
        for i in (0..self.n).step_by((self.n / 257).max(1)) {
            for v in 0..=self.c {
                if psi[i * cw + v as usize] != be.psi(i, v) {
                    bail!("psi sidecar diverges at ({}, {})", i, v);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a fake manifest dir for parser tests (no XLA involved).
    fn fake_dir() -> String {
        let dir = std::env::temp_dir().join(format!("cabin_manifest_{}", std::process::id()));
        let dir_s = dir.to_str().unwrap().to_string();
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "config": {"n": 16, "c": 3, "d": 8, "m": 2, "mp": 4, "mq": 2, "mc": 4, "seed": 5},
            "sidecars": {"pi": "pi.u32", "psi": "psi.u8"},
            "artifacts": {
                "cabin_sketch": {"hlo": "cs.hlo.txt", "inputs": [["i32", [2, 16]]], "outputs": [["f32", [2, 8]]]}
            }
        }"#;
        std::fs::write(format!("{dir_s}/manifest.json"), manifest).unwrap();
        // sidecars from the native derivations
        let pi = crate::sketch::mappings::derive_pi(5, 16, 8);
        let mut pi_bytes = Vec::new();
        for v in &pi {
            pi_bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(format!("{dir_s}/pi.u32"), pi_bytes).unwrap();
        let be = crate::sketch::BinEm::new(16, 3, crate::sketch::PsiMode::PerAttribute, 5);
        let mut psi_bytes = Vec::new();
        for i in 0..16 {
            for v in 0..=3u16 {
                psi_bytes.push(be.psi(i, v));
            }
        }
        std::fs::write(format!("{dir_s}/psi.u8"), psi_bytes).unwrap();
        dir_s
    }

    #[test]
    fn parse_and_validate() {
        let dir = fake_dir();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n, 16);
        assert_eq!(m.d, 8);
        assert_eq!(m.artifact("cabin_sketch").unwrap().inputs[0].1, vec![2, 16]);
        assert!(m.artifact("nope").is_none());
        m.validate_against_native().unwrap();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn corrupted_sidecar_detected() {
        let dir = fake_dir();
        // flip a pi byte
        let p = format!("{dir}/pi.u32");
        let mut b = std::fs::read(&p).unwrap();
        b[0] ^= 1;
        std::fs::write(&p, b).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.validate_against_native().is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/cabin").is_err());
    }
}
