//! The XLA execution engine: one PJRT CPU client, one compiled executable
//! per artifact, typed batch entry points.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`, with the
//! outputs unwrapped via `to_tuple1()` (aot.py lowers with
//! `return_tuple=True`).

use super::artifacts::Manifest;
use crate::data::CatVector;
use crate::sketch::BitVec;
use anyhow::{bail, Context, Result};
use std::sync::Mutex;

/// Compiled executables for the artifact set. `execute` takes `&self` but
/// the underlying PJRT executable is not documented thread-safe, so calls
/// are serialised through a mutex — the coordinator batches upstream of
/// this anyway.
pub struct XlaEngine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    lock: Mutex<()>,
    exe_cabin_sketch: xla::PjRtLoadedExecutable,
    exe_cham_allpairs: xla::PjRtLoadedExecutable,
    exe_cham_cross: xla::PjRtLoadedExecutable,
    exe_sketch_allpairs: xla::PjRtLoadedExecutable,
}

impl XlaEngine {
    /// Load + compile everything in `dir`. Fails if artifacts are missing
    /// or the sidecars diverge from the native derivations.
    pub fn load(dir: &str) -> Result<XlaEngine> {
        let manifest = Manifest::load(dir)?;
        manifest
            .validate_against_native()
            .context("sidecar validation")?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest
                .hlo_path(name)
                .with_context(|| format!("artifact {name} missing from manifest"))?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compile {name}"))
        };
        Ok(XlaEngine {
            exe_cabin_sketch: compile("cabin_sketch")?,
            exe_cham_allpairs: compile("cham_allpairs")?,
            exe_cham_cross: compile("cham_cross")?,
            exe_sketch_allpairs: compile("sketch_allpairs")?,
            client,
            lock: Mutex::new(()),
            manifest,
        })
    }

    /// Convenience: try the default location, None if unavailable.
    pub fn try_default() -> Option<XlaEngine> {
        for dir in ["artifacts", "../artifacts"] {
            if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
                match Self::load(dir) {
                    Ok(e) => return Some(e),
                    Err(err) => {
                        crate::obs::log::warn(
                            "runtime",
                            "artifacts_unusable",
                            &[
                                ("dir", crate::obs::log::V::s(dir)),
                                ("error", crate::obs::log::V::s(format!("{err:#}"))),
                            ],
                        );
                        return None;
                    }
                }
            }
        }
        None
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
        expect_len: usize,
    ) -> Result<Vec<f32>> {
        let _guard = self.lock.lock().unwrap();
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        let out = lit.to_tuple1()?; // aot.py lowers return_tuple=True, 1-tuple
        let v = out.to_vec::<f32>()?;
        if v.len() != expect_len {
            bail!("output length {} != expected {}", v.len(), expect_len);
        }
        Ok(v)
    }

    /// Densify a categorical vector batch into the artifact's (m, n) i32
    /// layout, padding missing rows with all-zeros (estimates for padding
    /// rows are discarded by callers).
    fn densify(&self, batch: &[CatVector]) -> Result<xla::Literal> {
        let (m, n) = (self.manifest.m, self.manifest.n);
        if batch.len() > m {
            bail!("batch {} exceeds artifact batch size {}", batch.len(), m);
        }
        let mut flat = vec![0i32; m * n];
        for (r, p) in batch.iter().enumerate() {
            if p.dim() != n {
                bail!("vector dim {} != artifact n {}", p.dim(), n);
            }
            for &(i, v) in p.entries() {
                flat[r * n + i as usize] = v as i32;
            }
        }
        Ok(xla::Literal::vec1(&flat).reshape(&[m as i64, n as i64])?)
    }

    fn sketch_matrix_literal(&self, sketches: &[BitVec], rows: usize) -> Result<xla::Literal> {
        let d = self.manifest.d;
        if sketches.len() > rows {
            bail!("batch {} exceeds artifact rows {}", sketches.len(), rows);
        }
        let mut flat = vec![0f32; rows * d];
        for (r, s) in sketches.iter().enumerate() {
            if s.len() != d {
                bail!("sketch dim {} != artifact d {}", s.len(), d);
            }
            for b in s.iter_ones() {
                flat[r * d + b] = 1.0;
            }
        }
        Ok(xla::Literal::vec1(&flat).reshape(&[rows as i64, d as i64])?)
    }

    /// Run the `cabin_sketch` artifact on ≤ m categorical vectors; returns
    /// one packed sketch per input.
    pub fn cabin_sketch(&self, batch: &[CatVector]) -> Result<Vec<BitVec>> {
        let (m, d) = (self.manifest.m, self.manifest.d);
        let lit = self.densify(batch)?;
        let out = self.run_f32(&self.exe_cabin_sketch, &[lit], m * d)?;
        Ok(batch
            .iter()
            .enumerate()
            .map(|(r, _)| BitVec::from_f32s(&out[r * d..(r + 1) * d]))
            .collect())
    }

    /// Run `cham_allpairs` on ≤ mp sketches; returns the (len × len)
    /// estimate matrix (padding rows stripped).
    pub fn cham_allpairs(&self, sketches: &[BitVec]) -> Result<Vec<f64>> {
        let mp = self.manifest.mp;
        let k = sketches.len();
        let lit = self.sketch_matrix_literal(sketches, mp)?;
        let out = self.run_f32(&self.exe_cham_allpairs, &[lit], mp * mp)?;
        let mut res = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                res[i * k + j] = out[i * mp + j] as f64;
            }
        }
        Ok(res)
    }

    /// Run `cham_cross`: queries (≤ mq) × corpus shard (≤ mc).
    pub fn cham_cross(&self, queries: &[BitVec], corpus: &[BitVec]) -> Result<Vec<f64>> {
        let (mq, mc) = (self.manifest.mq, self.manifest.mc);
        let lq = self.sketch_matrix_literal(queries, mq)?;
        let lc = self.sketch_matrix_literal(corpus, mc)?;
        let out = self.run_f32(&self.exe_cham_cross, &[lq, lc], mq * mc)?;
        let (nq, nc) = (queries.len(), corpus.len());
        let mut res = vec![0.0f64; nq * nc];
        for i in 0..nq {
            for j in 0..nc {
                res[i * nc + j] = out[i * mc + j] as f64;
            }
        }
        Ok(res)
    }

    /// Fused end-to-end artifact: categorical batch → all-pairs estimates.
    pub fn sketch_allpairs(&self, batch: &[CatVector]) -> Result<Vec<f64>> {
        let m = self.manifest.m;
        let lit = self.densify(batch)?;
        let out = self.run_f32(&self.exe_sketch_allpairs, &[lit], m * m)?;
        let k = batch.len();
        let mut res = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                res[i * k + j] = out[i * m + j] as f64;
            }
        }
        Ok(res)
    }

    /// Native sketcher configured identically to the artifacts (π from the
    /// sidecar, ψ recomputed — validated equal at load).
    pub fn native_equivalent(&self) -> Result<crate::sketch::CabinSketcher> {
        let cfg = crate::sketch::SketchConfig::new(
            self.manifest.n,
            self.manifest.c,
            self.manifest.d,
            self.manifest.seed,
        );
        let pi = self.manifest.load_pi()?;
        Ok(crate::sketch::CabinSketcher::with_tables(cfg, pi))
    }
}

// Integration tests that need real artifacts live in
// rust/tests/integration_runtime.rs (skipped when artifacts/ is absent).
