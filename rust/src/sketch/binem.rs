//! **BinEm** — stage 1 of Cabin (Algorithm 1, lines 6–13): a random binary
//! encoding of a categorical vector that *preserves dimension* and halves
//! Hamming distances in expectation (Lemma 2: `HD(u,v) = 2·E[HD(u',v')]`).
//!
//! Two ψ modes:
//!
//! * [`PsiMode::Shared`] — the construction as *printed* in the paper: one
//!   mapping ψ : {1,…,c} → {0,1} applied at every position (Figure 1). Two
//!   coordinates holding the same pair of values reuse the same coin flips,
//!   which correlates the per-coordinate indicators `W'_i` that Lemma 2's
//!   Chernoff step treats as independent. On BoW-like data, where most
//!   values equal 1, a single coin (ψ(1)) then controls the majority of all
//!   coordinates and the per-draw variance explodes (ablation A2 measures
//!   this; Figure 4's tight box plots are unreachable in this mode).
//! * [`PsiMode::PerAttribute`] — **the default**: an independent ψ_i per
//!   coordinate, `ψ_i(v) = bit(mix64(seed, i, v))`. This is the
//!   construction under which the paper's stated analysis (independent
//!   `W'_i`) and its empirical variance results actually hold, at the cost
//!   of one hash per nonzero instead of a table lookup. The python AOT
//!   side bakes the identical table (`prng.derive_psi_matrix`).

use super::bitvec::BitVec;
use super::mappings::derive_psi;
use crate::data::CatVector;
use crate::util::rng::mix64;

/// How the category mapping ψ is instantiated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PsiMode {
    /// Single shared ψ over category values (the paper's construction).
    Shared,
    /// Independent ψ per attribute position (ablation extension).
    PerAttribute,
}

/// The BinEm encoder.
#[derive(Clone, Debug)]
pub struct BinEm {
    dim: usize,
    mode: PsiMode,
    seed: u64,
    /// ψ table for `Shared` mode; `table[v] ∈ {0,1}`, `table[0] = 0`.
    psi_table: Vec<u8>,
}

impl BinEm {
    pub fn new(dim: usize, num_categories: u16, mode: PsiMode, seed: u64) -> Self {
        Self {
            dim,
            mode,
            seed,
            psi_table: match mode {
                PsiMode::Shared => derive_psi(seed, num_categories),
                PsiMode::PerAttribute => Vec::new(),
            },
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn mode(&self) -> PsiMode {
        self.mode
    }

    /// ψ applied to value `v` at position `i` (position ignored in Shared
    /// mode). Returns 0 for missing values by construction.
    #[inline]
    pub fn psi(&self, i: usize, v: u16) -> u8 {
        if v == 0 {
            return 0;
        }
        match self.mode {
            PsiMode::Shared => {
                // values beyond the table (shouldn't happen with correct c)
                // hash deterministically instead of panicking
                *self
                    .psi_table
                    .get(v as usize)
                    .unwrap_or(&((mix64(self.seed ^ v as u64) & 1) as u8))
            }
            PsiMode::PerAttribute => {
                (mix64(self.seed ^ ((i as u64) << 20) ^ v as u64) & 1) as u8
            }
        }
    }

    /// Materialise `u' = BinEm(u) ∈ {0,1}^n` as a packed bit vector.
    /// Used by the analysis experiments (Figures 4–5) and the baselines
    /// that operate on BinEm embeddings (BCS, Hamming-LSH).
    pub fn encode(&self, u: &CatVector) -> BitVec {
        debug_assert_eq!(u.dim(), self.dim);
        let mut out = BitVec::zeros(self.dim);
        for &(i, v) in u.entries() {
            if self.psi(i as usize, v) == 1 {
                out.set(i as usize);
            }
        }
        out
    }

    /// Iterate the positions of set bits in `BinEm(u)` without
    /// materialising the n-bit vector — the fused Cabin hot path.
    pub fn encode_ones<'a>(&'a self, u: &'a CatVector) -> impl Iterator<Item = usize> + 'a {
        u.entries()
            .iter()
            .filter(move |&&(i, v)| self.psi(i as usize, v) == 1)
            .map(|&(i, _)| i as usize)
    }

    /// The ψ table (Shared mode); exposed for the AOT artifact check.
    pub fn psi_table(&self) -> &[u8] {
        &self.psi_table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn zero_preservation_lemma1a() {
        // Lemma 1(a): nonzeros of u' ⊆ nonzeros of u.
        let mut rng = Xoshiro256::new(3);
        let u = CatVector::random(500, 40, 9, &mut rng);
        let be = BinEm::new(500, 9, PsiMode::Shared, 11);
        let u1 = be.encode(&u);
        assert!(u1.count_ones() <= u.nnz());
        for i in u1.iter_ones() {
            assert_ne!(u.get(i), 0, "bit set where u missing");
        }
    }

    #[test]
    fn expectation_lemma1b() {
        // Lemma 1(b): E[|u'|] = nnz(u)/2, over independent ψ draws.
        let mut rng = Xoshiro256::new(5);
        let u = CatVector::random(2000, 200, 50, &mut rng);
        let trials = 400;
        let mut total = 0usize;
        for s in 0..trials {
            let be = BinEm::new(2000, 50, PsiMode::Shared, s as u64);
            total += be.encode(&u).count_ones();
        }
        let mean = total as f64 / trials as f64;
        let expect = u.nnz() as f64 / 2.0;
        assert!(
            (mean - expect).abs() < 0.05 * expect,
            "mean {} expect {}",
            mean,
            expect
        );
    }

    #[test]
    fn hamming_halving_lemma2() {
        // Lemma 2(a): E[HD(u',v')] = HD(u,v)/2.
        let mut rng = Xoshiro256::new(6);
        let u = CatVector::random(3000, 150, 20, &mut rng);
        let v = CatVector::random(3000, 150, 20, &mut rng);
        let h = u.hamming(&v) as f64;
        let trials = 500;
        for mode in [PsiMode::Shared, PsiMode::PerAttribute] {
            let mut total = 0usize;
            for s in 0..trials {
                let be = BinEm::new(3000, 20, mode, 1000 + s as u64);
                total += be.encode(&u).xor_count(&be.encode(&v));
            }
            let mean = total as f64 / trials as f64;
            assert!(
                (mean - h / 2.0).abs() < 0.05 * h,
                "{:?}: mean {} expect {}",
                mode,
                mean,
                h / 2.0
            );
        }
    }

    #[test]
    fn equal_coordinates_never_differ() {
        // First observation in Lemma 2's proof: u_i = v_i ⇒ u'_i = v'_i.
        let u = CatVector::from_dense(&[4, 0, 2, 2, 0, 7]);
        let v = CatVector::from_dense(&[4, 0, 2, 3, 1, 7]);
        for mode in [PsiMode::Shared, PsiMode::PerAttribute] {
            for seed in 0..50 {
                let be = BinEm::new(6, 9, mode, seed);
                let (a, b) = (be.encode(&u), be.encode(&v));
                for i in [0usize, 1, 2, 5] {
                    assert_eq!(a.get(i), b.get(i), "seed {} i {}", seed, i);
                }
            }
        }
    }

    #[test]
    fn encode_ones_matches_encode() {
        let mut rng = Xoshiro256::new(9);
        let u = CatVector::random(800, 60, 12, &mut rng);
        for mode in [PsiMode::Shared, PsiMode::PerAttribute] {
            let be = BinEm::new(800, 12, mode, 77);
            let full = be.encode(&u);
            let ones: Vec<usize> = be.encode_ones(&u).collect();
            assert_eq!(ones, full.iter_ones().collect::<Vec<_>>());
        }
    }

    /// Cross-language contract: python/tests/test_prng.py pins the same
    /// matrix from prng.derive_psi_matrix(42, 8, 5).
    #[test]
    fn per_attribute_psi_matches_python() {
        let expect: [[u8; 6]; 8] = [
            [0, 0, 0, 1, 1, 1],
            [0, 1, 0, 1, 0, 0],
            [0, 1, 1, 0, 0, 0],
            [0, 0, 0, 1, 1, 0],
            [0, 0, 1, 0, 1, 1],
            [0, 1, 1, 0, 0, 1],
            [0, 1, 0, 0, 1, 0],
            [0, 1, 1, 1, 0, 1],
        ];
        let be = BinEm::new(8, 5, PsiMode::PerAttribute, 42);
        for i in 0..8 {
            for v in 0..=5u16 {
                assert_eq!(be.psi(i, v), expect[i][v as usize], "i={} v={}", i, v);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let u = CatVector::from_dense(&[1, 2, 3, 0, 5]);
        let a = BinEm::new(5, 5, PsiMode::Shared, 1).encode(&u);
        let b = BinEm::new(5, 5, PsiMode::Shared, 1).encode(&u);
        assert_eq!(a, b);
    }
}
