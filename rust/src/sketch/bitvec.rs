//! Packed bit-vector substrate: the representation of every binary sketch.
//!
//! Sketches are `d`-bit vectors stored as `u64` words. All pairwise
//! statistics the estimators need — Hamming weight, Hamming distance,
//! bitwise inner product, union size — are word-parallel popcounts, which is
//! exactly the "faster bitwise operators" advantage the paper claims for
//! binary sketches (Section 1). The word-slice reductions themselves now
//! live in [`crate::sketch::kernels`], which picks the widest
//! implementation the running CPU supports (AVX2 / AVX-512-VPOPCNTDQ /
//! NEON, scalar otherwise) once at startup.
//!
//! The kernels come in two layers: free functions over raw `&[u64]` word
//! slices ([`popcount_words`], [`and_count_words`], [`xor_count_words`],
//! [`or_count_words`]) — these are what arena scans over
//! [`crate::sketch::matrix::SketchMatrix`] rows call, with no `BitVec`
//! construction or cloning — and the [`BitVec`] methods, which are thin
//! wrappers over the same word kernels. Both layers route through the
//! process-wide dispatch table ([`crate::sketch::kernels::active`]);
//! every arm is bit-identical to the scalar oracle in
//! [`crate::sketch::kernels::scalar`]. Operand word-length mismatches are
//! a hard error in every build profile: truncating to the shorter slice
//! would silently mask dimension-mismatch bugs.

use super::kernels;

/// A fixed-length packed bit vector.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BitVec {
    bits: usize,
    words: Vec<u64>,
}

impl BitVec {
    /// All-zeros vector of `bits` bits.
    pub fn zeros(bits: usize) -> Self {
        Self {
            bits,
            words: vec![0u64; bits.div_ceil(64)],
        }
    }

    /// Build from an iterator of set-bit positions.
    pub fn from_indices<I: IntoIterator<Item = usize>>(bits: usize, idx: I) -> Self {
        let mut v = Self::zeros(bits);
        for i in idx {
            v.set(i);
        }
        v
    }

    /// Reassemble from a packed word buffer (arena row views). The caller
    /// guarantees the tail bits beyond `bits` are zero — rows copied out of
    /// a [`crate::sketch::matrix::SketchMatrix`] satisfy this because they
    /// were packed from `BitVec`s in the first place.
    pub fn from_words(bits: usize, words: Vec<u64>) -> Self {
        assert_eq!(
            words.len(),
            bits.div_ceil(64),
            "word buffer length {} does not match {} bits",
            words.len(),
            bits
        );
        Self { bits, words }
    }

    /// Build from a 0/1 byte slice (test/interop convenience).
    pub fn from_bytes01(bytes: &[u8]) -> Self {
        let mut v = Self::zeros(bytes.len());
        for (i, &b) in bytes.iter().enumerate() {
            if b != 0 {
                v.set(i);
            }
        }
        v
    }

    /// Build from f32 0/1 values (XLA artifact outputs).
    pub fn from_f32s(vals: &[f32]) -> Self {
        let mut v = Self::zeros(vals.len());
        for (i, &x) in vals.iter().enumerate() {
            if x >= 0.5 {
                v.set(i);
            }
        }
        v
    }

    /// Expand into f32 0/1 values (XLA artifact inputs).
    pub fn to_f32s(&self) -> Vec<f32> {
        (0..self.bits)
            .map(|i| if self.get(i) { 1.0 } else { 0.0 })
            .collect()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bits
    }

    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.bits);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.bits);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.bits);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Reset to all zeros without reallocating (hot-path reuse).
    pub fn zero_out(&mut self) {
        for w in self.words.iter_mut() {
            *w = 0;
        }
    }

    /// Hamming weight `|u|`.
    #[inline]
    pub fn count_ones(&self) -> usize {
        popcount_words(&self.words)
    }

    /// Bitwise inner product `⟨u,v⟩ = |u ∧ v|`.
    #[inline]
    pub fn and_count(&self, other: &BitVec) -> usize {
        debug_assert_eq!(self.bits, other.bits);
        and_count_words(&self.words, &other.words)
    }

    /// Hamming distance `|u ⊕ v|`.
    #[inline]
    pub fn xor_count(&self, other: &BitVec) -> usize {
        debug_assert_eq!(self.bits, other.bits);
        xor_count_words(&self.words, &other.words)
    }

    /// Union size `|u ∨ v|`.
    #[inline]
    pub fn or_count(&self, other: &BitVec) -> usize {
        debug_assert_eq!(self.bits, other.bits);
        or_count_words(&self.words, &other.words)
    }

    /// In-place OR (sketch merging in the coordinator).
    pub fn or_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.bits, other.bits);
        for (a, b) in self.words.iter_mut().zip(other.words.iter()) {
            *a |= b;
        }
    }

    /// Iterate set-bit positions in increasing order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let tz = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + tz)
                }
            })
        })
    }

    /// Memory in bytes (paper's space-saving argument; Section 1 point (i)).
    pub fn memory_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Hamming weight of a word slice, via the active dispatch arm.
#[inline]
pub fn popcount_words(words: &[u64]) -> usize {
    (kernels::active().popcount)(words)
}

/// `|a ∧ b|` over raw word slices. Panics on length mismatch.
#[inline]
pub fn and_count_words(a: &[u64], b: &[u64]) -> usize {
    (kernels::active().and_count)(a, b)
}

/// `|a ⊕ b|` over raw word slices. Panics on length mismatch.
#[inline]
pub fn xor_count_words(a: &[u64], b: &[u64]) -> usize {
    (kernels::active().xor_count)(a, b)
}

/// `|a ∨ b|` over raw word slices. Panics on length mismatch.
#[inline]
pub fn or_count_words(a: &[u64], b: &[u64]) -> usize {
    (kernels::active().or_count)(a, b)
}

/// `|a ∧ b|` — historical 8-way-unrolled spelling, kept so PR-4-era call
/// sites keep compiling. Since the dispatch-table redesign both
/// spellings route to the same arm (which is at least 8-words-wide on
/// every ISA), so this is exactly [`and_count_words`]. Panics on length
/// mismatch.
#[inline]
pub fn and_count_words8(a: &[u64], b: &[u64]) -> usize {
    and_count_words(a, b)
}

/// `|a ⊕ b|` — historical 8-way-unrolled spelling; see
/// [`and_count_words8`]. Exactly [`xor_count_words`]. Panics on length
/// mismatch.
#[inline]
pub fn xor_count_words8(a: &[u64], b: &[u64]) -> usize {
    xor_count_words(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_bitvec(rng: &mut Xoshiro256, bits: usize, p: f64) -> BitVec {
        let mut v = BitVec::zeros(bits);
        for i in 0..bits {
            if rng.bernoulli(p) {
                v.set(i);
            }
        }
        v
    }

    #[test]
    fn set_get_clear() {
        let mut v = BitVec::zeros(130);
        assert!(!v.get(129));
        v.set(129);
        v.set(0);
        v.set(64);
        assert!(v.get(129) && v.get(0) && v.get(64));
        assert_eq!(v.count_ones(), 3);
        v.clear(64);
        assert!(!v.get(64));
        assert_eq!(v.count_ones(), 2);
    }

    #[test]
    fn pairwise_ops_match_naive() {
        let mut rng = Xoshiro256::new(4);
        for bits in [1usize, 63, 64, 65, 200, 1000, 1024] {
            let a = random_bitvec(&mut rng, bits, 0.3);
            let b = random_bitvec(&mut rng, bits, 0.3);
            let mut and_n = 0;
            let mut xor_n = 0;
            let mut or_n = 0;
            for i in 0..bits {
                let (x, y) = (a.get(i), b.get(i));
                and_n += (x && y) as usize;
                xor_n += (x != y) as usize;
                or_n += (x || y) as usize;
            }
            assert_eq!(a.and_count(&b), and_n, "bits={}", bits);
            assert_eq!(a.xor_count(&b), xor_n, "bits={}", bits);
            assert_eq!(a.or_count(&b), or_n, "bits={}", bits);
            // identity: |u| + |v| = |u∧v| + |u∨v|
            assert_eq!(a.count_ones() + b.count_ones(), and_n + or_n);
        }
    }

    #[test]
    fn iter_ones_matches_get() {
        let mut rng = Xoshiro256::new(8);
        let v = random_bitvec(&mut rng, 300, 0.2);
        let ones: Vec<usize> = v.iter_ones().collect();
        let expect: Vec<usize> = (0..300).filter(|&i| v.get(i)).collect();
        assert_eq!(ones, expect);
    }

    #[test]
    fn from_indices_and_bytes() {
        let v = BitVec::from_indices(10, [1, 3, 3, 9]);
        assert_eq!(v.count_ones(), 3);
        let w = BitVec::from_bytes01(&[0, 1, 0, 1, 0, 0, 0, 0, 0, 1]);
        assert_eq!(v, w);
    }

    #[test]
    fn f32_roundtrip() {
        let v = BitVec::from_indices(70, [0, 5, 69]);
        let f = v.to_f32s();
        assert_eq!(f.len(), 70);
        assert_eq!(BitVec::from_f32s(&f), v);
    }

    #[test]
    fn or_assign_merges() {
        let mut a = BitVec::from_indices(100, [1, 2]);
        let b = BitVec::from_indices(100, [2, 99]);
        a.or_assign(&b);
        assert_eq!(a, BitVec::from_indices(100, [1, 2, 99]));
    }

    #[test]
    fn word_kernels_match_methods() {
        let mut rng = Xoshiro256::new(9);
        let a = random_bitvec(&mut rng, 500, 0.3);
        let b = random_bitvec(&mut rng, 500, 0.3);
        assert_eq!(popcount_words(a.words()), a.count_ones());
        assert_eq!(and_count_words(a.words(), b.words()), a.and_count(&b));
        assert_eq!(xor_count_words(a.words(), b.words()), a.xor_count(&b));
        assert_eq!(or_count_words(a.words(), b.words()), a.or_count(&b));
    }

    #[test]
    fn dispatched_kernels_match_scalar_oracle_exactly() {
        // Word counts straddling every unroll/vector boundary, including
        // the ragged tails (1..7 trailing words) and the empty slice.
        // Whatever arm the dispatch table picked on this machine must be
        // bit-identical to the scalar oracle (the deep multi-arm property
        // test lives in tests/prop_kernels.rs).
        let mut rng = Xoshiro256::new(11);
        for bits in [1usize, 63, 64, 65, 7 * 64, 8 * 64, 9 * 64, 511, 513, 1000, 1024] {
            let a = random_bitvec(&mut rng, bits, 0.4);
            let b = random_bitvec(&mut rng, bits, 0.4);
            let (aw, bw) = (a.words(), b.words());
            assert_eq!(popcount_words(aw), kernels::scalar::popcount_words(aw), "bits={bits}");
            assert_eq!(
                and_count_words(aw, bw),
                kernels::scalar::and_count_words(aw, bw),
                "bits={bits}"
            );
            assert_eq!(
                xor_count_words(aw, bw),
                kernels::scalar::xor_count_words(aw, bw),
                "bits={bits}"
            );
            assert_eq!(
                or_count_words(aw, bw),
                kernels::scalar::or_count_words(aw, bw),
                "bits={bits}"
            );
            // The historical 8-way spellings are the same dispatch arm.
            assert_eq!(and_count_words8(aw, bw), and_count_words(aw, bw), "bits={bits}");
            assert_eq!(xor_count_words8(aw, bw), xor_count_words(aw, bw), "bits={bits}");
        }
        assert_eq!(and_count_words8(&[], &[]), 0);
        assert_eq!(xor_count_words8(&[], &[]), 0);
    }

    #[test]
    #[should_panic(expected = "word-length mismatch")]
    fn and_count8_rejects_mismatched_dims() {
        let _ = and_count_words8(&[0u64; 2], &[0u64; 3]);
    }

    #[test]
    #[should_panic(expected = "word-length mismatch")]
    fn and_count_rejects_mismatched_dims() {
        // 64 bits = 1 word vs 128 bits = 2 words: must panic, not truncate.
        let a = BitVec::from_indices(64, [0, 5]);
        let b = BitVec::from_indices(128, [0, 5, 100]);
        let _ = a.and_count(&b);
    }

    #[test]
    #[should_panic(expected = "word-length mismatch")]
    fn xor_count_rejects_mismatched_dims() {
        let a = BitVec::zeros(64);
        let b = BitVec::zeros(256);
        let _ = a.xor_count(&b);
    }

    #[test]
    #[should_panic(expected = "word-length mismatch")]
    fn or_count_rejects_mismatched_dims() {
        let a = BitVec::zeros(192);
        let b = BitVec::zeros(64);
        let _ = a.or_count(&b);
    }

    #[test]
    fn from_words_roundtrip() {
        let v = BitVec::from_indices(130, [0, 64, 129]);
        let w = BitVec::from_words(130, v.words().to_vec());
        assert_eq!(v, w);
    }

    #[test]
    fn memory_is_packed() {
        // 1000 bits → 16 words → 128 bytes, vs 4000 bytes for f32 (the
        // paper's 32× space argument).
        let v = BitVec::zeros(1000);
        assert_eq!(v.memory_bytes(), 128);
    }
}
