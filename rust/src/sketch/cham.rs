//! **Cham** — estimating the original categorical Hamming distance from two
//! Cabin sketches (Algorithm 2): `Cham(ũ,ṽ) = 2·BinHamming(ũ,ṽ)`.
//!
//! ## The two BinHamming variants
//!
//! The printed Algorithm 2 box gives
//! `h̃ = (1/ln D)·(D^{|ũ|} + D^{|ṽ|} + ⟨ũ,ṽ⟩/d − 1)` with `D = 1 − 1/d`,
//! which is a garbled transcription (see DESIGN.md §1): with `â` denoting
//! the occupancy inversion `ln(1−|ũ|/d)/ln D`, the identity
//! `D^â = 1 − |ũ|/d` shows the printed inner expression equals
//! `1 − |ũ∨ṽ|/d`, i.e. the quantity whose log yields the union-size
//! estimate — the box dropped the inversions. We therefore implement:
//!
//! * [`Estimator::OccupancyInversion`] (canonical): invert three
//!   balls-in-bins occupancies,
//!   `ĥ = 2·est(|ũ∨ṽ|) − est(|ũ|) − est(|ṽ|)` where
//!   `est(x) = ln(1−x/d)/ln(1−1/d)`. This is the estimator BinSketch's own
//!   analysis (paper's Lemma 3 ← [33, Appendix B]) concentrates.
//! * [`Estimator::PaperLiteral`]: the formula exactly as printed. Accurate
//!   only when `|ũ| ≪ d` (first-order regime); kept for the ablation
//!   (`repro ablation-estimator`) and fidelity.
//!
//! Besides Hamming, BinSketch sketches support inner-product / cosine /
//! Jaccard estimation of the *binary* BinEm embeddings; those estimators
//! are provided too (the paper cites this as a reason for choosing
//! BinSketch over alternatives).

use super::bitvec::BitVec;
use super::cabin::SketchConfig;

/// Which BinHamming formula to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Estimator {
    /// Occupancy-inversion (canonical; matches BinSketch's analysis).
    OccupancyInversion,
    /// The Algorithm-2 box exactly as printed in the paper.
    PaperLiteral,
}

/// Invert the expected bin occupancy: the number of balls `a` that makes
/// `E[occupied] = d(1 − D^a)` equal `occ`. Saturation (`occ ≥ d`) clamps to
/// the max invertible occupancy (d-1 bins ⇒ finite estimate).
#[inline]
pub fn invert_occupancy(occ: f64, d: usize) -> f64 {
    let df = d as f64;
    let ln_d_ratio = (1.0 - 1.0 / df).ln(); // ln D < 0
    let occ = occ.min(df - 1.0).max(0.0);
    (1.0 - occ / df).ln() / ln_d_ratio
}

/// BinHamming via occupancy inversion: estimates `HD(u',v')` of the binary
/// pre-images from sketches `ũ,ṽ`.
pub fn binhamming_occupancy(su: &BitVec, sv: &BitVec) -> f64 {
    let d = su.len();
    debug_assert_eq!(d, sv.len());
    let wu = su.count_ones() as f64;
    let wv = sv.count_ones() as f64;
    let ip = su.and_count(sv) as f64;
    let union = wu + wv - ip;
    let a_hat = invert_occupancy(wu, d);
    let b_hat = invert_occupancy(wv, d);
    let u_hat = invert_occupancy(union, d);
    (2.0 * u_hat - a_hat - b_hat).max(0.0)
}

/// BinHamming exactly as printed in Algorithm 2 of the paper.
pub fn binhamming_literal(su: &BitVec, sv: &BitVec) -> f64 {
    let d = su.len() as f64;
    let big_d = 1.0 - 1.0 / d;
    let wu = su.count_ones() as f64;
    let wv = sv.count_ones() as f64;
    let ip = su.and_count(sv) as f64;
    let inner = big_d.powf(wu) + big_d.powf(wv) + ip / d - 1.0;
    // ln D < 0; for disjoint sparse sketches inner < 1 ⇒ positive estimate.
    (1.0 / big_d.ln()) * inner
}

/// `Cham(ũ,ṽ)` — the categorical Hamming-distance estimate (Algorithm 2):
/// twice the binary estimate, per Lemma 2's halving.
pub fn estimate_hamming(su: &BitVec, sv: &BitVec, cfg: &SketchConfig) -> f64 {
    2.0 * match cfg.estimator {
        Estimator::OccupancyInversion => binhamming_occupancy(su, sv),
        Estimator::PaperLiteral => binhamming_literal(su, sv),
    }
}

/// Estimated inner product `⟨u',v'⟩` of the binary BinEm embeddings.
pub fn estimate_inner_product(su: &BitVec, sv: &BitVec) -> f64 {
    let d = su.len();
    let wu = su.count_ones() as f64;
    let wv = sv.count_ones() as f64;
    let ip = su.and_count(sv) as f64;
    let a_hat = invert_occupancy(wu, d);
    let b_hat = invert_occupancy(wv, d);
    let u_hat = invert_occupancy(wu + wv - ip, d);
    (a_hat + b_hat - u_hat).max(0.0)
}

/// Estimated cosine similarity of the binary BinEm embeddings.
pub fn estimate_cosine(su: &BitVec, sv: &BitVec) -> f64 {
    let d = su.len();
    let a_hat = invert_occupancy(su.count_ones() as f64, d);
    let b_hat = invert_occupancy(sv.count_ones() as f64, d);
    if a_hat <= 0.0 || b_hat <= 0.0 {
        return 0.0;
    }
    (estimate_inner_product(su, sv) / (a_hat * b_hat).sqrt()).clamp(0.0, 1.0)
}

/// Estimated Jaccard similarity of the binary BinEm embeddings.
pub fn estimate_jaccard(su: &BitVec, sv: &BitVec) -> f64 {
    let d = su.len();
    let wu = su.count_ones() as f64;
    let wv = sv.count_ones() as f64;
    let ip = su.and_count(sv) as f64;
    let union_hat = invert_occupancy(wu + wv - ip, d);
    if union_hat <= 0.0 {
        return 0.0;
    }
    (estimate_inner_product(su, sv) / union_hat).clamp(0.0, 1.0)
}

/// Scalar form of the estimator used by the L1/L2 kernels: given row
/// weights and the gram entry over an f32 0/1 sketch matrix. This is the
/// exact function `python/compile/kernels/cham.py` computes; the rust
/// runtime tests pin both against [`binhamming_occupancy`].
#[inline]
pub fn binhamming_from_stats(wu: f64, wv: f64, ip: f64, d: usize) -> f64 {
    let a_hat = invert_occupancy(wu, d);
    let b_hat = invert_occupancy(wv, d);
    let u_hat = invert_occupancy(wu + wv - ip, d);
    (2.0 * u_hat - a_hat - b_hat).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::binsketch::BinSketch;
    use crate::util::rng::Xoshiro256;

    fn random_binary(rng: &mut Xoshiro256, n: usize, ones: usize) -> BitVec {
        BitVec::from_indices(n, rng.sample_indices(n, ones))
    }

    #[test]
    fn occupancy_inversion_inverts() {
        // est(E[occ(a)]) == a for the expected occupancy curve.
        for d in [128usize, 1000] {
            for a in [0usize, 1, 10, 50, 100] {
                let df = d as f64;
                let occ = df * (1.0 - (1.0 - 1.0 / df).powi(a as i32));
                let back = invert_occupancy(occ, d);
                assert!((back - a as f64).abs() < 1e-6, "d={} a={} back={}", d, a, back);
            }
        }
    }

    #[test]
    fn saturation_is_finite() {
        let d = 64;
        let v = invert_occupancy(64.0, d);
        assert!(v.is_finite() && v > 0.0);
        assert!(invert_occupancy(-3.0, d) == 0.0);
    }

    #[test]
    fn binhamming_accurate_on_sparse_inputs() {
        // End-to-end over BinSketch: estimate HD(u',v') within Theorem-2-ish
        // additive error.
        let mut rng = Xoshiro256::new(20);
        let n = 20_000;
        let s = 300; // density
        let d = 4096;
        for trial in 0..5u64 {
            let u = random_binary(&mut rng, n, s);
            let v = random_binary(&mut rng, n, s);
            let truth = u.xor_count(&v) as f64;
            let bs = BinSketch::new(n, d, 100 + trial);
            let est = binhamming_occupancy(&bs.compress(&u), &bs.compress(&v));
            let tol = 11.0 * (s as f64 * (6.0f64 / 0.01).ln()).sqrt(); // Thm 2 at δ=0.01
            assert!(
                (est - truth).abs() < tol,
                "trial {}: est {} truth {} tol {}",
                trial,
                est,
                truth,
                tol
            );
        }
    }

    #[test]
    fn identical_sketches_estimate_zero() {
        let mut rng = Xoshiro256::new(21);
        let u = random_binary(&mut rng, 1000, 80);
        let bs = BinSketch::new(1000, 256, 5);
        let s = bs.compress(&u);
        assert_eq!(binhamming_occupancy(&s, &s), 0.0);
        assert!(estimate_jaccard(&s, &s) > 0.99);
        assert!(estimate_cosine(&s, &s) > 0.99);
    }

    #[test]
    fn literal_formula_is_garbled_but_log_restores_it() {
        // The printed Algorithm-2 box (no log) yields large *negative*
        // "distances" on sparse sketches — it cannot be what the authors
        // ran. Restoring the dropped log turns the inner expression into
        // the union-occupancy estimate (ablation A1's finding).
        let mut rng = Xoshiro256::new(22);
        let u = random_binary(&mut rng, 50_000, 40);
        let v = random_binary(&mut rng, 50_000, 40);
        let bs = BinSketch::new(50_000, 8192, 9);
        let (su, sv) = (bs.compress(&u), bs.compress(&v));
        let truth = u.xor_count(&v) as f64;
        let occ = binhamming_occupancy(&su, &sv);
        let lit = binhamming_literal(&su, &sv);
        assert!(lit < 0.0, "printed formula should be nonsensical: {}", lit);
        assert!((occ - truth).abs() < 0.2 * truth + 10.0, "occ {} truth {}", occ, truth);
        // log-restored inner expression = union-size estimate
        let d = 8192f64;
        let inner = 1.0 - (su.or_count(&sv) as f64) / d;
        let union_est = inner.ln() / (1.0 - 1.0 / d).ln();
        let union_truth = u.or_count(&v) as f64;
        assert!(
            (union_est - union_truth).abs() < 0.15 * union_truth,
            "union est {} truth {}",
            union_est,
            union_truth
        );
    }

    #[test]
    fn literal_degrades_when_dense() {
        // At |ũ| ~ d/2 the printed formula underestimates badly; the
        // inversion stays accurate. This is ablation A1's one-line summary.
        let mut rng = Xoshiro256::new(23);
        let n = 20_000;
        let d = 512;
        let u = random_binary(&mut rng, n, 400);
        let v = random_binary(&mut rng, n, 400);
        let truth = u.xor_count(&v) as f64;
        let bs = BinSketch::new(n, d, 3);
        let (su, sv) = (bs.compress(&u), bs.compress(&v));
        let occ_err = (binhamming_occupancy(&su, &sv) - truth).abs();
        let lit_err = (binhamming_literal(&su, &sv) - truth).abs();
        assert!(occ_err < lit_err, "occ_err {} lit_err {}", occ_err, lit_err);
        assert!(occ_err / truth < 0.25, "occ rel err {}", occ_err / truth);
    }

    #[test]
    fn inner_product_estimate() {
        let mut rng = Xoshiro256::new(24);
        let n = 10_000;
        // construct overlapping vectors with known ip
        let base = rng.sample_indices(n, 300);
        let u = BitVec::from_indices(n, base[..200].iter().copied());
        let v = BitVec::from_indices(n, base[100..300].iter().copied());
        let truth = u.and_count(&v) as f64; // 100
        let bs = BinSketch::new(n, 4096, 11);
        let est = estimate_inner_product(&bs.compress(&u), &bs.compress(&v));
        assert!((est - truth).abs() < 25.0, "est {} truth {}", est, truth);
    }

    #[test]
    fn stats_form_matches_bitvec_form() {
        let mut rng = Xoshiro256::new(25);
        let u = random_binary(&mut rng, 5000, 200);
        let v = random_binary(&mut rng, 5000, 200);
        let bs = BinSketch::new(5000, 1024, 13);
        let (su, sv) = (bs.compress(&u), bs.compress(&v));
        let direct = binhamming_occupancy(&su, &sv);
        let via_stats = binhamming_from_stats(
            su.count_ones() as f64,
            sv.count_ones() as f64,
            su.and_count(&sv) as f64,
            1024,
        );
        assert!((direct - via_stats).abs() < 1e-9);
    }

    #[test]
    fn estimator_symmetry() {
        let mut rng = Xoshiro256::new(26);
        let u = random_binary(&mut rng, 3000, 150);
        let v = random_binary(&mut rng, 3000, 100);
        let bs = BinSketch::new(3000, 512, 1);
        let (su, sv) = (bs.compress(&u), bs.compress(&v));
        // symmetric up to f.p. association order
        assert!(
            (binhamming_occupancy(&su, &sv) - binhamming_occupancy(&sv, &su)).abs() < 1e-9
        );
        assert!((binhamming_literal(&su, &sv) - binhamming_literal(&sv, &su)).abs() < 1e-9);
    }
}
