//! Contiguous bit-packed sketch arena.
//!
//! A [`SketchMatrix`] stores `n` sketches of `bits` bits each as one
//! row-major `u64` word arena: a single allocation per shard instead of the
//! one-heap-box-per-sketch layout of `Vec<BitVec>`. Scans borrow rows as
//! `&[u64]` views ([`SketchMatrix::row`]) and feed them straight into the
//! word-slice popcount kernels in [`crate::sketch::bitvec`], so the query
//! hot path never clones a sketch or chases a per-sketch pointer — this is
//! the layout that lets the coordinator's top-k scan run at the
//! word-parallel popcount speed the paper's Section 1 argues for.
//!
//! Each row's Hamming weight is cached at insertion time (`weights`): the
//! Cham estimator needs `|ṽ|` for every candidate, and recomputing it per
//! query per candidate would double the popcount work of a scan.

use super::bitvec::{popcount_words, BitVec};
use super::kernels;

/// Row-major arena of fixed-width packed bit rows with cached row weights.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SketchMatrix {
    bits: usize,
    words_per_row: usize,
    words: Vec<u64>,
    weights: Vec<u32>,
}

impl SketchMatrix {
    /// Empty arena for `bits`-bit sketches.
    pub fn new(bits: usize) -> Self {
        Self::with_row_capacity(bits, 0)
    }

    /// Empty arena with space reserved for `rows` sketches.
    pub fn with_row_capacity(bits: usize, rows: usize) -> Self {
        let words_per_row = bits.div_ceil(64);
        Self {
            bits,
            words_per_row,
            words: Vec::with_capacity(words_per_row * rows),
            weights: Vec::with_capacity(rows),
        }
    }

    /// Pack a slice of sketches into one arena (analysis / all-pairs paths).
    /// All sketches must share a dimension.
    pub fn from_sketches(sketches: &[BitVec]) -> Self {
        let bits = sketches.first().map(|s| s.len()).unwrap_or(0);
        let mut m = Self::with_row_capacity(bits, sketches.len());
        for s in sketches {
            m.push(s);
        }
        m
    }

    /// Sketch dimension in bits.
    #[inline]
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Words per row (`bits.div_ceil(64)`).
    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Number of rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Append a sketch as a new row. Panics on dimension mismatch — the
    /// same hard-error policy as the word kernels.
    pub fn push(&mut self, sketch: &BitVec) {
        assert_eq!(
            sketch.len(),
            self.bits,
            "sketch dim {} does not match arena dim {}",
            sketch.len(),
            self.bits
        );
        self.words.extend_from_slice(sketch.words());
        self.weights.push(popcount_words(sketch.words()) as u32);
    }

    /// Append a row directly from a packed word slice with its
    /// precomputed weight (arena-to-arena copies, e.g. store snapshots —
    /// skips the `BitVec` round-trip and the popcount). The caller
    /// guarantees `weight` is the slice's true Hamming weight and the tail
    /// bits beyond `bits` are zero.
    pub fn push_row(&mut self, words: &[u64], weight: u32) {
        assert_eq!(
            words.len(),
            self.words_per_row,
            "row has {} words, arena rows have {}",
            words.len(),
            self.words_per_row
        );
        self.words.extend_from_slice(words);
        self.weights.push(weight);
    }

    /// Borrowed word view of row `i` — the zero-copy scan path.
    #[inline]
    pub fn row(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Cached Hamming weight of row `i`.
    #[inline]
    pub fn weight(&self, i: usize) -> usize {
        self.weights[i] as usize
    }

    /// Copy row `i` back out as an owned [`BitVec`] (lookup responses).
    pub fn row_bitvec(&self, i: usize) -> BitVec {
        BitVec::from_words(self.bits, self.row(i).to_vec())
    }

    /// Iterate rows as borrowed word slices.
    pub fn rows(&self) -> impl Iterator<Item = &[u64]> + '_ {
        (0..self.len()).map(move |i| self.row(i))
    }

    /// Move this arena's last row to the end of `dst` (shard rebalancing:
    /// no per-row allocation). Returns `false` when empty.
    pub fn move_last_row_to(&mut self, dst: &mut SketchMatrix) -> bool {
        assert_eq!(
            self.bits, dst.bits,
            "cannot move a {}-bit row into a {}-bit arena",
            self.bits, dst.bits
        );
        let Some(w) = self.weights.pop() else {
            return false;
        };
        let offset = self.words.len() - self.words_per_row;
        dst.words.extend_from_slice(&self.words[offset..]);
        self.words.truncate(offset);
        dst.weights.push(w);
        true
    }

    /// Drop the arena's trailing row (WAL `MoveOut` replay — the
    /// recovery-side mirror of [`SketchMatrix::move_last_row_to`] when the
    /// destination shard replays its own log). Returns `false` when empty.
    pub fn pop_row(&mut self) -> bool {
        if self.weights.pop().is_none() {
            return false;
        }
        self.words.truncate(self.words.len() - self.words_per_row);
        true
    }

    /// Remove row `i` by moving the last row into its slot — the arena
    /// delete primitive. O(words_per_row), order of the surviving rows is
    /// unchanged except that the former last row now lives at `i` (the
    /// caller mirrors the same swap into its id and index structures).
    /// Panics if `i` is out of bounds.
    pub fn swap_remove_row(&mut self, i: usize) {
        let last = self.len() - 1;
        assert!(i <= last, "row {i} out of bounds for {} rows", last + 1);
        if i != last {
            let (head, tail) = self.words.split_at_mut(last * self.words_per_row);
            head[i * self.words_per_row..(i + 1) * self.words_per_row]
                .copy_from_slice(&tail[..self.words_per_row]);
        }
        self.words.truncate(last * self.words_per_row);
        self.weights.swap_remove(i);
    }

    /// Overwrite row `i` in place with a packed word slice and its
    /// precomputed weight — the arena upsert primitive. The caller
    /// guarantees `weight` is the slice's true Hamming weight and the
    /// tail bits beyond `bits` are zero. Panics on width mismatch or if
    /// `i` is out of bounds.
    pub fn overwrite_row(&mut self, i: usize, words: &[u64], weight: u32) {
        assert_eq!(
            words.len(),
            self.words_per_row,
            "row has {} words, arena rows have {}",
            words.len(),
            self.words_per_row
        );
        self.words[i * self.words_per_row..(i + 1) * self.words_per_row].copy_from_slice(words);
        self.weights[i] = weight;
    }

    /// Arena memory footprint in bytes (words + weight cache).
    pub fn memory_bytes(&self) -> usize {
        self.words.len() * 8 + self.weights.len() * 4
    }

    /// Rows per scoring tile such that one tile of this arena's rows stays
    /// within ~32 KiB (comfortably inside L1 alongside the query block).
    /// Always ≥ 8 so tiny rows still amortise the per-tile bookkeeping,
    /// and capped at 512 so the per-tile count buffer stays small.
    ///
    /// The count is rounded down to a multiple of [`Self::ROW_BLOCK`] —
    /// the natural block of every dispatch arm (8 words = two AVX2 /
    /// one AVX-512 vector loads, and the scalar 8-way unroll) — so full
    /// tiles never end mid-block and row strides stay cache-line
    /// multiples for the common 512-bit sketch.
    #[inline]
    pub fn tile_rows(&self) -> usize {
        const TILE_BYTES: usize = 32 * 1024;
        let raw = (TILE_BYTES / (self.words_per_row * 8).max(1)).clamp(8, 512);
        (raw / Self::ROW_BLOCK) * Self::ROW_BLOCK
    }

    /// Natural row block of the scoring kernels: every dispatch arm's
    /// inner loop consumes 8 words per step, and the tile loops hand the
    /// kernels whole rows — keeping tiles in multiples of 8 rows keeps
    /// the per-tile bookkeeping aligned with the unroll.
    pub const ROW_BLOCK: usize = 8;

    /// Blocked multi-query scoring: `|q ∧ row|` for every query in
    /// `queries` against every arena row in `[row_start, row_end)`,
    /// written to `out[qi * tile_len + i]` where `i` indexes rows within
    /// the tile and `tile_len = row_end - row_start`.
    ///
    /// Row-major over the tile with the queries replayed per row: each row
    /// is pulled into cache once and scored against all Q queries through
    /// the active dispatch arm ([`crate::sketch::kernels::active`] —
    /// AVX2/AVX-512/NEON when the CPU has them, the 8-way scalar unroll
    /// otherwise), instead of Q independent passes each streaming the
    /// whole arena. Bit-for-bit identical to calling
    /// [`crate::sketch::bitvec::and_count_words`] per (query, row) pair —
    /// integer popcounts, no reassociation concerns.
    ///
    /// Panics if any query's word length differs from this arena's row
    /// width, or if `out` is not exactly `queries.len() * tile_len`.
    pub fn tile_and_counts(
        &self,
        queries: &[&[u64]],
        row_start: usize,
        row_end: usize,
        out: &mut [usize],
    ) {
        self.tile_counts(queries, row_start, row_end, out, kernels::active().and_count)
    }

    /// Blocked multi-query Hamming kernel: as [`SketchMatrix::tile_and_counts`]
    /// but computing `|q ⊕ row|` — the raw Hamming-distance counterpart,
    /// identical to the scalar [`crate::sketch::bitvec::xor_count_words`].
    pub fn tile_xor_counts(
        &self,
        queries: &[&[u64]],
        row_start: usize,
        row_end: usize,
        out: &mut [usize],
    ) {
        self.tile_counts(queries, row_start, row_end, out, kernels::active().xor_count)
    }

    #[inline]
    fn tile_counts(
        &self,
        queries: &[&[u64]],
        row_start: usize,
        row_end: usize,
        out: &mut [usize],
        kernel: fn(&[u64], &[u64]) -> usize,
    ) {
        assert!(
            row_start <= row_end && row_end <= self.len(),
            "tile [{row_start}, {row_end}) out of bounds for {} rows",
            self.len()
        );
        let tile_len = row_end - row_start;
        assert_eq!(
            out.len(),
            queries.len() * tile_len,
            "count buffer holds {} slots, tile needs {} queries x {} rows",
            out.len(),
            queries.len(),
            tile_len
        );
        for i in 0..tile_len {
            let row = self.row(row_start + i);
            for (qi, q) in queries.iter().enumerate() {
                out[qi * tile_len + i] = kernel(q, row);
            }
        }
    }

    /// Gathered single-query scoring: `|q ∧ row|` for each (possibly
    /// non-contiguous) arena row in `rows` — the indexed-rerank shape,
    /// sharing the same dispatch arm as the contiguous tiles so the
    /// rerank and full-scan paths cannot drift. Panics if `out` is not
    /// exactly `rows.len()`.
    pub fn gather_and_counts(&self, query: &[u64], rows: &[u32], out: &mut [usize]) {
        assert_eq!(
            out.len(),
            rows.len(),
            "count buffer holds {} slots for {} gathered rows",
            out.len(),
            rows.len()
        );
        let and_count = kernels::active().and_count;
        for (slot, &r) in out.iter_mut().zip(rows) {
            *slot = and_count(query, self.row(r as usize));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::bitvec::and_count_words;
    use crate::util::rng::Xoshiro256;

    fn sk(rng: &mut Xoshiro256, d: usize, ones: usize) -> BitVec {
        BitVec::from_indices(d, rng.sample_indices(d, ones))
    }

    #[test]
    fn push_row_roundtrip() {
        let mut rng = Xoshiro256::new(1);
        let d = 200;
        let sketches: Vec<BitVec> = (0..17).map(|_| sk(&mut rng, d, 30)).collect();
        let m = SketchMatrix::from_sketches(&sketches);
        assert_eq!(m.len(), 17);
        assert_eq!(m.bits(), d);
        assert_eq!(m.words_per_row(), d.div_ceil(64));
        for (i, s) in sketches.iter().enumerate() {
            assert_eq!(m.row(i), s.words(), "row {i}");
            assert_eq!(m.weight(i), s.count_ones(), "weight {i}");
            assert_eq!(m.row_bitvec(i), *s, "bitvec {i}");
        }
    }

    #[test]
    fn row_kernels_match_bitvec_ops() {
        let mut rng = Xoshiro256::new(2);
        let d = 130; // non-multiple of 64: exercises the tail word
        let sketches: Vec<BitVec> = (0..6).map(|_| sk(&mut rng, d, 25)).collect();
        let m = SketchMatrix::from_sketches(&sketches);
        for i in 0..m.len() {
            for j in 0..m.len() {
                assert_eq!(
                    and_count_words(m.row(i), m.row(j)),
                    sketches[i].and_count(&sketches[j])
                );
            }
        }
    }

    #[test]
    fn rows_iterator_visits_all() {
        let mut rng = Xoshiro256::new(3);
        let sketches: Vec<BitVec> = (0..5).map(|_| sk(&mut rng, 64, 10)).collect();
        let m = SketchMatrix::from_sketches(&sketches);
        let collected: Vec<&[u64]> = m.rows().collect();
        assert_eq!(collected.len(), 5);
        for (r, s) in collected.iter().zip(&sketches) {
            assert_eq!(*r, s.words());
        }
    }

    #[test]
    fn move_last_row_transfers_words_and_weight() {
        let mut rng = Xoshiro256::new(4);
        let d = 96;
        let a_rows: Vec<BitVec> = (0..4).map(|_| sk(&mut rng, d, 20)).collect();
        let mut a = SketchMatrix::from_sketches(&a_rows);
        let mut b = SketchMatrix::new(d);
        assert!(a.move_last_row_to(&mut b));
        assert!(a.move_last_row_to(&mut b));
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 2);
        // moved in pop order: b holds rows 3 then 2
        assert_eq!(b.row_bitvec(0), a_rows[3]);
        assert_eq!(b.row_bitvec(1), a_rows[2]);
        assert_eq!(b.weight(0), a_rows[3].count_ones());
        // survivors untouched
        assert_eq!(a.row_bitvec(0), a_rows[0]);
        assert_eq!(a.row_bitvec(1), a_rows[1]);
        // drain to empty, then refuse
        assert!(a.move_last_row_to(&mut b));
        assert!(a.move_last_row_to(&mut b));
        assert!(!a.move_last_row_to(&mut b));
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn pop_row_is_the_inverse_of_push() {
        let mut rng = Xoshiro256::new(9);
        let d = 96;
        let rows: Vec<BitVec> = (0..3).map(|_| sk(&mut rng, d, 20)).collect();
        let mut m = SketchMatrix::from_sketches(&rows);
        assert!(m.pop_row());
        assert_eq!(m.len(), 2);
        assert_eq!(m.row_bitvec(1), rows[1]);
        assert_eq!(m.memory_bytes(), 2 * (2 * 8 + 4));
        assert!(m.pop_row());
        assert!(m.pop_row());
        assert!(!m.pop_row());
        assert!(m.is_empty());
    }

    #[test]
    fn swap_remove_row_mirrors_vec_swap_remove() {
        let mut rng = Xoshiro256::new(12);
        let d = 130; // ragged tail word
        let rows: Vec<BitVec> = (0..9).map(|_| sk(&mut rng, d, 25)).collect();
        let mut m = SketchMatrix::from_sketches(&rows);
        let mut model = rows.clone();
        // interior, head, and tail removals, interleaved
        for i in [3usize, 0, 6, 5, 0] {
            m.swap_remove_row(i);
            model.swap_remove(i);
            assert_eq!(m.len(), model.len());
            for (r, s) in model.iter().enumerate() {
                assert_eq!(m.row_bitvec(r), *s, "row {r} after removing {i}");
                assert_eq!(m.weight(r), s.count_ones());
            }
        }
        // drain to empty via the last-row path
        while !m.is_empty() {
            m.swap_remove_row(m.len() - 1);
            model.pop();
        }
        assert_eq!(m.memory_bytes(), 0);
    }

    #[test]
    fn overwrite_row_replaces_words_and_weight() {
        let mut rng = Xoshiro256::new(13);
        let d = 200;
        let rows: Vec<BitVec> = (0..4).map(|_| sk(&mut rng, d, 30)).collect();
        let mut m = SketchMatrix::from_sketches(&rows);
        let fresh = sk(&mut rng, d, 45);
        m.overwrite_row(2, fresh.words(), fresh.count_ones() as u32);
        assert_eq!(m.row_bitvec(2), fresh);
        assert_eq!(m.weight(2), fresh.count_ones());
        // neighbours untouched
        assert_eq!(m.row_bitvec(1), rows[1]);
        assert_eq!(m.row_bitvec(3), rows[3]);
        assert_eq!(m.len(), 4);
    }

    #[test]
    #[should_panic(expected = "arena rows have")]
    fn overwrite_row_rejects_wrong_width() {
        let mut m = SketchMatrix::from_sketches(&[BitVec::zeros(128)]);
        m.overwrite_row(0, &[0u64], 0);
    }

    #[test]
    fn push_row_matches_push() {
        let mut rng = Xoshiro256::new(8);
        let d = 200;
        let s = sk(&mut rng, d, 30);
        let mut a = SketchMatrix::new(d);
        a.push(&s);
        let mut b = SketchMatrix::new(d);
        b.push_row(a.row(0), a.weight(0) as u32);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "arena rows have")]
    fn push_row_rejects_wrong_width() {
        let mut m = SketchMatrix::new(128);
        m.push_row(&[0u64], 0);
    }

    #[test]
    #[should_panic(expected = "does not match arena dim")]
    fn push_rejects_wrong_dimension() {
        let mut m = SketchMatrix::new(128);
        m.push(&BitVec::zeros(64));
    }

    #[test]
    fn tile_kernels_match_scalar_pairwise() {
        use crate::sketch::bitvec::{and_count_words, xor_count_words};
        let mut rng = Xoshiro256::new(10);
        let d = 130; // ragged tail word
        let sketches: Vec<BitVec> = (0..23).map(|_| sk(&mut rng, d, 30)).collect();
        let m = SketchMatrix::from_sketches(&sketches);
        let queries: Vec<BitVec> = (0..5).map(|_| sk(&mut rng, d, 25)).collect();
        let qwords: Vec<&[u64]> = queries.iter().map(|q| q.words()).collect();
        // ragged final tile: 23 rows in tiles of 10
        for start in (0..m.len()).step_by(10) {
            let end = (start + 10).min(m.len());
            let n = end - start;
            let mut and_out = vec![0usize; qwords.len() * n];
            let mut xor_out = vec![0usize; qwords.len() * n];
            m.tile_and_counts(&qwords, start, end, &mut and_out);
            m.tile_xor_counts(&qwords, start, end, &mut xor_out);
            for (qi, q) in queries.iter().enumerate() {
                for i in 0..n {
                    assert_eq!(
                        and_out[qi * n + i],
                        and_count_words(q.words(), m.row(start + i)),
                        "and q{qi} row{}",
                        start + i
                    );
                    assert_eq!(
                        xor_out[qi * n + i],
                        xor_count_words(q.words(), m.row(start + i)),
                        "xor q{qi} row{}",
                        start + i
                    );
                }
            }
        }
    }

    #[test]
    fn gather_counts_match_scalar() {
        use crate::sketch::bitvec::and_count_words;
        let mut rng = Xoshiro256::new(11);
        let d = 200;
        let sketches: Vec<BitVec> = (0..12).map(|_| sk(&mut rng, d, 40)).collect();
        let m = SketchMatrix::from_sketches(&sketches);
        let q = sk(&mut rng, d, 35);
        let rows: Vec<u32> = vec![7, 0, 11, 3, 3];
        let mut out = vec![0usize; rows.len()];
        m.gather_and_counts(q.words(), &rows, &mut out);
        for (i, &r) in rows.iter().enumerate() {
            assert_eq!(out[i], and_count_words(q.words(), m.row(r as usize)));
        }
    }

    #[test]
    #[should_panic(expected = "count buffer")]
    fn tile_counts_rejects_wrong_buffer_size() {
        let m = SketchMatrix::from_sketches(&[BitVec::zeros(64), BitVec::zeros(64)]);
        let q = BitVec::zeros(64);
        let mut out = vec![0usize; 1]; // needs 2
        m.tile_and_counts(&[q.words()], 0, 2, &mut out);
    }

    #[test]
    fn tile_rows_is_bounded() {
        // tiny rows: capped at 512; huge rows: floored at 8
        assert_eq!(SketchMatrix::new(64).tile_rows(), 512);
        assert_eq!(SketchMatrix::new(1 << 20).tile_rows(), 8);
        // 1024-bit rows = 128 B → 256 rows per 32 KiB tile
        assert_eq!(SketchMatrix::new(1024).tile_rows(), 256);
    }

    #[test]
    fn empty_and_memory() {
        let m = SketchMatrix::new(1024);
        assert!(m.is_empty());
        assert_eq!(m.memory_bytes(), 0);
        let mut m2 = SketchMatrix::new(1000);
        m2.push(&BitVec::zeros(1000));
        // 16 words + one u32 weight
        assert_eq!(m2.memory_bytes(), 16 * 8 + 4);
    }
}
