//! **BinSketch** — stage 2 of Cabin (Algorithm 1, lines 14–21): OR-fold a
//! sparse binary vector into `d` bins through the random attribute mapping
//! π (Pratap–Bera–Revanuru, ICDM 2019).
//!
//! `ũ[j] = ⋁_{i : π(i)=j} u'[i]`

use super::bitvec::BitVec;
use super::mappings::derive_pi;

/// The BinSketch compressor for `n`-bit inputs to `d`-bit sketches.
#[derive(Clone, Debug)]
pub struct BinSketch {
    n: usize,
    d: usize,
    pi: Vec<u32>,
}

impl BinSketch {
    pub fn new(n: usize, d: usize, seed: u64) -> Self {
        Self {
            n,
            d,
            pi: derive_pi(seed, n, d),
        }
    }

    /// Build with an explicit π table (e.g. loaded from an AOT sidecar).
    pub fn with_pi(n: usize, d: usize, pi: Vec<u32>) -> Self {
        assert_eq!(pi.len(), n);
        assert!(pi.iter().all(|&b| (b as usize) < d));
        Self { n, d, pi }
    }

    pub fn input_dim(&self) -> usize {
        self.n
    }

    pub fn sketch_dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn pi(&self, i: usize) -> usize {
        self.pi[i] as usize
    }

    pub fn pi_table(&self) -> &[u32] {
        &self.pi
    }

    /// Compress a full binary vector.
    pub fn compress(&self, u: &BitVec) -> BitVec {
        debug_assert_eq!(u.len(), self.n);
        let mut out = BitVec::zeros(self.d);
        for i in u.iter_ones() {
            out.set(self.pi[i] as usize);
        }
        out
    }

    /// Compress from an iterator of set-bit positions (fused path — never
    /// materialises the n-bit intermediate).
    pub fn compress_ones<I: IntoIterator<Item = usize>>(&self, ones: I) -> BitVec {
        let mut out = BitVec::zeros(self.d);
        for i in ones {
            out.set(self.pi[i] as usize);
        }
        out
    }

    /// Compress into a caller-provided buffer (allocation-free hot path;
    /// the buffer is zeroed first).
    pub fn compress_ones_into<I: IntoIterator<Item = usize>>(&self, ones: I, out: &mut BitVec) {
        debug_assert_eq!(out.len(), self.d);
        out.zero_out();
        for i in ones {
            out.set(self.pi[i] as usize);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_binary(rng: &mut Xoshiro256, n: usize, ones: usize) -> BitVec {
        BitVec::from_indices(n, rng.sample_indices(n, ones))
    }

    #[test]
    fn definition_matches_naive_or() {
        let mut rng = Xoshiro256::new(1);
        let n = 500;
        let d = 64;
        let bs = BinSketch::new(n, d, 42);
        let u = random_binary(&mut rng, n, 40);
        let sk = bs.compress(&u);
        // naive: per output bin, OR over preimage
        for j in 0..d {
            let any = (0..n).any(|i| bs.pi(i) == j && u.get(i));
            assert_eq!(sk.get(j), any, "bin {}", j);
        }
    }

    #[test]
    fn sketch_weight_bounded_by_input_weight() {
        let mut rng = Xoshiro256::new(2);
        for _ in 0..20 {
            let u = random_binary(&mut rng, 1000, 100);
            let bs = BinSketch::new(1000, 256, rng.next_u64());
            assert!(bs.compress(&u).count_ones() <= u.count_ones());
        }
    }

    #[test]
    fn fused_paths_agree() {
        let mut rng = Xoshiro256::new(3);
        let u = random_binary(&mut rng, 2000, 150);
        let bs = BinSketch::new(2000, 128, 7);
        let a = bs.compress(&u);
        let b = bs.compress_ones(u.iter_ones());
        let mut c = BitVec::zeros(128);
        bs.compress_ones_into(u.iter_ones(), &mut c);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn expected_occupancy_matches_balls_in_bins() {
        // E[|ũ|] = d(1 − (1−1/d)^a) for a random π.
        let mut rng = Xoshiro256::new(4);
        let (n, d, a) = (5000usize, 200usize, 300usize);
        let u = random_binary(&mut rng, n, a);
        let trials = 300;
        let mut total = 0usize;
        for s in 0..trials {
            total += BinSketch::new(n, d, s as u64).compress(&u).count_ones();
        }
        let mean = total as f64 / trials as f64;
        let expect = d as f64 * (1.0 - (1.0 - 1.0 / d as f64).powi(a as i32));
        assert!(
            (mean - expect).abs() < 0.02 * expect,
            "mean {} expect {}",
            mean,
            expect
        );
    }

    #[test]
    fn with_pi_validates() {
        let bs = BinSketch::with_pi(4, 2, vec![0, 1, 1, 0]);
        let u = BitVec::from_indices(4, [1]);
        assert_eq!(bs.compress(&u), BitVec::from_indices(2, [1]));
    }

    #[test]
    #[should_panic]
    fn with_pi_rejects_out_of_range() {
        BinSketch::with_pi(2, 2, vec![0, 5]);
    }
}
