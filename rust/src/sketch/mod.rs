//! The paper's contribution: **Cabin** (categorical → binary sketch) and
//! **Cham** (Hamming-distance estimation from sketches), built on
//! **BinEm** (random binary encoding, Lemma 1–2) and **BinSketch**
//! (Pratap–Bera–Revanuru ICDM'19).
//!
//! Pipeline (Algorithm 1 of the paper):
//!
//! ```text
//!   u ∈ {0,…,c}^n  --BinEm(ψ)-->  u' ∈ {0,1}^n  --BinSketch(π)-->  ũ ∈ {0,1}^d
//! ```
//!
//! and estimation (Algorithm 2): `Cham(ũ,ṽ) = 2·BinHamming(ũ,ṽ)`.
//!
//! The native implementation fuses both stages into a single pass over the
//! nonzeros of `u` (`CabinSketcher::sketch`), which is the coordinator's
//! CPU hot path; the JAX/Pallas AOT path (see `runtime`) computes the same
//! function as a masked matmul and is bit-identical because ψ and π are
//! derived from the same splitmix64 streams (see `mappings`).

pub mod binem;
pub mod binsketch;
pub mod bitvec;
pub mod cabin;
pub mod cham;
pub mod kernels;
pub mod mappings;
pub mod matrix;

pub use binem::{BinEm, PsiMode};
pub use binsketch::BinSketch;
pub use bitvec::BitVec;
pub use cabin::{CabinSketcher, SketchConfig};
pub use cham::{Estimator, estimate_hamming};
pub use kernels::{Isa, Kernels};
pub use matrix::SketchMatrix;

/// Recommended sketch dimension from Theorem 2: `d = s·sqrt((s/2)·ln(6/δ))`
/// where `s` is an upper bound on vector density and `δ` the error
/// probability. The paper observes (and we confirm — see EXPERIMENTS.md F3)
/// that far smaller `d` works in practice.
pub fn recommended_dim(density_bound: usize, delta: f64) -> usize {
    let s = density_bound as f64;
    let d = s * (s / 2.0 * (6.0 / delta).ln()).sqrt();
    (d.ceil() as usize).max(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recommended_dim_scales_with_density() {
        let d1 = recommended_dim(100, 0.1);
        let d2 = recommended_dim(400, 0.1);
        // d ∝ s^{3/2}: quadrupling s multiplies d by 8
        let ratio = d2 as f64 / d1 as f64;
        assert!((ratio - 8.0).abs() < 0.1, "ratio {}", ratio);
    }

    #[test]
    fn recommended_dim_reasonable_values() {
        // s=457 (KOS density), δ=0.1 → ~ 457·sqrt(228.5·4.09) ≈ 13_900
        let d = recommended_dim(457, 0.1);
        assert!(d > 10_000 && d < 20_000, "d={}", d);
    }
}
