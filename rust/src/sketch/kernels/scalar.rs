//! Portable scalar kernels — the property-tested **oracle** every SIMD
//! backend is checked against, and the fallback arm of the dispatch table
//! on machines without usable vector extensions.
//!
//! These are the PR-4 blocked-scoring kernels, kept verbatim: a 4-way
//! unrolled family (one popcnt chain per accumulator; what single-pair
//! estimator calls used) and an 8-way family (the per-row inner step of
//! the arena tile kernels in [`crate::sketch::matrix`]). Both unrolls are
//! exactly equal on every input — integer popcounts commute with any
//! unroll order — so either may serve as the reference; the property
//! tests in `tests/prop_kernels.rs` pin every dispatch arm to the 4-way
//! functions here.
//!
//! Operand word-length mismatches are a hard error in every build
//! profile: truncating to the shorter slice would silently mask
//! dimension-mismatch bugs.

/// Hamming weight of a word slice (4-way unroll: lets the compiler keep
/// four popcnt chains in flight).
#[inline]
pub fn popcount_words(words: &[u64]) -> usize {
    let mut c0 = 0u64;
    let mut c1 = 0u64;
    let mut c2 = 0u64;
    let mut c3 = 0u64;
    let chunks = words.chunks_exact(4);
    let rem = chunks.remainder();
    for ch in chunks {
        c0 += ch[0].count_ones() as u64;
        c1 += ch[1].count_ones() as u64;
        c2 += ch[2].count_ones() as u64;
        c3 += ch[3].count_ones() as u64;
    }
    let mut total = c0 + c1 + c2 + c3;
    for w in rem {
        total += w.count_ones() as u64;
    }
    total as usize
}

/// `|a ∧ b|` over raw word slices, 4-way unrolled. Panics on length
/// mismatch.
#[inline]
pub fn and_count_words(a: &[u64], b: &[u64]) -> usize {
    binop_popcount(a, b, |a, b| a & b)
}

/// `|a ⊕ b|` over raw word slices, 4-way unrolled. Panics on length
/// mismatch.
#[inline]
pub fn xor_count_words(a: &[u64], b: &[u64]) -> usize {
    binop_popcount(a, b, |a, b| a ^ b)
}

/// `|a ∨ b|` over raw word slices, 4-way unrolled. Panics on length
/// mismatch.
#[inline]
pub fn or_count_words(a: &[u64], b: &[u64]) -> usize {
    binop_popcount(a, b, |a, b| a | b)
}

/// `|a ∧ b|`, 8-way unrolled — the scalar dispatch arm for the blocked
/// batch-scoring paths. Exactly equal to [`and_count_words`] on every
/// input. Panics on length mismatch.
#[inline]
pub fn and_count_words8(a: &[u64], b: &[u64]) -> usize {
    binop_popcount8(a, b, |a, b| a & b)
}

/// `|a ⊕ b|`, 8-way unrolled — see [`and_count_words8`]. Exactly equal to
/// [`xor_count_words`] on every input. Panics on length mismatch.
#[inline]
pub fn xor_count_words8(a: &[u64], b: &[u64]) -> usize {
    binop_popcount8(a, b, |a, b| a ^ b)
}

/// `|a ∨ b|`, 8-way unrolled — see [`and_count_words8`]. Exactly equal to
/// [`or_count_words`] on every input. Panics on length mismatch.
#[inline]
pub fn or_count_words8(a: &[u64], b: &[u64]) -> usize {
    binop_popcount8(a, b, |a, b| a | b)
}

#[inline]
fn binop_popcount(a: &[u64], b: &[u64], op: fn(u64, u64) -> u64) -> usize {
    // Length mismatch is a dimension bug at the call site; truncating to
    // min(len) here would return a plausible-looking count and hide it, so
    // it is a hard error in release builds too.
    super::assert_same_words(a, b);
    let n = a.len();
    let mut c0 = 0u64;
    let mut c1 = 0u64;
    let mut c2 = 0u64;
    let mut c3 = 0u64;
    let mut i = 0;
    while i + 4 <= n {
        c0 += op(a[i], b[i]).count_ones() as u64;
        c1 += op(a[i + 1], b[i + 1]).count_ones() as u64;
        c2 += op(a[i + 2], b[i + 2]).count_ones() as u64;
        c3 += op(a[i + 3], b[i + 3]).count_ones() as u64;
        i += 4;
    }
    let mut total = c0 + c1 + c2 + c3;
    while i < n {
        total += op(a[i], b[i]).count_ones() as u64;
        i += 1;
    }
    total as usize
}

#[inline]
fn binop_popcount8(a: &[u64], b: &[u64], op: fn(u64, u64) -> u64) -> usize {
    // Same hard-error policy as binop_popcount: a length mismatch is a
    // dimension bug at the call site, never a truncation.
    super::assert_same_words(a, b);
    let n = a.len();
    let mut c0 = 0u64;
    let mut c1 = 0u64;
    let mut c2 = 0u64;
    let mut c3 = 0u64;
    let mut c4 = 0u64;
    let mut c5 = 0u64;
    let mut c6 = 0u64;
    let mut c7 = 0u64;
    let mut i = 0;
    while i + 8 <= n {
        c0 += op(a[i], b[i]).count_ones() as u64;
        c1 += op(a[i + 1], b[i + 1]).count_ones() as u64;
        c2 += op(a[i + 2], b[i + 2]).count_ones() as u64;
        c3 += op(a[i + 3], b[i + 3]).count_ones() as u64;
        c4 += op(a[i + 4], b[i + 4]).count_ones() as u64;
        c5 += op(a[i + 5], b[i + 5]).count_ones() as u64;
        c6 += op(a[i + 6], b[i + 6]).count_ones() as u64;
        c7 += op(a[i + 7], b[i + 7]).count_ones() as u64;
        i += 8;
    }
    let mut total = (c0 + c1 + c2 + c3) + (c4 + c5 + c6 + c7);
    while i < n {
        total += op(a[i], b[i]).count_ones() as u64;
        i += 1;
    }
    total as usize
}
