//! AVX-512 VPOPCNTDQ kernels — native 512-bit vector popcount.
//!
//! `vpopcntq` counts each of the eight u64 lanes of a zmm register in one
//! instruction, so a binop-popcount is load/load/op/popcnt/add per 8
//! words. The intrinsics (`_mm512_popcnt_epi64` and friends) are
//! unstable at the crate MSRV, so this whole module sits behind the
//! default-off `avx512` cargo feature (which turns on
//! `feature(stdarch_x86_avx512)` at the crate root and therefore requires
//! a nightly toolchain). Runtime detection still applies on top: the
//! dispatch table only selects this arm when
//! `is_x86_feature_detected!` reports both `avx512f` and
//! `avx512vpopcntdq`.
//!
//! Safety: same contract as the AVX2 module — the functions are reachable
//! only through the dispatch table, which is constructed strictly after
//! feature detection succeeds.

use core::arch::x86_64::*;

#[target_feature(enable = "avx512f")]
#[target_feature(enable = "avx512vpopcntdq")]
unsafe fn popcount_inner(words: &[u64]) -> usize {
    let n = words.len();
    let p = words.as_ptr();
    let mut acc = _mm512_setzero_si512();
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm512_loadu_si512(p.add(i) as *const _);
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(v));
        i += 8;
    }
    let mut total = _mm512_reduce_add_epi64(acc) as u64;
    while i < n {
        total += words[i].count_ones() as u64;
        i += 1;
    }
    total as usize
}

/// Hamming weight of a word slice.
pub(super) fn popcount_words(words: &[u64]) -> usize {
    unsafe { popcount_inner(words) }
}

// Same shape as the AVX2 module: `#[target_feature]` functions cannot be
// generic over the combining op at our MSRV, so a macro stamps out one
// inner + wrapper per binop.
macro_rules! avx512_binop_popcount {
    ($inner:ident, $name:ident, $vop:ident, $sop:expr) => {
        #[target_feature(enable = "avx512f")]
        #[target_feature(enable = "avx512vpopcntdq")]
        unsafe fn $inner(a: &[u64], b: &[u64]) -> usize {
            let n = a.len();
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            let mut acc = _mm512_setzero_si512();
            let mut i = 0;
            while i + 8 <= n {
                let va = _mm512_loadu_si512(pa.add(i) as *const _);
                let vb = _mm512_loadu_si512(pb.add(i) as *const _);
                acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64($vop(va, vb)));
                i += 8;
            }
            let mut total = _mm512_reduce_add_epi64(acc) as u64;
            let sop: fn(u64, u64) -> u64 = $sop;
            while i < n {
                total += sop(a[i], b[i]).count_ones() as u64;
                i += 1;
            }
            total as usize
        }

        pub(super) fn $name(a: &[u64], b: &[u64]) -> usize {
            super::assert_same_words(a, b);
            unsafe { $inner(a, b) }
        }
    };
}

avx512_binop_popcount!(and_inner, and_count_words, _mm512_and_si512, |a, b| a & b);
avx512_binop_popcount!(xor_inner, xor_count_words, _mm512_xor_si512, |a, b| a ^ b);
avx512_binop_popcount!(or_inner, or_count_words, _mm512_or_si512, |a, b| a | b);
