//! AVX2 popcount kernels (Muła `vpshufb`-LUT).
//!
//! x86-64 has no 256-bit vector popcount below AVX-512; the classic
//! workaround (Muła/Kurz/Lemire, "Faster population counts using AVX2
//! instructions") splits each byte into nibbles, looks each nibble's bit
//! count up in a shuffled 16-entry LUT, and horizontally accumulates the
//! per-byte counts into the four u64 lanes with `vpsadbw` against zero.
//! One 256-bit vector covers four sketch words, so the 8-word inner step
//! of the blocked scoring kernels is exactly two vector loads per
//! operand — the unroll the scalar kernels were already shaped for.
//!
//! Safety: every public function here is safe to *declare* only because
//! the dispatch table in [`super`] hands this module out strictly after
//! `is_x86_feature_detected!("avx2")` succeeds. The module is private to
//! `kernels`; nothing else can reach these entry points.

use core::arch::x86_64::*;

/// Nibble→bit-count lookup table, replicated across both 128-bit lanes
/// (`vpshufb` shuffles within each lane independently).
const NIBBLE_LUT: [i8; 32] = [
    0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
    0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
];

/// Per-byte popcount of a 256-bit vector, widened to u64 lane sums.
#[target_feature(enable = "avx2")]
unsafe fn popcount256(v: __m256i) -> __m256i {
    let lut = _mm256_loadu_si256(NIBBLE_LUT.as_ptr() as *const __m256i);
    let low_mask = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(v, low_mask);
    let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(v), low_mask);
    let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
    // Sum of absolute byte differences against zero = per-64-bit-lane sum
    // of the byte counts; no lane can overflow (max 8 bytes × 8 bits).
    _mm256_sad_epu8(cnt, _mm256_setzero_si256())
}

/// Horizontal sum of the four u64 lanes.
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi64(v: __m256i) -> u64 {
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
}

#[target_feature(enable = "avx2")]
unsafe fn popcount_inner(words: &[u64]) -> usize {
    let n = words.len();
    let p = words.as_ptr();
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    let mut i = 0;
    while i + 8 <= n {
        let v0 = _mm256_loadu_si256(p.add(i) as *const __m256i);
        let v1 = _mm256_loadu_si256(p.add(i + 4) as *const __m256i);
        acc0 = _mm256_add_epi64(acc0, popcount256(v0));
        acc1 = _mm256_add_epi64(acc1, popcount256(v1));
        i += 8;
    }
    let mut total = hsum_epi64(acc0) + hsum_epi64(acc1);
    while i < n {
        total += words[i].count_ones() as u64;
        i += 1;
    }
    total as usize
}

/// Hamming weight of a word slice.
pub(super) fn popcount_words(words: &[u64]) -> usize {
    unsafe { popcount_inner(words) }
}

// `#[target_feature]` functions cannot be generic over the combining op
// at our MSRV, so each binop gets its own generated inner + wrapper. The
// wrappers repeat the scalar kernels' hard length assert so every
// dispatch arm rejects mismatched dimensions identically.
macro_rules! avx2_binop_popcount {
    ($inner:ident, $name:ident, $vop:ident, $sop:expr) => {
        #[target_feature(enable = "avx2")]
        unsafe fn $inner(a: &[u64], b: &[u64]) -> usize {
            let n = a.len();
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            let mut acc0 = _mm256_setzero_si256();
            let mut acc1 = _mm256_setzero_si256();
            let mut i = 0;
            while i + 8 <= n {
                let a0 = _mm256_loadu_si256(pa.add(i) as *const __m256i);
                let b0 = _mm256_loadu_si256(pb.add(i) as *const __m256i);
                let a1 = _mm256_loadu_si256(pa.add(i + 4) as *const __m256i);
                let b1 = _mm256_loadu_si256(pb.add(i + 4) as *const __m256i);
                acc0 = _mm256_add_epi64(acc0, popcount256($vop(a0, b0)));
                acc1 = _mm256_add_epi64(acc1, popcount256($vop(a1, b1)));
                i += 8;
            }
            let mut total = hsum_epi64(acc0) + hsum_epi64(acc1);
            let sop: fn(u64, u64) -> u64 = $sop;
            while i < n {
                total += sop(a[i], b[i]).count_ones() as u64;
                i += 1;
            }
            total as usize
        }

        pub(super) fn $name(a: &[u64], b: &[u64]) -> usize {
            super::assert_same_words(a, b);
            unsafe { $inner(a, b) }
        }
    };
}

avx2_binop_popcount!(and_inner, and_count_words, _mm256_and_si256, |a, b| a & b);
avx2_binop_popcount!(xor_inner, xor_count_words, _mm256_xor_si256, |a, b| a ^ b);
avx2_binop_popcount!(or_inner, or_count_words, _mm256_or_si256, |a, b| a | b);
