//! NEON popcount kernels for aarch64.
//!
//! AArch64 has a per-byte vector popcount (`cnt.16b`) in the baseline
//! instruction set, so the idiom is: load 16 bytes (two sketch words),
//! `cnt` per byte, widen-sum the sixteen byte counts with `uaddlv`. Two
//! 128-bit vectors per iteration cover the same 8-word inner step the
//! scalar and AVX2 arms use.
//!
//! NEON is mandatory in the aarch64 baseline (every target this crate
//! compiles for has it), so unlike the x86 arms there is no runtime
//! detection step — the dispatch table selects this arm unconditionally
//! on aarch64. The intrinsics are still `unsafe fn` in `core::arch`;
//! the wrappers are sound because the feature is architecturally
//! guaranteed.

use core::arch::aarch64::*;

/// Popcount of one 128-bit vector (16 bytes = 2 sketch words).
#[inline]
fn popcount128(v: uint8x16_t) -> u64 {
    unsafe { vaddlvq_u8(vcntq_u8(v)) as u64 }
}

/// Hamming weight of a word slice.
pub(super) fn popcount_words(words: &[u64]) -> usize {
    let n = words.len();
    let p = words.as_ptr() as *const u8;
    let mut total = 0u64;
    let mut i = 0;
    while i + 8 <= n {
        unsafe {
            let v0 = vld1q_u8(p.add(i * 8));
            let v1 = vld1q_u8(p.add((i + 2) * 8));
            let v2 = vld1q_u8(p.add((i + 4) * 8));
            let v3 = vld1q_u8(p.add((i + 6) * 8));
            total += popcount128(v0) + popcount128(v1);
            total += popcount128(v2) + popcount128(v3);
        }
        i += 8;
    }
    while i < n {
        total += words[i].count_ones() as u64;
        i += 1;
    }
    total as usize
}

// One generated inner loop per binop, mirroring the x86 arms.
macro_rules! neon_binop_popcount {
    ($name:ident, $vop:ident, $sop:expr) => {
        pub(super) fn $name(a: &[u64], b: &[u64]) -> usize {
            super::assert_same_words(a, b);
            let n = a.len();
            let pa = a.as_ptr() as *const u8;
            let pb = b.as_ptr() as *const u8;
            let mut total = 0u64;
            let mut i = 0;
            while i + 8 <= n {
                unsafe {
                    let a0 = vld1q_u8(pa.add(i * 8));
                    let b0 = vld1q_u8(pb.add(i * 8));
                    let a1 = vld1q_u8(pa.add((i + 2) * 8));
                    let b1 = vld1q_u8(pb.add((i + 2) * 8));
                    let a2 = vld1q_u8(pa.add((i + 4) * 8));
                    let b2 = vld1q_u8(pb.add((i + 4) * 8));
                    let a3 = vld1q_u8(pa.add((i + 6) * 8));
                    let b3 = vld1q_u8(pb.add((i + 6) * 8));
                    total += popcount128($vop(a0, b0)) + popcount128($vop(a1, b1));
                    total += popcount128($vop(a2, b2)) + popcount128($vop(a3, b3));
                }
                i += 8;
            }
            let sop: fn(u64, u64) -> u64 = $sop;
            while i < n {
                total += sop(a[i], b[i]).count_ones() as u64;
                i += 1;
            }
            total as usize
        }
    };
}

neon_binop_popcount!(and_count_words, vandq_u8, |a, b| a & b);
neon_binop_popcount!(xor_count_words, veorq_u8, |a, b| a ^ b);
neon_binop_popcount!(or_count_words, vorrq_u8, |a, b| a | b);
