//! Runtime-dispatched popcount/scoring kernels.
//!
//! Every estimator in the Cabin/Cham family bottoms out in one of four
//! word-slice reductions — `|u|`, `|u ∧ v|`, `|u ⊕ v|`, `|u ∨ v|` — so
//! this module owns exactly those four entry points and selects the
//! widest implementation the running CPU supports, **once**, at first
//! use:
//!
//! | arm      | where                         | how                              |
//! |----------|-------------------------------|----------------------------------|
//! | `scalar` | everywhere (oracle, fallback) | 4-/8-way unrolled `count_ones`   |
//! | `avx2`   | x86-64 with AVX2              | Muła `vpshufb`-LUT + `vpsadbw`   |
//! | `avx512` | x86-64, nightly `avx512` flag | native `vpopcntq`                |
//! | `neon`   | aarch64 (baseline)            | `cnt.16b` + `uaddlv`             |
//!
//! Selection happens in [`active`] via `is_x86_feature_detected!` behind
//! a `OnceLock`, so the hot paths pay one relaxed atomic load, never a
//! re-detection. The chosen arm is surfaced as the `kernel_isa` stats
//! field (and through the Prometheus exposition) so benches and soaks
//! record which path actually ran. Set `CABIN_KERNEL_ISA=scalar|avx2|
//! avx512|neon` to pin the dispatch (an unavailable or unknown name
//! silently falls back to auto-detection — a serving process must not
//! refuse to boot over a stale env var).
//!
//! Every arm enforces the same hard word-length contract as the original
//! scalar kernels (see [`scalar`]) and is bit-identical to them on every
//! input — property-tested over ragged tile shapes, odd word counts and
//! empty slices in `tests/prop_kernels.rs`. [`table_for`] and
//! [`available`] expose specific arms (when the CPU has them) so tests
//! and benches can compare implementations side by side regardless of
//! which arm [`active`] picked.

use std::sync::OnceLock;

pub mod scalar;

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod avx512;

#[cfg(target_arch = "x86_64")]
mod avx2;

#[cfg(target_arch = "aarch64")]
mod neon;

/// Instruction-set architecture of a kernel arm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable unrolled `u64::count_ones` loops — always available.
    Scalar,
    /// AVX2 `vpshufb`-LUT popcount (x86-64, runtime-detected).
    Avx2,
    /// AVX-512 VPOPCNTDQ (x86-64, `avx512` cargo feature + runtime-detected).
    Avx512,
    /// NEON `cnt`/`uaddlv` (aarch64 baseline).
    Neon,
}

impl Isa {
    /// Stable lowercase name — used in logs, bench lane labels and docs.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Numeric code for the flat `(name, f64)` stats surface:
    /// 0 = scalar, 1 = avx2, 2 = avx512, 3 = neon (`kernel_isa` field).
    pub fn code(self) -> f64 {
        match self {
            Isa::Scalar => 0.0,
            Isa::Avx2 => 1.0,
            Isa::Avx512 => 2.0,
            Isa::Neon => 3.0,
        }
    }

    fn from_name(name: &str) -> Option<Isa> {
        match name {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" => Some(Isa::Avx512),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// Dispatch table: the four word-slice reductions for one ISA arm.
///
/// Plain `fn` pointers, not a trait object — the table is a static, the
/// call is one indirect jump, and the pointers are `'static` so holding
/// a `&'static Kernels` is free to copy around (shard workers grab it
/// once per scan, not per row).
pub struct Kernels {
    /// Which arm this table is.
    pub isa: Isa,
    /// Hamming weight `|u|`.
    pub popcount: fn(&[u64]) -> usize,
    /// Bitwise inner product `|u ∧ v|`. Panics on word-length mismatch.
    pub and_count: fn(&[u64], &[u64]) -> usize,
    /// Hamming distance `|u ⊕ v|`. Panics on word-length mismatch.
    pub xor_count: fn(&[u64], &[u64]) -> usize,
    /// Union size `|u ∨ v|`. Panics on word-length mismatch.
    pub or_count: fn(&[u64], &[u64]) -> usize,
}

static SCALAR: Kernels = Kernels {
    isa: Isa::Scalar,
    popcount: scalar::popcount_words,
    and_count: scalar::and_count_words8,
    xor_count: scalar::xor_count_words8,
    or_count: scalar::or_count_words8,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    isa: Isa::Avx2,
    popcount: avx2::popcount_words,
    and_count: avx2::and_count_words,
    xor_count: avx2::xor_count_words,
    or_count: avx2::or_count_words,
};

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
static AVX512: Kernels = Kernels {
    isa: Isa::Avx512,
    popcount: avx512::popcount_words,
    and_count: avx512::and_count_words,
    xor_count: avx512::xor_count_words,
    or_count: avx512::or_count_words,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    isa: Isa::Neon,
    popcount: neon::popcount_words,
    and_count: neon::and_count_words,
    xor_count: neon::xor_count_words,
    or_count: neon::or_count_words,
};

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The dispatch table every serving path routes through — detected once,
/// cached for the life of the process.
#[inline]
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(detect)
}

fn detect() -> &'static Kernels {
    if let Ok(want) = std::env::var("CABIN_KERNEL_ISA") {
        if let Some(t) = Isa::from_name(want.trim()).and_then(table_for) {
            return t;
        }
        // Unknown or unavailable override: fall through to auto-detect —
        // a stale env var must never stop a serving process from booting.
    }
    best_available()
}

fn best_available() -> &'static Kernels {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    {
        if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq") {
            return &AVX512;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return &AVX2;
        }
    }
    baseline()
}

/// The widest arm guaranteed by the architecture alone (no detection).
#[cfg(target_arch = "aarch64")]
fn baseline() -> &'static Kernels {
    &NEON
}

/// The widest arm guaranteed by the architecture alone (no detection).
#[cfg(not(target_arch = "aarch64"))]
fn baseline() -> &'static Kernels {
    &SCALAR
}

/// The table for a specific ISA, if this build has the arm compiled in
/// *and* the running CPU supports it. `Scalar` always succeeds. Lets
/// property tests and benches exercise a specific arm without touching
/// the process-wide [`active`] selection.
pub fn table_for(isa: Isa) -> Option<&'static Kernels> {
    match isa {
        Isa::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            if is_x86_feature_detected!("avx2") {
                Some(&AVX2)
            } else {
                None
            }
        }
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Isa::Avx512 => {
            if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq") {
                Some(&AVX512)
            } else {
                None
            }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => Some(&NEON),
        #[allow(unreachable_patterns)]
        _ => None,
    }
}

/// Every arm usable on this machine, scalar first. The property tests
/// iterate this so a CI box without AVX2 still passes (it just has less
/// to compare) while an AVX2 box proves bit-identity for real.
pub fn available() -> Vec<&'static Kernels> {
    let mut out = vec![&SCALAR];
    for isa in [Isa::Avx2, Isa::Avx512, Isa::Neon] {
        if let Some(t) = table_for(isa) {
            out.push(t);
        }
    }
    out
}

/// Shared word-length contract check — identical message across every
/// arm, pinned by the `should_panic` tests in [`crate::sketch::bitvec`].
#[inline]
pub(crate) fn assert_same_words(a: &[u64], b: &[u64]) {
    assert_eq!(
        a.len(),
        b.len(),
        "bitvec word-length mismatch: {} vs {} words — operands come from different dimensions",
        a.len(),
        b.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available() {
        let arms = available();
        assert_eq!(arms[0].isa, Isa::Scalar);
        assert!(table_for(Isa::Scalar).is_some());
    }

    #[test]
    fn active_is_one_of_available() {
        let active = active();
        assert!(
            available().iter().any(|t| t.isa == active.isa),
            "active arm {:?} missing from available()",
            active.isa
        );
    }

    #[test]
    fn isa_names_and_codes_are_stable() {
        // The name feeds logs/bench lanes; the code is the wire value of
        // the `kernel_isa` stats field. Neither may drift.
        for (isa, name, code) in [
            (Isa::Scalar, "scalar", 0.0),
            (Isa::Avx2, "avx2", 1.0),
            (Isa::Avx512, "avx512", 2.0),
            (Isa::Neon, "neon", 3.0),
        ] {
            assert_eq!(isa.name(), name);
            assert_eq!(isa.code(), code);
            assert_eq!(Isa::from_name(name), Some(isa));
        }
        assert_eq!(Isa::from_name("sse2"), None);
    }

    #[test]
    fn every_available_arm_matches_scalar_on_smoke_input() {
        // The deep ragged-shape property test lives in
        // tests/prop_kernels.rs; this is the in-tree smoke version.
        let mut a = vec![0u64; 37];
        let mut b = vec![0u64; 37];
        for i in 0..37u64 {
            a[i as usize] = i.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            b[i as usize] = i.wrapping_mul(0xbf58_476d_1ce4_e5b9) ^ !0;
        }
        for t in available() {
            let name = t.isa.name();
            assert_eq!((t.popcount)(&a), scalar::popcount_words(&a), "{name}");
            assert_eq!((t.and_count)(&a, &b), scalar::and_count_words(&a, &b), "{name}");
            assert_eq!((t.xor_count)(&a, &b), scalar::xor_count_words(&a, &b), "{name}");
            assert_eq!((t.or_count)(&a, &b), scalar::or_count_words(&a, &b), "{name}");
            assert_eq!((t.popcount)(&[]), 0, "{name}");
            assert_eq!((t.and_count)(&[], &[]), 0, "{name}");
        }
    }
}
