//! **Cabin** — the full sketching pipeline (Algorithm 1): BinEm ∘ BinSketch,
//! fused into one pass over the nonzeros of the input vector.

use super::binem::{BinEm, PsiMode};
use super::binsketch::BinSketch;
use super::bitvec::BitVec;
use super::cham::Estimator;
use crate::data::{CatVector, CategoricalDataset};
use crate::util::parallel;

/// Everything needed to (re)construct a sketcher and to interpret sketches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SketchConfig {
    /// Input dimension `n`.
    pub input_dim: usize,
    /// Largest category label `c`.
    pub num_categories: u16,
    /// Sketch dimension `d`.
    pub sketch_dim: usize,
    /// Seed for both ψ and π streams.
    pub seed: u64,
    /// ψ instantiation (paper: Shared).
    pub psi_mode: PsiMode,
    /// Which BinHamming estimator Cham uses.
    pub estimator: Estimator,
}

impl SketchConfig {
    pub fn new(input_dim: usize, num_categories: u16, sketch_dim: usize, seed: u64) -> Self {
        Self {
            input_dim,
            num_categories,
            sketch_dim,
            seed,
            psi_mode: PsiMode::PerAttribute,
            estimator: Estimator::OccupancyInversion,
        }
    }

    pub fn with_psi_mode(mut self, m: PsiMode) -> Self {
        self.psi_mode = m;
        self
    }

    pub fn with_estimator(mut self, e: Estimator) -> Self {
        self.estimator = e;
        self
    }
}

/// The Cabin sketcher. Construction derives ψ and π; [`CabinSketcher::sketch`]
/// is then a single pass over the input's nonzeros:
/// `for (i,v) in u: if ψ(v)=1 { ũ[π(i)] = 1 }`.
#[derive(Clone, Debug)]
pub struct CabinSketcher {
    config: SketchConfig,
    binem: BinEm,
    binsketch: BinSketch,
}

impl CabinSketcher {
    pub fn new(input_dim: usize, num_categories: u16, sketch_dim: usize, seed: u64) -> Self {
        Self::from_config(SketchConfig::new(input_dim, num_categories, sketch_dim, seed))
    }

    pub fn from_config(config: SketchConfig) -> Self {
        Self {
            binem: BinEm::new(
                config.input_dim,
                config.num_categories,
                config.psi_mode,
                config.seed,
            ),
            binsketch: BinSketch::new(config.input_dim, config.sketch_dim, config.seed),
            config,
        }
    }

    /// Build with an explicit π table (AOT sidecar path).
    pub fn with_tables(config: SketchConfig, pi: Vec<u32>) -> Self {
        Self {
            binem: BinEm::new(
                config.input_dim,
                config.num_categories,
                config.psi_mode,
                config.seed,
            ),
            binsketch: BinSketch::with_pi(config.input_dim, config.sketch_dim, pi),
            config,
        }
    }

    pub fn config(&self) -> &SketchConfig {
        &self.config
    }

    pub fn binem(&self) -> &BinEm {
        &self.binem
    }

    pub fn binsketch(&self) -> &BinSketch {
        &self.binsketch
    }

    /// `Cabin(u)` — the fused one-pass sketch. `O(nnz(u))`.
    pub fn sketch(&self, u: &CatVector) -> BitVec {
        self.binsketch.compress_ones(self.binem.encode_ones(u))
    }

    /// Allocation-free variant for the serving hot path.
    pub fn sketch_into(&self, u: &CatVector, out: &mut BitVec) {
        self.binsketch
            .compress_ones_into(self.binem.encode_ones(u), out);
    }

    /// Two-stage (unfused) reference: materialise `u' = BinEm(u)` then
    /// compress. Used by tests to show fused == staged, and by the analysis
    /// experiments that need `u'` itself.
    pub fn sketch_staged(&self, u: &CatVector) -> (BitVec, BitVec) {
        let u1 = self.binem.encode(u);
        let sk = self.binsketch.compress(&u1);
        (u1, sk)
    }

    /// Sketch an entire dataset in parallel.
    pub fn sketch_dataset(&self, ds: &CategoricalDataset, threads: usize) -> Vec<BitVec> {
        let mut out: Vec<BitVec> = vec![BitVec::zeros(self.config.sketch_dim); ds.len()];
        parallel::par_chunks_mut(&mut out, threads, |start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                self.sketch_into(&ds.points[start + off], slot);
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn fused_equals_staged() {
        let mut rng = Xoshiro256::new(10);
        for seed in 0..10u64 {
            let u = CatVector::random(2000, 120, 30, &mut rng);
            let sk = CabinSketcher::new(2000, 30, 256, seed);
            let fused = sk.sketch(&u);
            let (_, staged) = sk.sketch_staged(&u);
            assert_eq!(fused, staged, "seed {}", seed);
        }
    }

    #[test]
    fn sparsity_halving_lemma4() {
        // Lemma 4: E[ones(Cabin(u))] ≤ nnz(u)/2.
        let mut rng = Xoshiro256::new(11);
        let u = CatVector::random(5000, 400, 40, &mut rng);
        let trials = 200;
        let mut total = 0usize;
        for s in 0..trials {
            total += CabinSketcher::new(5000, 40, 1000, s).sketch(&u).count_ones();
        }
        let mean = total as f64 / trials as f64;
        assert!(
            mean <= u.nnz() as f64 / 2.0 + 3.0,
            "mean {} vs T/2 {}",
            mean,
            u.nnz() / 2
        );
    }

    #[test]
    fn sketch_into_reuses_buffer() {
        let mut rng = Xoshiro256::new(12);
        let u = CatVector::random(1000, 50, 10, &mut rng);
        let v = CatVector::random(1000, 50, 10, &mut rng);
        let sk = CabinSketcher::new(1000, 10, 128, 1);
        let mut buf = BitVec::zeros(128);
        sk.sketch_into(&u, &mut buf);
        assert_eq!(buf, sk.sketch(&u));
        sk.sketch_into(&v, &mut buf); // no residue from u
        assert_eq!(buf, sk.sketch(&v));
    }

    #[test]
    fn dataset_parallel_matches_serial() {
        let mut rng = Xoshiro256::new(13);
        let pts = (0..40)
            .map(|_| CatVector::random(500, 30, 8, &mut rng))
            .collect();
        let ds = CategoricalDataset::new("t", 500, 8, pts);
        let sk = CabinSketcher::new(500, 8, 64, 5);
        let par = sk.sketch_dataset(&ds, 4);
        for (i, p) in ds.points.iter().enumerate() {
            assert_eq!(par[i], sk.sketch(p));
        }
    }

    #[test]
    fn identical_inputs_identical_sketches() {
        let u = CatVector::from_dense(&[1, 0, 2, 3, 0, 0, 4]);
        let sk = CabinSketcher::new(7, 4, 16, 99);
        assert_eq!(sk.sketch(&u), sk.sketch(&u.clone()));
        assert_eq!(sk.sketch(&u).len(), 16);
    }
}
