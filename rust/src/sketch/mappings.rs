//! Derivation of the paper's two random mappings from a single seed.
//!
//! * ψ : {0,…,c} → {0,1}   (category mapping; ψ(0) = 0)
//! * π : {0,…,n-1} → {0,…,d-1}  (attribute mapping)
//!
//! Both are drawn from splitmix64 streams with fixed stream tags, and the
//! *identical* derivation is implemented in `python/compile/prng.py` so that
//! the JAX AOT artifacts bake the same ψ table and π one-hot matrix the rust
//! native path uses. `python/tests/test_prng.py` and the rust tests below
//! pin the same vectors. When artifacts are present the rust side can also
//! load the sidecar files (`artifacts/pi_*.u32`, `artifacts/psi_*.u8`) and
//! verify agreement (see `runtime::artifacts`).

use crate::util::rng::SplitMix64;

/// Stream tags: seed ⊕ tag selects an independent stream.
pub const PSI_STREAM: u64 = 0x5049_5053_4954_0001; // "PSI"
pub const PI_STREAM: u64 = 0x5049_5f4d_4150_0002; // "PI_MAP"

/// The category mapping ψ as an explicit table over `{0,…,c}`; `table[0]`
/// is always 0 (missing stays missing).
pub fn derive_psi(seed: u64, num_categories: u16) -> Vec<u8> {
    let mut sm = SplitMix64::new(seed ^ PSI_STREAM);
    let mut table = Vec::with_capacity(num_categories as usize + 1);
    table.push(0u8);
    for _ in 1..=num_categories {
        table.push((sm.next_u64() & 1) as u8);
    }
    table
}

/// The attribute mapping π as an explicit table over `{0,…,n-1}` with
/// values in `{0,…,d-1}`.
///
/// Uses `next_u64() % d`; the modulo bias is ≤ d/2⁶⁴ ≈ 10⁻¹⁶ — irrelevant,
/// and keeping it a single modulo makes the python port trivial.
pub fn derive_pi(seed: u64, n: usize, d: usize) -> Vec<u32> {
    assert!(d > 0 && d <= u32::MAX as usize);
    let mut sm = SplitMix64::new(seed ^ PI_STREAM);
    (0..n).map(|_| (sm.next_u64() % d as u64) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pinned vectors — python/tests/test_prng.py asserts the same numbers.
    #[test]
    fn psi_pinned_vectors_seed42() {
        let t = derive_psi(42, 8);
        assert_eq!(t.len(), 9);
        assert_eq!(t[0], 0);
        // regenerate deterministically and compare against itself via stream
        let mut sm = SplitMix64::new(42 ^ PSI_STREAM);
        for v in &t[1..] {
            assert_eq!(*v as u64, sm.next_u64() & 1);
        }
    }

    #[test]
    fn pi_pinned_properties() {
        let pi = derive_pi(7, 1000, 64);
        assert_eq!(pi.len(), 1000);
        assert!(pi.iter().all(|&b| b < 64));
        // deterministic
        assert_eq!(pi, derive_pi(7, 1000, 64));
        // different seeds differ
        assert_ne!(pi, derive_pi(8, 1000, 64));
        // roughly uniform occupancy
        let mut counts = vec![0usize; 64];
        for &b in &pi {
            counts[b as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max < 40 && min > 2, "occupancy skew {}..{}", min, max);
    }

    #[test]
    fn psi_is_roughly_balanced() {
        let t = derive_psi(1, 2036); // BrainCell-scale category count
        let ones: usize = t.iter().map(|&b| b as usize).sum();
        let frac = ones as f64 / 2036.0;
        assert!((frac - 0.5).abs() < 0.05, "psi balance {}", frac);
    }
}
