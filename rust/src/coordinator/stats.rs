//! Typed view of the `stats` wire response.
//!
//! The wire keeps its shape — `stats` answers a flat JSON object of
//! numeric fields, decoded as ordered `(name, value)` pairs
//! ([`Response::Stats`](super::protocol::Response)) — but string-keyed
//! lookups (`client.stat("queires")`) fail at runtime with a typo'd name
//! and a silent `Err`. [`Stats`] turns every *schema* field into a
//! struct member, so the lookup is checked at compile time, while
//! anything this build does not know — fields added by newer servers,
//! and the dynamic families (`stage_*`, `repl_applied_seq_shard{i}`,
//! `repl_lag_shard{i}`, `repl_visibility_age_ms_shard{i}`,
//! `executor_queue_hwm_shard{i}`, `persist_next_seq_shard{i}`,
//! `persist_wal_live_bytes`) — is preserved verbatim in
//! [`Stats::extra`], in arrival order. Nothing is dropped:
//! [`Stats::to_fields`] reproduces every pair (schema members first, in
//! schema order, then `extra`).
//!
//! The schema member list is generated from one name table by
//! `stats_struct!`, so the struct, [`Stats::FIELD_NAMES`],
//! [`Stats::from_fields`], [`Stats::get`] and [`Stats::to_fields`]
//! cannot drift apart. Wire names are pinned by the golden test in
//! [`super::metrics`] (`stats_schema_is_stable_and_unique`) plus the
//! `index_cfg_*`/`persist_cfg_*` config tests — renaming a member here
//! without those tests failing is impossible, which is the compat
//! contract: this module may grow fields, never rename them.

/// Generate [`Stats`]: one `pub f64` member per schema field, plus the
/// `extra` spillover, with the name table shared by every accessor.
macro_rules! stats_struct {
    ($($field:ident),+ $(,)?) => {
        /// One `stats` snapshot with every schema field typed. See the
        /// module docs for the schema/`extra` split and the compat
        /// contract; construct with [`Stats::from_fields`] (or
        /// `Client::typed_stats`).
        #[derive(Clone, Debug, Default, PartialEq)]
        pub struct Stats {
            $(pub $field: f64,)+
            /// Fields outside the schema, in arrival order: dynamic
            /// per-shard/per-stage families and anything a newer server
            /// added. Look up with [`Stats::get`].
            pub extra: Vec<(String, f64)>,
        }

        impl Stats {
            /// Every schema member, in declaration (= wire) order.
            pub const FIELD_NAMES: &[&str] = &[$(stringify!($field)),+];

            /// Decode a `stats` reply: schema names fill their members,
            /// everything else lands in [`Stats::extra`]. A schema field
            /// the server did not send stays 0.0 — exactly what the
            /// server reports for a counter it has never incremented.
            pub fn from_fields(fields: Vec<(String, f64)>) -> Stats {
                let mut s = Stats::default();
                for (name, value) in fields {
                    match name.as_str() {
                        $(stringify!($field) => s.$field = value,)+
                        _ => s.extra.push((name, value)),
                    }
                }
                s
            }

            /// Name-based lookup across schema members *and* `extra` —
            /// for dynamic names built at runtime. Prefer the members
            /// for schema fields.
            pub fn get(&self, name: &str) -> Option<f64> {
                match name {
                    $(stringify!($field) => Some(self.$field),)+
                    _ => super::metrics::stats_field(&self.extra, name),
                }
            }

            /// Re-encode as `(name, value)` pairs: schema members first
            /// in schema order, then `extra` in arrival order. Feeds
            /// anything that consumed `Client::stats` output.
            pub fn to_fields(&self) -> Vec<(String, f64)> {
                let mut out = vec![$((stringify!($field).to_string(), self.$field)),+];
                out.extend(self.extra.iter().cloned());
                out
            }
        }
    };
}

stats_struct! {
    // write/read request counters
    inserts,
    deletes,
    upserts,
    ttl_expirations,
    queries,
    query_batches,
    distances,
    heatmaps,
    batches_flushed,
    batch_items,
    errors,
    // sketching backend
    xla_batches,
    native_batches,
    // LSH index read path
    index_probes,
    index_candidates,
    index_reranked,
    index_fallbacks,
    index_indexed_scans,
    // shard-executor runtime
    executor_queue_depth,
    executor_busy_workers,
    executor_jobs,
    executor_scatters,
    executor_job_panics,
    // persistence
    persist_wal_records,
    persist_wal_bytes,
    persist_snapshots,
    persist_recovery_ms,
    persist_generation,
    persist_group_commits,
    persist_wal_dead_frames,
    persist_compactions,
    // scoring-kernel dispatch (0 scalar / 1 avx2 / 2 avx512 / 3 neon)
    kernel_isa,
    // replication
    repl_snapshots_served,
    repl_tails_served,
    repl_frames_shipped,
    repl_bytes_shipped,
    repl_frames_applied,
    repl_bytes_applied,
    repl_connects,
    repl_stalls,
    repl_move_defers,
    repl_diverged,
    repl_caught_up,
    // wall-clock replication visibility lag (follower side): time from a
    // frame's primary commit stamp to its local apply
    repl_visibility_lag_count,
    repl_visibility_lag_p50_ms,
    repl_visibility_lag_p99_ms,
    // end-to-end latency summaries
    insert_p50_ms,
    insert_p99_ms,
    query_p50_ms,
    query_p99_ms,
    // server-level config echo + role (always present in a server reply)
    index_cfg_mode,
    index_cfg_bands,
    index_cfg_band_bits,
    index_cfg_probes,
    index_cfg_auto_min_rows,
    persist_cfg_mode,
    persist_cfg_fsync,
    persist_cfg_snapshot_every,
    persist_cfg_commit_window_us,
    persist_cfg_wal_max_bytes,
    persist_cfg_compact_dead_frames,
    repl_role,
    // failover: durable epoch (0 = non-durable), fence gauge (0 = not
    // fenced, else the observed superseding epoch), probe supervisor
    repl_epoch,
    failover_fenced,
    failover_probes,
    failover_probe_failures,
    failover_consecutive_failures,
    failover_promotions,
    failover_fence_events,
    failover_last_epoch,
    // observability: the advisory read-staleness budget this server was
    // started with (0 = unset) and the flight-recorder event journal
    // (events recorded / events overwritten by ring wraparound)
    cfg_max_read_staleness_ms,
    journal_events,
    journal_dropped,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_names_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for name in Stats::FIELD_NAMES {
            assert!(seen.insert(*name), "duplicate schema field {name}");
        }
    }

    #[test]
    fn from_fields_routes_schema_and_extra() {
        let s = Stats::from_fields(vec![
            ("queries".into(), 7.0),
            ("stage_read_scan_p99_ms".into(), 1.25),
            ("kernel_isa".into(), 1.0),
            ("from_the_future".into(), 42.0),
        ]);
        assert_eq!(s.queries, 7.0);
        assert_eq!(s.kernel_isa, 1.0);
        assert_eq!(s.inserts, 0.0); // unsent schema field stays zero
        assert_eq!(
            s.extra,
            vec![
                ("stage_read_scan_p99_ms".to_string(), 1.25),
                ("from_the_future".to_string(), 42.0),
            ]
        );
        // get() spans both sides of the split
        assert_eq!(s.get("queries"), Some(7.0));
        assert_eq!(s.get("stage_read_scan_p99_ms"), Some(1.25));
        assert_eq!(s.get("nope"), None);
    }

    #[test]
    fn to_fields_preserves_every_pair() {
        let fields = vec![
            ("inserts".into(), 3.0),
            ("stage_write_wal_count".into(), 9.0),
        ];
        let back = Stats::from_fields(fields).to_fields();
        assert_eq!(back.len(), Stats::FIELD_NAMES.len() + 1);
        assert!(back.contains(&("inserts".to_string(), 3.0)));
        assert!(back.contains(&("stage_write_wal_count".to_string(), 9.0)));
        // schema members lead, in schema order
        let names: Vec<&str> = back.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(&names[..Stats::FIELD_NAMES.len()], Stats::FIELD_NAMES);
    }

    #[test]
    fn schema_covers_every_static_metrics_field() {
        // every name Metrics::snapshot emits is either a typed member or
        // one of the dynamic stage_* family — nothing silently becomes
        // `extra` on a plain in-memory server
        for (name, _) in super::super::metrics::Metrics::new().snapshot() {
            assert!(
                Stats::FIELD_NAMES.contains(&name.as_str()) || name.starts_with("stage_"),
                "snapshot field {name} missing from the Stats schema"
            );
        }
    }
}
