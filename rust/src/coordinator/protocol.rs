//! Wire protocol: one JSON object per line, both directions.
//!
//! Requests (`op` discriminates):
//! ```text
//! {"op":"insert",  "vec":[0,3,0,…]}             → {"ok":true,"id":17}
//! {"op":"insert_sparse","dim":4096,"idx":[…],"val":[…]}
//!   (both insert forms take an optional "ttl_ms": a *relative*
//!    time-to-live in milliseconds; the primary stamps the absolute
//!    deadline at apply time and sweeps expired rows in the background)
//! {"op":"delete",  "id":17}                     → {"ok":true,"deleted":17}
//! {"op":"upsert",  "id":17, "vec":[…]}          → {"ok":true,"upserted":17}
//!   (upsert replaces a live id in place or resurrects a deleted one;
//!    also takes vec/sparse forms and the optional "ttl_ms")
//! {"op":"query",   "vec":[…], "k":5}            → {"ok":true,"hits":[{"id":3,"dist":41.2},…]}
//! {"op":"query_batch","k":5,"dim":4096,          ("dim" optional: validated
//!  "queries":[{"idx":[…],"val":[…]} | {"vec":[…]},…]}  when present)
//!                                               → {"ok":true,"results":[[{"id":…,"dist":…},…],…]}
//! {"op":"distance","a":3,"b":9}                 → {"ok":true,"dist":57.9}
//! {"op":"heatmap"}                              → {"ok":true,"n":…,"values":[…]}  (small corpora)
//! {"op":"stats"}                                → {"ok":true, counters…}
//! {"op":"flush"}                                → {"ok":true,"flushed":true}       (fsync all WALs)
//! {"op":"snapshot"}                             → {"ok":true,"snapshot_generation":3}
//! {"op":"promote"}                              → {"ok":true,"promoted":true,"epoch":"2",
//!                                                  "applied_seqs":["812","790"]}   (replicas only)
//! {"op":"demote","epoch":"3"}                   → {"ok":true,"demoted":true,"epoch":"3"}
//! {"op":"ping","epoch":"2"}                     → {"ok":true,"pong":true,"epoch":"2"}
//!   ("epoch" optional both ways; durable servers echo theirs, and treat
//!    a higher peer epoch as evidence of a newer primary — see below)
//! {"op":"shutdown"}
//! ```
//!
//! `flush` and `snapshot` require the server to run with persistence
//! enabled (`--data-dir`); otherwise they answer with an error response.
//! `promote` requires a replica (`serve --replicate-from`): it stops the
//! puller and flips the replica writable, returning the per-shard applied
//! WAL sequences. Errors: `{"ok":false,"error":"…"}`.
//!
//! ## Epoch fencing
//!
//! Durable servers carry a monotonic **failover epoch** (persisted in the
//! manifest, starting at 1). Promotion bumps it; every durable mutation
//! ack, `pong`, replication header and `promoted` reply carries the
//! current value as a string-encoded u64 (non-durable servers omit it).
//! A server that observes a *higher* epoch than its own — on a `ping`,
//! `demote`, or `repl_wal_tail` request — concludes a newer primary was
//! promoted, **fences itself read-only** (persisting the observed epoch
//! and a fence marker so the decision survives restart) and rejects
//! writes with an error naming both epochs. `demote` is the explicit
//! spelling of the same transition, used by operators to turn a fenced
//! ex-primary back into a follower before restarting it with
//! `--replicate-from`.
//!
//! ## Stream ops (framed raw payloads)
//!
//! Four ops reply with a JSON **header line followed by raw payload
//! bytes**, which [`Response`] cannot represent. They share one
//! [`StreamRequest`] envelope — a `"stream"` key instead of `"op"`:
//!
//! ```text
//! {"stream":"repl_snapshot"}                → header {"ok":true,"generation":…,"shard_bytes":[…],…}
//!                                             + concatenated shard snapshot bytes
//! {"stream":"repl_wal_tail","shard":0,      → header {"ok":true,"frames":…,"bytes":N,…}
//!  "from_seq":"812","max_bytes":1048576,      + N bytes of raw WAL frames
//!  "epoch":"2"}                               ("epoch" optional: the follower's
//!                                              own epoch, for fencing)
//! {"stream":"metrics_text"}                 → header {"ok":true,"bytes":N}
//!                                             + N bytes of text/plain Prometheus exposition
//! {"stream":"events"}                       → header {"ok":true,"bytes":N}
//!                                             + N bytes of flight-recorder JSONL (obs::journal)
//! ```
//!
//! The payload length is always carried by the header (`bytes`, or the
//! `shard_bytes` array summed), so a reader drains exactly that many
//! bytes after the newline — see [`crate::replica::shipper`] and
//! [`crate::obs::prom`] for the payload producers, and `docs/PROTOCOL.md`
//! for the full framing contract.
//!
//! The PR 5–7 era `"op"` spellings of these three ops
//! (`{"op":"repl_snapshot"}` etc.) were deprecated for one release and
//! are now **removed**: such lines fall through to [`Request`] parsing
//! and draw an `unknown op` error (pinned by `tests/protocol_compat.rs`).
//!
//! ## Validation
//!
//! Validation happens here, before anything reaches the router: `k == 0`
//! is rejected with an error response (the seed let it through and the
//! top-k kernel underflowed `hits[k - 1]`, killing the shard worker — and,
//! via the scatter/gather `join().unwrap()`, the whole connection), and
//! `query_batch` elements are dimension-checked individually.
//!
//! ## Write options
//!
//! The per-write knobs (TTL, trace id) travel as one [`WriteOpts`]
//! struct through `Client::insert_with`/`upsert_with` and the batcher's
//! options-based submit path; `WriteOpts::default()` reproduces the
//! plain untimed, untraced write exactly.
//!
//! ## Trace propagation
//!
//! Every request line — ordinary and stream envelope alike — accepts an
//! optional top-level `"trace"` field: a client-chosen u64 (string or
//! numeric form, like seqs) that the server adopts instead of stamping
//! its own per-connection trace id. The id rides batcher tickets,
//! executor jobs, slow-op records and the structured log on whichever
//! node handles the request, so one grep joins a request's story across
//! primary and follower. Trace-less lines parse and answer exactly as
//! before ([`Request::parse_with_trace`] returns `None` and the server
//! stamps); replies never carry the field.

use crate::data::CatVector;
use crate::util::json::Json;
use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Insert { vec: CatVector },
    /// Insert with a relative time-to-live. Wire form is plain
    /// `"op":"insert"` plus a nonzero `"ttl_ms"` — a separate variant so
    /// the untimed fast path stays a one-field struct everywhere it is
    /// constructed.
    InsertTtl { vec: CatVector, ttl_ms: u64 },
    /// Remove a live id from the corpus (primary only; replicated).
    Delete { id: usize },
    /// Replace the sketch behind a live id in place, or resurrect a
    /// deleted id. `ttl_ms == 0` means no expiry (and *clears* any
    /// previous deadline on the id).
    Upsert { id: usize, vec: CatVector, ttl_ms: u64 },
    Query { vec: CatVector, k: usize },
    QueryBatch { vecs: Vec<CatVector>, k: usize },
    Distance { a: usize, b: usize },
    Heatmap,
    Stats,
    /// Fsync every shard WAL (durable servers only).
    Flush,
    /// Force a snapshot rotation now (durable servers only).
    Snapshot,
    /// Flip a caught-up replica writable (replicas only): stop pulling
    /// from the primary and start accepting inserts. Bumps the durable
    /// failover epoch on the first promotion.
    Promote,
    /// Fence a durable server read-only (the inverse of promote): used
    /// by operators to step a revived ex-primary down before rejoining
    /// it as a follower. `epoch`, when present, is the higher epoch to
    /// adopt (e.g. the new primary's).
    Demote { epoch: Option<u64> },
    /// Liveness probe. `epoch`, when present, is the sender's failover
    /// epoch — durable servers compare it against their own for fencing.
    Ping { epoch: Option<u64> },
    Shutdown,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Hit {
    pub id: usize,
    pub dist: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Mutation acks carry the durable failover epoch the write was
    /// accepted under (`None` on non-durable servers — their wire bytes
    /// are unchanged from the pre-epoch protocol).
    Inserted { id: usize, epoch: Option<u64> },
    /// The id's row was removed from the corpus.
    Deleted { id: usize, epoch: Option<u64> },
    /// The id's sketch was replaced (in place or by resurrection).
    Upserted { id: usize, epoch: Option<u64> },
    Hits { hits: Vec<Hit> },
    HitsBatch { results: Vec<Vec<Hit>> },
    Distance { dist: f64 },
    Heatmap { n: usize, values: Vec<f64> },
    Stats { fields: Vec<(String, f64)> },
    /// All WALs flushed and fsynced.
    Flushed,
    /// Snapshot rotation completed; the new live generation.
    Snapshotted { generation: u64 },
    /// Replica promoted to writable; per-shard applied WAL sequences at
    /// the moment the puller stopped, and the (freshly bumped) failover
    /// epoch the replica now serves writes under.
    Promoted { applied_seqs: Vec<u64>, epoch: u64 },
    /// Server fenced read-only; the failover epoch it is fenced at.
    Demoted { epoch: u64 },
    /// `epoch` is the durable server's failover epoch (`None` from
    /// non-durable servers — bytes unchanged from the pre-epoch `pong`).
    Pong { epoch: Option<u64> },
    ShuttingDown,
    Error { message: String },
}

/// Per-write options carried by the unified mutation entry points
/// (`Client::insert_with`/`upsert_with`, the batcher's options-based
/// submit). `Default` reproduces the historical plain write exactly: no
/// expiry, no trace stamp.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WriteOpts {
    /// Relative time-to-live in milliseconds; 0 means "no expiry" (and,
    /// on upsert, *clears* any previous deadline on the id). The server
    /// stamps the absolute deadline at apply time.
    pub ttl_ms: u64,
    /// Trace id stamped on the write as it flows through batcher tickets
    /// and slow-op records. Client-side this stays 0 — the server assigns
    /// per-connection trace ids; the field exists so server-internal
    /// submitters thread theirs through the same options struct.
    pub trace: u64,
}

impl WriteOpts {
    /// Shorthand for "expire after `ttl_ms`" with everything else default.
    pub fn ttl(ttl_ms: u64) -> Self {
        WriteOpts { ttl_ms, ..Default::default() }
    }
}

/// Header of a framed stream op: a JSON line whose reply is a JSON
/// header line **plus raw payload bytes** (see the module docs for the
/// framing). Parsed before [`Request`] in the connection loop — these
/// three ops used to be hand-routed ad hoc; this envelope is now the one
/// routing point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamRequest {
    /// Full snapshot of the current persisted generation (replication
    /// bootstrap): header carries the configuration fingerprint,
    /// per-shard base sequences and `shard_bytes`; the payload is the
    /// shard snapshot files concatenated in shard order. `trace` is the
    /// requesting follower's session trace id, logged on the serving
    /// side so a bootstrap is join-able across both nodes' logs.
    ReplSnapshot { trace: Option<u64> },
    /// Raw WAL frame range for one shard starting at `from_seq`
    /// (exclusive): header carries `frames`/`bytes`/`live_seq`/`epoch`/
    /// `commit_ms`; the payload is `bytes` of verbatim checksummed
    /// frames. The request-side `epoch` is the follower's own failover
    /// epoch — a primary that sees a *higher* one fences itself (see
    /// the module docs) instead of shipping. `trace` is the follower's
    /// session trace id (see [`StreamRequest::ReplSnapshot`]).
    ReplWalTail {
        shard: usize,
        from_seq: u64,
        max_bytes: usize,
        epoch: Option<u64>,
        trace: Option<u64>,
    },
    /// Prometheus text exposition: header `{"ok":true,"bytes":N}`, then
    /// `N` bytes of `text/plain; version=0.0.4`.
    MetricsText,
    /// Flight-recorder dump: header `{"bytes":N,"ok":true}`, then `N`
    /// bytes of JSONL — one journal event per line, oldest first (see
    /// [`crate::obs::journal`]). Served by primaries and followers.
    Events,
}

/// Default `max_bytes` for a WAL tail chunk when the request omits it.
pub const WAL_TAIL_DEFAULT_MAX_BYTES: usize = 1 << 20;

impl StreamRequest {
    /// Cheap pre-parse sniff: could this line be a stream op (a
    /// `"stream"` envelope)? False positives are fine —
    /// [`StreamRequest::from_json_line`] returns `Ok(None)` for them and
    /// the line falls through to [`Request`] parsing; the point is that
    /// ordinary request lines skip the extra parse entirely.
    pub fn looks_like(line: &str) -> bool {
        line.contains("\"stream\"")
    }

    /// Parse a header line. `Ok(None)` means "not a stream op" (route it
    /// to [`Request::from_json_line`]); `Err` means it *is* one but
    /// malformed (answer with an error line). Only the `"stream"`
    /// envelope parses — the deprecated `"op"` spellings were removed
    /// after their one-release grace period and now fall through to
    /// [`Request`] parsing, which rejects them as unknown ops.
    pub fn from_json_line(line: &str) -> Result<Option<StreamRequest>> {
        let obj = crate::util::json::parse(line)?;
        let name = match obj.get("stream").and_then(|s| s.as_str()) {
            Some(s) => s.to_string(),
            None => return Ok(None),
        };
        Ok(Some(match name.as_str() {
            "repl_snapshot" => StreamRequest::ReplSnapshot {
                trace: parse_opt_seq(&obj, "trace")?,
            },
            "repl_wal_tail" => {
                let shard = obj.req_usize("shard")?;
                let from_seq = parse_seq(&obj, "from_seq")?;
                let max_bytes = obj
                    .get("max_bytes")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(WAL_TAIL_DEFAULT_MAX_BYTES)
                    .max(1);
                let epoch = parse_opt_seq(&obj, "epoch")?;
                let trace = parse_opt_seq(&obj, "trace")?;
                StreamRequest::ReplWalTail { shard, from_seq, max_bytes, epoch, trace }
            }
            "metrics_text" => StreamRequest::MetricsText,
            "events" => StreamRequest::Events,
            other => bail!("unknown stream op '{other}'"),
        }))
    }

    /// Serialise in the canonical `"stream"` envelope (client side).
    pub fn to_json_line(&self) -> String {
        match self {
            StreamRequest::ReplSnapshot { trace } => match trace {
                // trace-less form is byte-identical to the pre-trace wire
                None => r#"{"stream":"repl_snapshot"}"#.to_string(),
                Some(t) => Json::obj(vec![
                    ("stream", Json::Str("repl_snapshot".into())),
                    // string: trace ids are u64 and must roundtrip exactly
                    ("trace", Json::Str(t.to_string())),
                ])
                .to_string(),
            },
            StreamRequest::ReplWalTail { shard, from_seq, max_bytes, epoch, trace } => {
                let mut pairs = vec![
                    ("stream", Json::Str("repl_wal_tail".into())),
                    ("shard", Json::Num(*shard as f64)),
                    // string: seqs are u64 and must roundtrip exactly through
                    // the f64-backed JSON model (like manifest seqs)
                    ("from_seq", Json::Str(from_seq.to_string())),
                    ("max_bytes", Json::Num(*max_bytes as f64)),
                ];
                if let Some(e) = epoch {
                    pairs.push(("epoch", Json::Str(e.to_string())));
                }
                if let Some(t) = trace {
                    pairs.push(("trace", Json::Str(t.to_string())));
                }
                Json::obj(pairs).to_string()
            }
            StreamRequest::MetricsText => r#"{"stream":"metrics_text"}"#.to_string(),
            StreamRequest::Events => r#"{"stream":"events"}"#.to_string(),
        }
    }

    /// The op name, for logs and counters.
    pub fn op(&self) -> &'static str {
        match self {
            StreamRequest::ReplSnapshot { .. } => "repl_snapshot",
            StreamRequest::ReplWalTail { .. } => "repl_wal_tail",
            StreamRequest::MetricsText => "metrics_text",
            StreamRequest::Events => "events",
        }
    }
}

/// Sequence field: accepts the canonical string form (exact u64) and the
/// numeric form old clients sent for small values. Semantics and error
/// text match the pre-envelope parser (`replica::seq_field`) so malformed
/// requests keep drawing the same error lines.
fn parse_seq(obj: &Json, key: &str) -> Result<u64> {
    match obj.get(key) {
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("field '{key}' is not a u64")),
        Some(Json::Num(n)) if *n >= 0.0 => Ok(*n as u64),
        _ => bail!("missing/invalid sequence field '{key}'"),
    }
}

/// Optional sequence-shaped field (`epoch`, `trace`): absent is `None`;
/// present-but-malformed is an error, never silently ignored.
fn parse_opt_seq(obj: &Json, key: &str) -> Result<Option<u64>> {
    match obj.get(key) {
        Some(_) => Ok(Some(parse_seq(obj, key)?)),
        None => Ok(None),
    }
}

/// Dense `"vec": [..]` array → [`CatVector`]; length must equal the corpus
/// dimension.
fn parse_dense(arr: &[Json], expected_dim: usize) -> Result<CatVector> {
    let dense: Vec<u16> = arr.iter().map(|x| x.as_f64().unwrap_or(0.0) as u16).collect();
    if dense.len() != expected_dim {
        bail!("vector dim {} != corpus dim {}", dense.len(), expected_dim);
    }
    Ok(CatVector::from_dense(&dense))
}

/// Sparse `"idx"`/`"val"` arrays → [`CatVector`] with an already-validated
/// `dim` — shared by the single-request sparse form and `query_batch`
/// elements so coercion and validation cannot drift between them.
fn parse_sparse_pairs(obj: &Json, dim: usize) -> Result<CatVector> {
    let idx = obj.req_arr("idx")?;
    let val = obj.req_arr("val")?;
    if idx.len() != val.len() {
        bail!("idx/val length mismatch");
    }
    let pairs = idx
        .iter()
        .zip(val)
        .map(|(i, v)| {
            (
                i.as_f64().unwrap_or(0.0) as u32,
                v.as_f64().unwrap_or(0.0) as u16,
            )
        })
        .collect();
    Ok(CatVector::from_pairs(dim, pairs))
}

fn parse_vec(obj: &Json, expected_dim: usize) -> Result<CatVector> {
    if let Some(arr) = obj.get("vec").and_then(|v| v.as_arr()) {
        return parse_dense(arr, expected_dim);
    }
    // sparse form
    let dim = obj.req_usize("dim")?;
    if dim != expected_dim {
        bail!("vector dim {} != corpus dim {}", dim, expected_dim);
    }
    parse_sparse_pairs(obj, dim)
}

/// Optional `"ttl_ms"` field: absent or 0 means "no expiry".
fn parse_ttl(obj: &Json) -> u64 {
    obj.get("ttl_ms").and_then(|v| v.as_usize()).unwrap_or(0) as u64
}

/// Parse and validate the `k` field (default 10, must be ≥ 1).
fn parse_k(obj: &Json) -> Result<usize> {
    let k = obj.get("k").and_then(|k| k.as_usize()).unwrap_or(10);
    if k == 0 {
        bail!("k must be >= 1");
    }
    Ok(k)
}

impl Request {
    pub fn from_json_line(line: &str, expected_dim: usize) -> Result<Request> {
        Ok(Request::parse_with_trace(line, expected_dim)?.0)
    }

    /// Parse a request line together with its optional top-level
    /// `"trace"` field (string or numeric u64, like seqs). `None` means
    /// the line carried no trace — the server stamps its own
    /// per-connection id and the reply bytes are unchanged; a malformed
    /// trace is an error, never silently ignored.
    pub fn parse_with_trace(line: &str, expected_dim: usize) -> Result<(Request, Option<u64>)> {
        let obj = crate::util::json::parse(line)?;
        let trace = parse_opt_seq(&obj, "trace")?;
        Ok((Request::from_obj(&obj, expected_dim)?, trace))
    }

    fn from_obj(obj: &Json, expected_dim: usize) -> Result<Request> {
        let op = obj.req_str("op")?;
        Ok(match op {
            "insert" | "insert_sparse" => {
                let vec = parse_vec(obj, expected_dim)?;
                match parse_ttl(obj) {
                    0 => Request::Insert { vec },
                    ttl_ms => Request::InsertTtl { vec, ttl_ms },
                }
            }
            "delete" => Request::Delete {
                id: obj.req_usize("id")?,
            },
            "upsert" => Request::Upsert {
                id: obj.req_usize("id")?,
                vec: parse_vec(obj, expected_dim)?,
                ttl_ms: parse_ttl(obj),
            },
            "query" => Request::Query {
                vec: parse_vec(obj, expected_dim)?,
                k: parse_k(obj)?,
            },
            "query_batch" => {
                let k = parse_k(obj)?;
                let queries = obj.req_arr("queries")?;
                // the top-level `dim` is advisory — sparse elements are
                // corpus-dimensional by definition, dense elements carry
                // their own length. Validate it when present on a
                // non-empty batch (it is vacuous on an empty one:
                // serializers emit 0 with no first vector to read it
                // from), never require it.
                if let Some(dim) = obj.get("dim").and_then(|v| v.as_usize()) {
                    if !queries.is_empty() && dim != expected_dim {
                        bail!("vector dim {} != corpus dim {}", dim, expected_dim);
                    }
                }
                let vecs = queries
                    .iter()
                    .enumerate()
                    .map(|(qi, q)| {
                        if let Some(arr) = q.get("vec").and_then(|v| v.as_arr()) {
                            parse_dense(arr, expected_dim)
                        } else {
                            parse_sparse_pairs(q, expected_dim)
                        }
                        .map_err(|e| e.context(format!("query {qi}")))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Request::QueryBatch { vecs, k }
            }
            "distance" => Request::Distance {
                a: obj.req_usize("a")?,
                b: obj.req_usize("b")?,
            },
            "heatmap" => Request::Heatmap,
            "stats" => Request::Stats,
            "flush" => Request::Flush,
            "snapshot" => Request::Snapshot,
            "promote" => Request::Promote,
            "demote" => Request::Demote {
                epoch: parse_opt_seq(obj, "epoch")?,
            },
            "ping" => Request::Ping {
                epoch: parse_opt_seq(obj, "epoch")?,
            },
            "shutdown" => Request::Shutdown,
            other => bail!("unknown op '{other}'"),
        })
    }

    /// Serialise (used by the client library).
    pub fn to_json_line(&self) -> String {
        match self {
            Request::Insert { vec } => {
                // sparse form keeps high-dim requests small on the wire
                let (idx, val): (Vec<f64>, Vec<f64>) = vec
                    .entries()
                    .iter()
                    .map(|&(i, v)| (i as f64, v as f64))
                    .unzip();
                Json::obj(vec![
                    ("op", Json::Str("insert_sparse".into())),
                    ("dim", Json::Num(vec.dim() as f64)),
                    ("idx", Json::from_f64s(&idx)),
                    ("val", Json::from_f64s(&val)),
                ])
                .to_string()
            }
            Request::InsertTtl { vec, ttl_ms } => {
                let (idx, val): (Vec<f64>, Vec<f64>) = vec
                    .entries()
                    .iter()
                    .map(|&(i, v)| (i as f64, v as f64))
                    .unzip();
                Json::obj(vec![
                    ("op", Json::Str("insert_sparse".into())),
                    ("dim", Json::Num(vec.dim() as f64)),
                    ("idx", Json::from_f64s(&idx)),
                    ("val", Json::from_f64s(&val)),
                    ("ttl_ms", Json::Num(*ttl_ms as f64)),
                ])
                .to_string()
            }
            Request::Delete { id } => Json::obj(vec![
                ("op", Json::Str("delete".into())),
                ("id", Json::Num(*id as f64)),
            ])
            .to_string(),
            Request::Upsert { id, vec, ttl_ms } => {
                let (idx, val): (Vec<f64>, Vec<f64>) = vec
                    .entries()
                    .iter()
                    .map(|&(i, v)| (i as f64, v as f64))
                    .unzip();
                Json::obj(vec![
                    ("op", Json::Str("upsert".into())),
                    ("id", Json::Num(*id as f64)),
                    ("dim", Json::Num(vec.dim() as f64)),
                    ("idx", Json::from_f64s(&idx)),
                    ("val", Json::from_f64s(&val)),
                    ("ttl_ms", Json::Num(*ttl_ms as f64)),
                ])
                .to_string()
            }
            Request::Query { vec, k } => {
                let (idx, val): (Vec<f64>, Vec<f64>) = vec
                    .entries()
                    .iter()
                    .map(|&(i, v)| (i as f64, v as f64))
                    .unzip();
                Json::obj(vec![
                    ("op", Json::Str("query".into())),
                    ("dim", Json::Num(vec.dim() as f64)),
                    ("idx", Json::from_f64s(&idx)),
                    ("val", Json::from_f64s(&val)),
                    ("k", Json::Num(*k as f64)),
                ])
                .to_string()
            }
            Request::QueryBatch { vecs, k } => {
                let dim = vecs.first().map(|v| v.dim()).unwrap_or(0);
                let queries: Vec<Json> = vecs
                    .iter()
                    .map(|vec| {
                        let (idx, val): (Vec<f64>, Vec<f64>) = vec
                            .entries()
                            .iter()
                            .map(|&(i, v)| (i as f64, v as f64))
                            .unzip();
                        Json::obj(vec![
                            ("idx", Json::from_f64s(&idx)),
                            ("val", Json::from_f64s(&val)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("op", Json::Str("query_batch".into())),
                    ("dim", Json::Num(dim as f64)),
                    ("k", Json::Num(*k as f64)),
                    ("queries", Json::Arr(queries)),
                ])
                .to_string()
            }
            Request::Distance { a, b } => Json::obj(vec![
                ("op", Json::Str("distance".into())),
                ("a", Json::Num(*a as f64)),
                ("b", Json::Num(*b as f64)),
            ])
            .to_string(),
            Request::Heatmap => r#"{"op":"heatmap"}"#.to_string(),
            Request::Stats => r#"{"op":"stats"}"#.to_string(),
            Request::Flush => r#"{"op":"flush"}"#.to_string(),
            Request::Snapshot => r#"{"op":"snapshot"}"#.to_string(),
            Request::Promote => r#"{"op":"promote"}"#.to_string(),
            Request::Demote { epoch } => match epoch {
                None => r#"{"op":"demote"}"#.to_string(),
                Some(e) => Json::obj(vec![
                    ("op", Json::Str("demote".into())),
                    // string: epochs are u64 and must roundtrip exactly
                    ("epoch", Json::Str(e.to_string())),
                ])
                .to_string(),
            },
            Request::Ping { epoch } => match epoch {
                None => r#"{"op":"ping"}"#.to_string(),
                Some(e) => Json::obj(vec![
                    ("op", Json::Str("ping".into())),
                    ("epoch", Json::Str(e.to_string())),
                ])
                .to_string(),
            },
            Request::Shutdown => r#"{"op":"shutdown"}"#.to_string(),
        }
    }

    /// Serialise with an explicit trace id (`Client::with_trace`).
    /// `trace == 0` reproduces [`Request::to_json_line`] byte-for-byte;
    /// otherwise the canonical line gains a string-encoded `"trace"`
    /// field in its lexicographic key position.
    pub fn to_json_line_with(&self, trace: u64) -> String {
        let line = self.to_json_line();
        if trace == 0 {
            return line;
        }
        match crate::util::json::parse(&line) {
            Ok(Json::Obj(mut m)) => {
                m.insert("trace".to_string(), Json::Str(trace.to_string()));
                Json::Obj(m).to_string()
            }
            // unreachable: every request serialises as a JSON object
            _ => line,
        }
    }

    /// The canonical wire `"op"` value — used by trace-correlation logs
    /// (`server/traced_op`) so a grep for a trace id also says what the
    /// request was.
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Insert { .. } | Request::InsertTtl { .. } => "insert",
            Request::Delete { .. } => "delete",
            Request::Upsert { .. } => "upsert",
            Request::Query { .. } => "query",
            Request::QueryBatch { .. } => "query_batch",
            Request::Distance { .. } => "distance",
            Request::Heatmap => "heatmap",
            Request::Stats => "stats",
            Request::Flush => "flush",
            Request::Snapshot => "snapshot",
            Request::Promote => "promote",
            Request::Demote { .. } => "demote",
            Request::Ping { .. } => "ping",
            Request::Shutdown => "shutdown",
        }
    }
}

impl Response {
    pub fn to_json_line(&self) -> String {
        match self {
            Response::Inserted { id, epoch } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("id", Json::Num(*id as f64)),
                ];
                if let Some(e) = epoch {
                    // string: epochs are u64 and must roundtrip exactly
                    pairs.push(("epoch", Json::Str(e.to_string())));
                }
                Json::obj(pairs).to_string()
            }
            Response::Deleted { id, epoch } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("deleted", Json::Num(*id as f64)),
                ];
                if let Some(e) = epoch {
                    pairs.push(("epoch", Json::Str(e.to_string())));
                }
                Json::obj(pairs).to_string()
            }
            Response::Upserted { id, epoch } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("upserted", Json::Num(*id as f64)),
                ];
                if let Some(e) = epoch {
                    pairs.push(("epoch", Json::Str(e.to_string())));
                }
                Json::obj(pairs).to_string()
            }
            Response::Hits { hits } => {
                let arr = hits
                    .iter()
                    .map(|h| {
                        Json::obj(vec![
                            ("id", Json::Num(h.id as f64)),
                            ("dist", Json::Num(h.dist)),
                        ])
                    })
                    .collect();
                Json::obj(vec![("ok", Json::Bool(true)), ("hits", Json::Arr(arr))]).to_string()
            }
            Response::HitsBatch { results } => {
                let arr = results
                    .iter()
                    .map(|hits| {
                        Json::Arr(
                            hits.iter()
                                .map(|h| {
                                    Json::obj(vec![
                                        ("id", Json::Num(h.id as f64)),
                                        ("dist", Json::Num(h.dist)),
                                    ])
                                })
                                .collect(),
                        )
                    })
                    .collect();
                Json::obj(vec![("ok", Json::Bool(true)), ("results", Json::Arr(arr))])
                    .to_string()
            }
            Response::Distance { dist } => {
                Json::obj(vec![("ok", Json::Bool(true)), ("dist", Json::Num(*dist))]).to_string()
            }
            Response::Heatmap { n, values } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("n", Json::Num(*n as f64)),
                ("values", Json::from_f64s(values)),
            ])
            .to_string(),
            Response::Stats { fields } => {
                let mut pairs = vec![("ok", Json::Bool(true))];
                let owned: Vec<(String, Json)> = fields
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect();
                let mut obj = std::collections::BTreeMap::new();
                for (k, v) in pairs.drain(..) {
                    obj.insert(k.to_string(), v);
                }
                for (k, v) in owned {
                    obj.insert(k, v);
                }
                Json::Obj(obj).to_string()
            }
            Response::Flushed => r#"{"ok":true,"flushed":true}"#.to_string(),
            Response::Snapshotted { generation } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("snapshot_generation", Json::Num(*generation as f64)),
            ])
            .to_string(),
            Response::Promoted { applied_seqs, epoch } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("promoted", Json::Bool(true)),
                // strings: seqs and epochs are u64 and must roundtrip
                // exactly through the f64-backed JSON model (like
                // manifest seqs)
                ("epoch", Json::Str(epoch.to_string())),
                (
                    "applied_seqs",
                    Json::Arr(
                        applied_seqs
                            .iter()
                            .map(|s| Json::Str(s.to_string()))
                            .collect(),
                    ),
                ),
            ])
            .to_string(),
            Response::Demoted { epoch } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("demoted", Json::Bool(true)),
                ("epoch", Json::Str(epoch.to_string())),
            ])
            .to_string(),
            Response::Pong { epoch } => match epoch {
                None => r#"{"ok":true,"pong":true}"#.to_string(),
                Some(e) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("pong", Json::Bool(true)),
                    ("epoch", Json::Str(e.to_string())),
                ])
                .to_string(),
            },
            Response::ShuttingDown => r#"{"ok":true,"shutdown":true}"#.to_string(),
            Response::Error { message } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(message.clone())),
            ])
            .to_string(),
        }
    }

    pub fn from_json_line(line: &str) -> Result<Response> {
        let obj = crate::util::json::parse(line)?;
        let ok = obj.get("ok").and_then(|b| b.as_bool()).unwrap_or(false);
        if !ok {
            return Ok(Response::Error {
                message: obj
                    .get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
            });
        }
        // string-encoded, like seqs; absent on non-durable replies
        let epoch = obj
            .get("epoch")
            .and_then(|v| v.as_str())
            .and_then(|s| s.parse::<u64>().ok());
        if let Some(id) = obj.get("id").and_then(|v| v.as_usize()) {
            return Ok(Response::Inserted { id, epoch });
        }
        let parse_hits = |hits: &[Json]| -> Vec<Hit> {
            hits.iter()
                .map(|h| Hit {
                    id: h.get("id").and_then(|v| v.as_usize()).unwrap_or(0),
                    dist: h.get("dist").and_then(|v| v.as_f64()).unwrap_or(0.0),
                })
                .collect()
        };
        if let Some(hits) = obj.get("hits").and_then(|v| v.as_arr()) {
            return Ok(Response::Hits {
                hits: parse_hits(hits),
            });
        }
        if let Some(results) = obj.get("results").and_then(|v| v.as_arr()) {
            return Ok(Response::HitsBatch {
                results: results
                    .iter()
                    .map(|hits| parse_hits(hits.as_arr().unwrap_or(&[])))
                    .collect(),
            });
        }
        if let Some(dist) = obj.get("dist").and_then(|v| v.as_f64()) {
            return Ok(Response::Distance { dist });
        }
        if let (Some(n), Some(values)) = (
            obj.get("n").and_then(|v| v.as_usize()),
            obj.get("values").and_then(|v| v.as_arr()),
        ) {
            return Ok(Response::Heatmap {
                n,
                values: values.iter().filter_map(|x| x.as_f64()).collect(),
            });
        }
        if obj.get("pong").is_some() {
            return Ok(Response::Pong { epoch });
        }
        if obj.get("shutdown").is_some() {
            return Ok(Response::ShuttingDown);
        }
        if obj.get("flushed").is_some() {
            return Ok(Response::Flushed);
        }
        if obj.get("promoted").is_some() {
            let applied_seqs = obj
                .get("applied_seqs")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().and_then(|s| s.parse::<u64>().ok()))
                .collect();
            // pre-epoch servers omitted the field; 0 marks "unknown"
            return Ok(Response::Promoted {
                applied_seqs,
                epoch: epoch.unwrap_or(0),
            });
        }
        if obj.get("demoted").is_some() {
            return Ok(Response::Demoted {
                epoch: epoch.unwrap_or(0),
            });
        }
        // before the stats fallback: these replies are themselves numeric
        // fields and would otherwise be swallowed as one-field Stats
        if let Some(id) = obj.get("deleted").and_then(|v| v.as_usize()) {
            return Ok(Response::Deleted { id, epoch });
        }
        if let Some(id) = obj.get("upserted").and_then(|v| v.as_usize()) {
            return Ok(Response::Upserted { id, epoch });
        }
        if let Some(generation) = obj.get("snapshot_generation").and_then(|v| v.as_usize()) {
            return Ok(Response::Snapshotted {
                generation: generation as u64,
            });
        }
        // stats: everything numeric except ok
        if let Json::Obj(m) = &obj {
            let fields = m
                .iter()
                .filter(|(k, _)| k.as_str() != "ok")
                .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                .collect::<Vec<_>>();
            if !fields.is_empty() {
                return Ok(Response::Stats { fields });
            }
        }
        bail!("unrecognised response: {line}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_insert() {
        let v = CatVector::from_dense(&[0, 3, 0, 0, 9]);
        let req = Request::Insert { vec: v };
        let line = req.to_json_line();
        let back = Request::from_json_line(&line, 5).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn request_roundtrip_mutations() {
        let v = CatVector::from_dense(&[0, 3, 0, 0, 9]);
        for req in [
            Request::InsertTtl {
                vec: v.clone(),
                ttl_ms: 60_000,
            },
            Request::Delete { id: 17 },
            Request::Upsert {
                id: 17,
                vec: v.clone(),
                ttl_ms: 0,
            },
            Request::Upsert {
                id: 4,
                vec: v,
                ttl_ms: 250,
            },
        ] {
            let back = Request::from_json_line(&req.to_json_line(), 5).unwrap();
            assert_eq!(back, req);
        }
        // a zero/absent ttl_ms on the insert ops is the plain untimed insert
        let plain = r#"{"op":"insert","vec":[0,2,0],"ttl_ms":0}"#;
        assert!(matches!(
            Request::from_json_line(plain, 3).unwrap(),
            Request::Insert { .. }
        ));
        // upsert validates the vector like insert does
        let bad = r#"{"op":"upsert","id":3,"vec":[1,2]}"#;
        assert!(Request::from_json_line(bad, 3).is_err());
        // delete requires the id
        assert!(Request::from_json_line(r#"{"op":"delete"}"#, 3).is_err());
    }

    #[test]
    fn request_roundtrip_query() {
        let v = CatVector::from_dense(&[1, 0, 2]);
        let req = Request::Query { vec: v, k: 7 };
        let back = Request::from_json_line(&req.to_json_line(), 3).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn request_roundtrip_query_batch() {
        let vecs = vec![
            CatVector::from_dense(&[1, 0, 2]),
            CatVector::from_dense(&[0, 3, 0]),
        ];
        let req = Request::QueryBatch { vecs, k: 4 };
        let back = Request::from_json_line(&req.to_json_line(), 3).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn k_zero_rejected_at_protocol_layer() {
        // Regression: k == 0 used to reach the top-k kernel and underflow
        // hits[k - 1], panicking the coordinator's shard workers.
        let q = r#"{"op":"query","dim":3,"idx":[0],"val":[1],"k":0}"#;
        let err = Request::from_json_line(q, 3).unwrap_err();
        assert!(err.to_string().contains("k must be >= 1"), "{err:#}");
        let qb = r#"{"op":"query_batch","dim":3,"k":0,"queries":[{"idx":[0],"val":[1]}]}"#;
        assert!(Request::from_json_line(qb, 3).is_err());
    }

    #[test]
    fn query_batch_empty_roundtrips() {
        // an empty batch serializes dim 0 (no first vector to read it
        // from) and must still parse — the reply is simply no results
        let req = Request::QueryBatch {
            vecs: Vec::new(),
            k: 2,
        };
        let back = Request::from_json_line(&req.to_json_line(), 3).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn query_batch_accepts_dense_elements() {
        let q = r#"{"op":"query_batch","dim":3,"k":2,"queries":[{"vec":[1,0,2]},{"idx":[1],"val":[3]}]}"#;
        match Request::from_json_line(q, 3).unwrap() {
            Request::QueryBatch { vecs, k: 2 } => {
                assert_eq!(vecs[0], CatVector::from_dense(&[1, 0, 2]));
                assert_eq!(vecs[1], CatVector::from_pairs(3, vec![(1, 3)]));
            }
            other => panic!("{other:?}"),
        }
        // an all-dense batch needs no top-level dim at all (mirrors the
        // single-query dense form)
        let no_dim = r#"{"op":"query_batch","k":2,"queries":[{"vec":[1,0,2]}]}"#;
        assert!(Request::from_json_line(no_dim, 3).is_ok());
    }

    #[test]
    fn query_batch_validates_per_query() {
        // wrong corpus dim
        let bad_dim = r#"{"op":"query_batch","dim":9,"k":2,"queries":[{"idx":[0],"val":[1]}]}"#;
        assert!(Request::from_json_line(bad_dim, 3).is_err());
        // ragged idx/val inside one element
        let ragged = r#"{"op":"query_batch","dim":3,"k":2,"queries":[{"idx":[0,1],"val":[1]}]}"#;
        assert!(Request::from_json_line(ragged, 3).is_err());
        // missing idx
        let missing = r#"{"op":"query_batch","dim":3,"k":2,"queries":[{"val":[1]}]}"#;
        assert!(Request::from_json_line(missing, 3).is_err());
    }

    #[test]
    fn dense_insert_form_accepted() {
        let r = Request::from_json_line(r#"{"op":"insert","vec":[0,2,0,1]}"#, 4).unwrap();
        match r {
            Request::Insert { vec } => {
                assert_eq!(vec.nnz(), 2);
                assert_eq!(vec.get(1), 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn dim_mismatch_rejected() {
        assert!(Request::from_json_line(r#"{"op":"insert","vec":[1,2]}"#, 3).is_err());
        assert!(
            Request::from_json_line(r#"{"op":"insert_sparse","dim":9,"idx":[0],"val":[1]}"#, 3)
                .is_err()
        );
    }

    #[test]
    fn unknown_op_rejected() {
        assert!(Request::from_json_line(r#"{"op":"frobnicate"}"#, 3).is_err());
    }

    #[test]
    fn flush_and_snapshot_ops_roundtrip() {
        for req in [
            Request::Flush,
            Request::Snapshot,
            Request::Promote,
            Request::Ping { epoch: None },
            Request::Ping { epoch: Some((1u64 << 55) + 3) },
            Request::Demote { epoch: None },
            Request::Demote { epoch: Some(9) },
        ] {
            let back = Request::from_json_line(&req.to_json_line(), 3).unwrap();
            assert_eq!(back, req);
        }
        // the epoch-less ping serialises byte-identically to the
        // pre-epoch protocol (pinned by tests/protocol_compat.rs)
        assert_eq!(
            Request::Ping { epoch: None }.to_json_line(),
            r#"{"op":"ping"}"#
        );
        // a snapshot reply must parse as Snapshotted, not a one-field Stats
        let back =
            Response::from_json_line(r#"{"ok":true,"snapshot_generation":9}"#).unwrap();
        assert_eq!(back, Response::Snapshotted { generation: 9 });
    }

    #[test]
    fn promoted_response_roundtrips_exact_seqs() {
        // beyond f64's 2^53 integer range: the string encoding must hold
        let resp = Response::Promoted {
            applied_seqs: vec![(1u64 << 55) + 1, 0, 42],
            epoch: (1u64 << 55) + 7,
        };
        let back = Response::from_json_line(&resp.to_json_line()).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Inserted { id: 42, epoch: None },
            Response::Inserted { id: 42, epoch: Some(2) },
            // like snapshot_generation, these must not be swallowed by
            // the one-field Stats fallback
            Response::Deleted { id: 7, epoch: None },
            Response::Deleted { id: 7, epoch: Some(3) },
            Response::Upserted { id: 0, epoch: None },
            Response::Upserted { id: 0, epoch: Some(1) },
            Response::Hits {
                hits: vec![
                    Hit { id: 1, dist: 2.5 },
                    Hit { id: 9, dist: 11.0 },
                ],
            },
            Response::HitsBatch {
                results: vec![
                    vec![Hit { id: 3, dist: 0.5 }],
                    vec![],
                    vec![Hit { id: 0, dist: 1.0 }, Hit { id: 8, dist: 4.5 }],
                ],
            },
            Response::Distance { dist: 3.25 },
            Response::Flushed,
            Response::Snapshotted { generation: 4 },
            Response::Promoted {
                applied_seqs: vec![3, 9],
                epoch: 2,
            },
            Response::Demoted { epoch: 4 },
            Response::Pong { epoch: None },
            Response::Pong { epoch: Some(5) },
            Response::ShuttingDown,
            Response::Error {
                message: "nope".into(),
            },
            Response::Heatmap {
                n: 2,
                values: vec![0.0, 1.0, 1.0, 0.0],
            },
        ] {
            let line = resp.to_json_line();
            let back = Response::from_json_line(&line).unwrap();
            assert_eq!(back, resp, "line {line}");
        }
    }

    #[test]
    fn stats_roundtrip() {
        let resp = Response::Stats {
            fields: vec![("inserts".into(), 5.0), ("queries".into(), 2.0)],
        };
        let back = Response::from_json_line(&resp.to_json_line()).unwrap();
        match back {
            Response::Stats { fields } => {
                assert!(fields.contains(&("inserts".to_string(), 5.0)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stream_envelope_roundtrips() {
        for req in [
            StreamRequest::ReplSnapshot { trace: None },
            StreamRequest::ReplSnapshot { trace: Some(77) },
            StreamRequest::ReplWalTail {
                shard: 2,
                from_seq: u64::MAX - 1,
                max_bytes: 4096,
                epoch: None,
                trace: None,
            },
            StreamRequest::ReplWalTail {
                shard: 0,
                from_seq: 3,
                max_bytes: 4096,
                epoch: Some((1u64 << 55) + 9),
                trace: Some((1u64 << 55) + 1),
            },
            StreamRequest::MetricsText,
            StreamRequest::Events,
        ] {
            let line = req.to_json_line();
            assert!(StreamRequest::looks_like(&line), "sniff missed {line}");
            let back = StreamRequest::from_json_line(&line).unwrap();
            assert_eq!(back, Some(req), "line {line}");
        }
    }

    #[test]
    fn stream_rejects_deprecated_op_spellings() {
        // The PR 5–7 era `"op"` spellings finished their one-release
        // deprecation window: they are no longer stream ops (Ok(None) →
        // fall through to Request parsing, which rejects them as unknown
        // ops — the error lines are pinned by tests/protocol_compat.rs).
        for line in [
            r#"{"op":"repl_snapshot"}"#,
            r#"{"op":"repl_wal_tail","shard":1,"from_seq":"7"}"#,
            r#"{"op":"metrics_text"}"#,
        ] {
            assert_eq!(StreamRequest::from_json_line(line).unwrap(), None, "line {line}");
            let err = Request::from_json_line(line, 3).unwrap_err();
            assert!(err.to_string().contains("unknown op"), "line {line}: {err:#}");
        }
    }

    #[test]
    fn stream_parse_ignores_ordinary_requests() {
        for line in [
            r#"{"op":"insert","vec":[0,1,2]}"#,
            r#"{"op":"stats"}"#,
            r#"{"op":"query","idx":[0],"val":[1],"dim":3,"k":1}"#,
        ] {
            assert_eq!(StreamRequest::from_json_line(line).unwrap(), None, "line {line}");
        }
        // the sniff may false-positive (e.g. a string *value* that is
        // exactly "stream") — parsing must still fall through cleanly;
        // ordinary request lines don't trip it at all
        assert!(!StreamRequest::looks_like(r#"{"op":"insert","vec":[0,1,2]}"#));
        let fp = r#"{"note":"stream","op":"x"}"#;
        assert!(StreamRequest::looks_like(fp));
        assert_eq!(StreamRequest::from_json_line(fp).unwrap(), None);
    }

    #[test]
    fn stream_wal_tail_field_forms_and_errors() {
        // numeric from_seq (old clients) and explicit max_bytes
        let line = r#"{"stream":"repl_wal_tail","shard":0,"from_seq":12,"max_bytes":64}"#;
        assert_eq!(
            StreamRequest::from_json_line(line).unwrap(),
            Some(StreamRequest::ReplWalTail {
                shard: 0,
                from_seq: 12,
                max_bytes: 64,
                epoch: None,
                trace: None,
            })
        );
        // a malformed epoch is an error, not silently ignored
        let bad_epoch = r#"{"stream":"repl_wal_tail","shard":0,"from_seq":"0","epoch":"x"}"#;
        assert!(StreamRequest::from_json_line(bad_epoch).is_err());
        // max_bytes is clamped to at least one byte so a tail always makes
        // progress
        let clamped = r#"{"stream":"repl_wal_tail","shard":0,"from_seq":"0","max_bytes":0}"#;
        match StreamRequest::from_json_line(clamped).unwrap() {
            Some(StreamRequest::ReplWalTail { max_bytes, .. }) => assert_eq!(max_bytes, 1),
            other => panic!("{other:?}"),
        }
        // malformed stream ops are errors, not pass-throughs
        assert!(StreamRequest::from_json_line(r#"{"stream":"repl_wal_tail"}"#).is_err());
        let bad_seq = r#"{"stream":"repl_wal_tail","shard":0,"from_seq":-3}"#;
        assert!(StreamRequest::from_json_line(bad_seq).is_err());
        assert!(StreamRequest::from_json_line(r#"{"stream":"no_such_op"}"#).is_err());
    }

    #[test]
    fn trace_field_parses_on_every_request_shape() {
        // string and numeric forms, like seqs
        let (req, trace) =
            Request::parse_with_trace(r#"{"op":"ping","trace":"12000007"}"#, 3).unwrap();
        assert_eq!(req, Request::Ping { epoch: None });
        assert_eq!(trace, Some(12_000_007));
        let (_, trace) = Request::parse_with_trace(r#"{"op":"stats","trace":42}"#, 3).unwrap();
        assert_eq!(trace, Some(42));
        // exact u64 round-trip through the string form
        let big = u64::MAX - 3;
        let line = format!(r#"{{"op":"heatmap","trace":"{big}"}}"#);
        assert_eq!(Request::parse_with_trace(&line, 3).unwrap().1, Some(big));
        // trace-less lines answer None — the server stamps its own
        let (req, trace) = Request::parse_with_trace(r#"{"op":"ping"}"#, 3).unwrap();
        assert_eq!(req, Request::Ping { epoch: None });
        assert_eq!(trace, None);
        // a malformed trace is an error, not silently dropped
        let err = Request::parse_with_trace(r#"{"op":"ping","trace":"x"}"#, 3).unwrap_err();
        assert!(err.to_string().contains("field 'trace' is not a u64"), "{err:#}");
        // writes carry it too
        let (req, trace) = Request::parse_with_trace(
            r#"{"op":"insert","trace":"9","vec":[0,2,0]}"#,
            3,
        )
        .unwrap();
        assert!(matches!(req, Request::Insert { .. }));
        assert_eq!(trace, Some(9));
    }

    #[test]
    fn to_json_line_with_trace_is_additive() {
        // trace 0 reproduces the canonical line byte-for-byte
        let req = Request::Ping { epoch: None };
        assert_eq!(req.to_json_line_with(0), req.to_json_line());
        // nonzero trace lands in lexicographic key position and parses back
        assert_eq!(req.to_json_line_with(7), r#"{"op":"ping","trace":"7"}"#);
        let q = Request::Query {
            vec: CatVector::from_dense(&[1, 0, 2]),
            k: 3,
        };
        let line = q.to_json_line_with(55);
        let (back, trace) = Request::parse_with_trace(&line, 3).unwrap();
        assert_eq!(back, q);
        assert_eq!(trace, Some(55));
        // stream envelopes: the trace-less spelling is byte-stable
        assert_eq!(
            StreamRequest::ReplSnapshot { trace: None }.to_json_line(),
            r#"{"stream":"repl_snapshot"}"#
        );
        assert_eq!(StreamRequest::Events.to_json_line(), r#"{"stream":"events"}"#);
        assert_eq!(
            StreamRequest::ReplSnapshot { trace: Some(3) }.to_json_line(),
            r#"{"stream":"repl_snapshot","trace":"3"}"#
        );
    }

    #[test]
    fn write_opts_default_matches_plain_write() {
        assert_eq!(WriteOpts::default(), WriteOpts { ttl_ms: 0, trace: 0 });
        assert_eq!(WriteOpts::ttl(250), WriteOpts { ttl_ms: 250, trace: 0 });
    }
}
