//! Dynamic batcher: the serving-path component that turns a stream of
//! single-vector writes into sketching batches.
//!
//! All mutating ops — insert, insert-with-TTL, delete, upsert — flow
//! through the *same* bounded queue. That is what keeps per-client write
//! order: a client's `insert x; delete x` lands in queue order, flushes
//! in queue order, and is acked in queue order. A batch that is pure
//! untimed inserts takes the blocked fast path
//! (`begin_insert_batch`); any batch containing a delete, upsert, or TTL
//! insert goes through the general mutation path
//! (`begin_mutation_batch`), which preserves intra-batch op order. Both
//! share the group-commit window, so mixed write streams still coalesce
//! their fsyncs.
//!
//! Flush policy (vLLM-style): a batch is dispatched when it reaches
//! `max_batch` items OR the oldest queued item has waited `max_delay`.
//! The queue is bounded (`queue_cap`); submitters block when it is full —
//! backpressure propagates to the TCP layer.
//!
//! Ack-wait pipelining: a flushed batch is *placed*
//! ([`crate::coordinator::store::ShardedStore::begin_insert_batch`]) on
//! the batcher thread, but its durability wait — the group-commit window
//! flush under `--fsync always` — and the client replies are handed to a
//! dedicated completion thread as an `(items, ids, ticket)` job. The
//! batcher thread therefore sketches batch N+1 while batch N's fsync
//! window is in flight, so a single client's insert stream can saturate a
//! commit window instead of serialising on it. The completion channel is
//! FIFO and the completion thread settles jobs in order, so replies keep
//! batch order (and with it per-client insert order); it is also bounded,
//! so a stalled disk backpressures the batcher rather than queueing
//! unacked batches without limit. A WAL commit failure still reaches
//! every waiter of exactly the failed batch as an insert error.
//!
//! The backend is pluggable: the XLA engine (fixed-batch AOT artifact,
//! padded) when the corpus configuration matches the artifacts, else the
//! native fused sketcher.
//!
//! Observability: every flush records the write-path stage histograms
//! (`stage_write_queue` per item; `stage_write_sketch`/`_place`/`_wal`/
//! `_fsync`/`_reply` per batch — the latter three inside the store and
//! the settle path), all lock-free ([`crate::obs::Stages`]). Items
//! breaching `--slow-op-ms` emit one structured `batcher/slow_op` event
//! carrying the trace id the server stamped on the ticket and the full
//! stage breakdown.

use super::metrics::Metrics;
use super::protocol::WriteOpts;
use super::server::now_ms;
use super::store::{InsertTicket, MutationOp, MutationResult, MutationTicket, ShardedStore};
use crate::data::CatVector;
use crate::obs::{self, log as obs_log};
use crate::runtime::XlaHandle;
use crate::sketch::{BitVec, CabinSketcher};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How many placed-but-unacked batches may wait on the completion thread
/// before the batcher blocks (a stalled disk must backpressure ingest,
/// not queue unacked work without bound).
const ACK_PIPELINE_DEPTH: usize = 64;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 4096,
        }
    }
}

/// Which sketching backend executes a flushed batch.
pub enum SketchBackend {
    Native(CabinSketcher),
    /// XLA artifact path (thread-confined worker); falls back to the
    /// bundled native sketcher for oversize batches or worker errors.
    Xla(XlaHandle, CabinSketcher),
}

impl SketchBackend {
    pub fn sketch_batch(&self, batch: &[CatVector], metrics: &Metrics) -> Vec<BitVec> {
        match self {
            SketchBackend::Native(sk) => {
                metrics.native_batches.fetch_add(1, Ordering::Relaxed);
                batch.iter().map(|p| sk.sketch(p)).collect()
            }
            SketchBackend::Xla(handle, fallback) => {
                if batch.len() <= handle.manifest.m {
                    match handle.cabin_sketch(batch.to_vec()) {
                        Ok(s) => {
                            metrics.xla_batches.fetch_add(1, Ordering::Relaxed);
                            return s;
                        }
                        Err(e) => obs_log::warn(
                            "batcher",
                            "xla_fallback",
                            &[("error", obs_log::V::s(format!("{e:#}")))],
                        ),
                    }
                }
                metrics.native_batches.fetch_add(1, Ordering::Relaxed);
                batch.iter().map(|p| fallback.sketch(p)).collect()
            }
        }
    }

    pub fn sketcher(&self) -> &CabinSketcher {
        match self {
            SketchBackend::Native(sk) => sk,
            SketchBackend::Xla(_, sk) => sk,
        }
    }
}

/// A submitted write's reply: the affected id (assigned for inserts,
/// echoed for delete/upsert), or the error that prevented the ack —
/// either a per-op failure (delete of an id the store does not hold) or
/// a durability failure (WAL commit error — the rows may be in memory
/// but were NOT committed, so the client must not be told they are safe).
pub type InsertReply = Result<usize, String>;

/// One queued write. Everything flows through the same queue so replies
/// keep per-client submission order across op kinds.
enum PendingOp {
    /// `deadline` is an absolute unix-millis expiry, 0 = none (the server
    /// converts the wire's relative `ttl_ms` before submitting).
    Insert { vec: CatVector, deadline: u64 },
    Delete { id: usize },
    Upsert { id: usize, vec: CatVector, deadline: u64 },
}

impl PendingOp {
    /// Op kind for slow-op records.
    fn kind(&self) -> &'static str {
        match self {
            PendingOp::Insert { .. } => "insert",
            PendingOp::Delete { .. } => "delete",
            PendingOp::Upsert { .. } => "upsert",
        }
    }
}

struct Pending {
    op: PendingOp,
    enqueued: Instant,
    /// Connection-scoped trace id stamped by the server (0 = untraced —
    /// library callers and benches). Flows into slow-op records so a
    /// breach can be matched back to its connection and request.
    trace: u64,
    reply: SyncSender<InsertReply>,
}

/// One mutation in submission form — the shape every submit entry point
/// collapses into ([`BatchSubmitter::submit_with`]). Expiry travels in
/// the accompanying [`WriteOpts`] as a *relative* `ttl_ms`; the submit
/// path stamps the absolute deadline once, on the primary, so the WAL
/// and every replica carry deadlines, never TTLs.
#[derive(Clone, Debug)]
pub enum WriteOp {
    Insert { vec: CatVector },
    Delete { id: usize },
    Upsert { id: usize, vec: CatVector },
}

/// Handle used by connection threads to submit inserts.
#[derive(Clone)]
pub struct BatchSubmitter {
    tx: SyncSender<Pending>,
}

impl BatchSubmitter {
    fn submit(&self, op: PendingOp, trace: u64) -> anyhow::Result<usize> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(Pending {
                op,
                enqueued: Instant::now(),
                trace,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("batcher stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batcher dropped reply"))?
            .map_err(|msg| anyhow::anyhow!(msg))
    }

    /// The one blocking submit entry point: queue `op` with per-write
    /// options and return the affected id once the batch the item landed
    /// in has been flushed *and* (on durable stores) its WAL commit
    /// landed. A durability failure comes back as `Err`, not an id.
    ///
    /// `opts.ttl_ms` (relative, 0 = none; on upsert, 0 *clears* any
    /// previous deadline) is stamped into an absolute unix-millis
    /// deadline here; `opts.trace` rides the ticket into slow-op records.
    /// `WriteOpts::default()` reproduces the historical plain write.
    pub fn submit_with(&self, op: WriteOp, opts: &WriteOpts) -> anyhow::Result<usize> {
        let deadline = match opts.ttl_ms {
            0 => 0, // no expiry (and on upsert: clear any previous one)
            t => now_ms().saturating_add(t),
        };
        let pending = match op {
            WriteOp::Insert { vec } => PendingOp::Insert { vec, deadline },
            WriteOp::Delete { id } => PendingOp::Delete { id },
            WriteOp::Upsert { id, vec } => PendingOp::Upsert { id, vec, deadline },
        };
        self.submit(pending, opts.trace)
    }

    /// Plain blocking insert. Shim for
    /// `submit_with(WriteOp::Insert { vec }, &WriteOpts::default())`.
    pub fn insert(&self, vec: CatVector) -> anyhow::Result<usize> {
        self.submit(PendingOp::Insert { vec, deadline: 0 }, 0)
    }

    /// Deprecated spelling of [`BatchSubmitter::submit_with`] with a bare
    /// trace id; goes away after one release.
    pub fn insert_traced(&self, vec: CatVector, trace: u64) -> anyhow::Result<usize> {
        self.submit(PendingOp::Insert { vec, deadline: 0 }, trace)
    }

    /// Insert with an already-absolute unix-millis expiry deadline
    /// (0 = none) — the replica apply path and restarts use this to
    /// preserve WAL-carried deadlines exactly; wire-facing callers want
    /// [`BatchSubmitter::submit_with`] and a relative TTL instead.
    pub fn insert_with_deadline(&self, vec: CatVector, deadline: u64) -> anyhow::Result<usize> {
        self.submit(PendingOp::Insert { vec, deadline }, 0)
    }

    /// Deprecated spelling of [`BatchSubmitter::insert_with_deadline`]
    /// with a trace id; goes away after one release.
    pub fn insert_with_deadline_traced(
        &self,
        vec: CatVector,
        deadline: u64,
        trace: u64,
    ) -> anyhow::Result<usize> {
        self.submit(PendingOp::Insert { vec, deadline }, trace)
    }

    /// Delete a live id; the reply echoes the id. Deleting an id the
    /// store does not hold is a per-op error, not a batch failure. Shim
    /// for `submit_with(WriteOp::Delete { id }, &WriteOpts::default())`.
    pub fn delete(&self, id: usize) -> anyhow::Result<usize> {
        self.submit(PendingOp::Delete { id }, 0)
    }

    /// Deprecated spelling of [`BatchSubmitter::submit_with`] with a bare
    /// trace id; goes away after one release.
    pub fn delete_traced(&self, id: usize, trace: u64) -> anyhow::Result<usize> {
        self.submit(PendingOp::Delete { id }, trace)
    }

    /// Replace the vector behind `id` (or resurrect a deleted id), with
    /// an already-absolute expiry deadline (0 = clear any expiry) — see
    /// [`BatchSubmitter::insert_with_deadline`] for when absolute
    /// deadlines are the right form.
    pub fn upsert(&self, id: usize, vec: CatVector, deadline: u64) -> anyhow::Result<usize> {
        self.submit(PendingOp::Upsert { id, vec, deadline }, 0)
    }

    /// Deprecated spelling of [`BatchSubmitter::upsert`] with a trace id;
    /// goes away after one release.
    pub fn upsert_traced(
        &self,
        id: usize,
        vec: CatVector,
        deadline: u64,
        trace: u64,
    ) -> anyhow::Result<usize> {
        self.submit(PendingOp::Upsert { id, vec, deadline }, trace)
    }

    /// Non-blocking submit (used by load generators to observe
    /// backpressure). Err(vec) when the queue is full.
    pub fn try_insert_nowait(&self, vec: CatVector) -> Result<Receiver<InsertReply>, CatVector> {
        let (reply_tx, reply_rx) = sync_channel(1);
        match self.tx.try_send(Pending {
            op: PendingOp::Insert { vec, deadline: 0 },
            enqueued: Instant::now(),
            trace: 0,
            reply: reply_tx,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(p)) | Err(TrySendError::Disconnected(p)) => match p.op {
                PendingOp::Insert { vec, .. } => Err(vec),
                _ => unreachable!("try_insert_nowait only queues inserts"),
            },
        }
    }
}

/// The durability ticket behind a placed batch: the blocked insert fast
/// path and the general mutation path settle through different store
/// calls.
enum AckTicket {
    Insert(InsertTicket),
    Mutation(MutationTicket),
}

/// Batch-granular stage durations measured on the batcher thread,
/// carried to the completion thread for slow-op records. The shared
/// stages of a batch (sketch/place) are inherently per-batch; only the
/// queue wait is per-item.
#[derive(Clone, Copy, Default)]
struct BatchTiming {
    sketch_s: f64,
    place_s: f64,
}

/// A placed batch awaiting its durability wait + client replies, handed
/// from the batcher thread to the completion thread. `outcomes[i]` is
/// item i's placement result (id, or a per-op error such as deleting an
/// unheld id); the ticket's commit error, if any, supersedes the ids.
struct AckJob {
    items: Vec<Pending>,
    outcomes: Vec<InsertReply>,
    ticket: AckTicket,
    timing: BatchTiming,
}

/// The batcher worker. Owns the backend and writes into the store.
pub struct Batcher {
    pub submitter: BatchSubmitter,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    ack_handle: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn start(
        config: BatcherConfig,
        backend: SketchBackend,
        store: Arc<ShardedStore>,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        let (tx, rx) = sync_channel::<Pending>(config.queue_cap);
        let (ack_tx, ack_rx) = sync_channel::<AckJob>(ACK_PIPELINE_DEPTH);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let ack_store = store.clone();
        let ack_metrics = metrics.clone();
        let ack_handle = std::thread::Builder::new()
            .name("cabin-batcher-ack".into())
            .spawn(move || ack_loop(ack_store, ack_metrics, ack_rx))
            .expect("spawn batcher ack thread");
        let handle = std::thread::Builder::new()
            .name("cabin-batcher".into())
            .spawn(move || run_loop(config, backend, store, metrics, rx, ack_tx, stop2))
            .expect("spawn batcher");
        Batcher {
            submitter: BatchSubmitter { tx },
            stop,
            handle: Some(handle),
            ack_handle: Some(ack_handle),
        }
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // run_loop drains + flushes, then drops its ack sender; the ack
        // loop settles every queued job and exits — no reply is lost
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.ack_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn run_loop(
    config: BatcherConfig,
    backend: SketchBackend,
    store: Arc<ShardedStore>,
    metrics: Arc<Metrics>,
    rx: Receiver<Pending>,
    ack_tx: SyncSender<AckJob>,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Pending> = Vec::with_capacity(config.max_batch);
    loop {
        if stop.load(Ordering::SeqCst) {
            flush(&backend, &store, &metrics, &mut pending, &ack_tx);
            return;
        }
        // Wait for the first item (with timeout so we notice stop).
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(p) => pending.push(p),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    flush(&backend, &store, &metrics, &mut pending, &ack_tx);
                    return;
                }
            }
        }
        // Accumulate until size or deadline.
        let deadline = pending[0].enqueued + config.max_delay;
        while pending.len() < config.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => pending.push(p),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        flush(&backend, &store, &metrics, &mut pending, &ack_tx);
    }
}

/// Sketch + place the accumulated batch, then hand the durability wait
/// and the replies to the completion thread. Replies stay in batch order
/// (the channel is FIFO and [`ack_loop`] settles jobs in order), and the
/// batcher is free to sketch the next batch while this one's commit
/// window is still in flight.
///
/// A batch of pure untimed inserts takes the blocked placement fast
/// path; anything containing a delete, upsert, or TTL deadline goes
/// through the general mutation path, which applies ops in batch order.
fn flush(
    backend: &SketchBackend,
    store: &ShardedStore,
    metrics: &Metrics,
    pending: &mut Vec<Pending>,
    ack_tx: &SyncSender<AckJob>,
) {
    if pending.is_empty() {
        return;
    }
    // Failpoint (after the empty-check so shutdown's drain flush of an
    // empty queue never trips it): an injected error *defers* the flush —
    // the items stay queued and the caller's loop retries, modelling a
    // transient stall without dropping replies. A `sleep` kind delays
    // inline, the way a slow sketching backend would.
    if crate::fault::check("batcher_flush").is_err() {
        return;
    }
    metrics.batches_flushed.fetch_add(1, Ordering::Relaxed);
    metrics
        .batch_items
        .fetch_add(pending.len() as u64, Ordering::Relaxed);
    // stage: queue wait, enqueue → this pickup (per item; lock-free)
    for p in pending.iter() {
        metrics
            .stages
            .write_queue
            .record_us(obs::elapsed_us(p.enqueued));
    }
    let mut timing = BatchTiming::default();
    let sketch_start = Instant::now();
    let plain_inserts = pending
        .iter()
        .all(|p| matches!(p.op, PendingOp::Insert { deadline: 0, .. }));
    let (outcomes, ticket) = if plain_inserts {
        let batch: Vec<CatVector> = pending
            .iter()
            .map(|p| match &p.op {
                PendingOp::Insert { vec, .. } => vec.clone(),
                _ => unreachable!(),
            })
            .collect();
        let sketches = backend.sketch_batch(&batch, metrics);
        timing.sketch_s = sketch_start.elapsed().as_secs_f64();
        metrics.stages.write_sketch.record_secs(timing.sketch_s);
        let place_start = Instant::now();
        let (ids, ticket) = store.begin_insert_batch(sketches);
        timing.place_s = place_start.elapsed().as_secs_f64();
        (ids.into_iter().map(Ok).collect(), AckTicket::Insert(ticket))
    } else {
        // one backend call sketches every vector-carrying op in the batch
        // (deletes carry none), then the sketches are zipped back in order
        let to_sketch: Vec<CatVector> = pending
            .iter()
            .filter_map(|p| match &p.op {
                PendingOp::Insert { vec, .. } | PendingOp::Upsert { vec, .. } => Some(vec.clone()),
                PendingOp::Delete { .. } => None,
            })
            .collect();
        let mut sketches = if to_sketch.is_empty() {
            Vec::new()
        } else {
            backend.sketch_batch(&to_sketch, metrics)
        }
        .into_iter();
        timing.sketch_s = sketch_start.elapsed().as_secs_f64();
        metrics.stages.write_sketch.record_secs(timing.sketch_s);
        let place_start = Instant::now();
        let ops: Vec<MutationOp> = pending
            .iter()
            .map(|p| match &p.op {
                PendingOp::Insert { deadline, .. } => MutationOp::Insert {
                    sketch: sketches.next().unwrap(),
                    deadline: *deadline,
                },
                PendingOp::Delete { id } => MutationOp::Delete { id: *id },
                PendingOp::Upsert { id, deadline, .. } => MutationOp::Upsert {
                    id: *id,
                    sketch: sketches.next().unwrap(),
                    deadline: *deadline,
                },
            })
            .collect();
        let (results, ticket) = store.begin_mutation_batch(ops);
        timing.place_s = place_start.elapsed().as_secs_f64();
        let outcomes = results
            .into_iter()
            .map(|r| match r {
                MutationResult::Inserted { id }
                | MutationResult::Deleted { id }
                | MutationResult::Upserted { id } => Ok(id),
                MutationResult::Failed { error } => Err(error),
            })
            .collect();
        (outcomes, AckTicket::Mutation(ticket))
    };
    let job = AckJob {
        items: std::mem::take(pending),
        outcomes,
        ticket,
        timing,
    };
    if let Err(std::sync::mpsc::SendError(job)) = ack_tx.send(job) {
        // completion thread gone (shutdown race): settle inline so no
        // submitter is left waiting forever
        settle(store, metrics, job);
    }
}

/// The completion thread: settles each placed batch's durability ticket
/// and releases its replies, in FIFO batch order.
fn ack_loop(store: Arc<ShardedStore>, metrics: Arc<Metrics>, rx: Receiver<AckJob>) {
    while let Ok(job) = rx.recv() {
        settle(&store, &metrics, job);
    }
}

/// Settle one batch: wait out its commit (window flush under group
/// commit), then reply to every submitter. Durability gate: a WAL commit
/// failure must surface on every ack of this batch — the rows may be
/// scannable in memory, but telling the client "inserted" would promise
/// crash-durability that was not met.
fn settle(store: &ShardedStore, metrics: &Metrics, job: AckJob) {
    let fsync_start = Instant::now();
    let committed = match job.ticket {
        AckTicket::Insert(t) => store.finish_insert_batch(t),
        AckTicket::Mutation(t) => store.finish_mutation_batch(t),
    };
    // batch-level fsync-wait view for the slow-op record (the store's
    // `write_fsync` stage histogram times the window wait itself)
    let fsync_s = fsync_start.elapsed().as_secs_f64();
    let batch_len = job.items.len();
    let timing = job.timing;
    let reply_start = Instant::now();
    match committed {
        Ok(()) => {
            for (p, outcome) in job.items.into_iter().zip(job.outcomes) {
                let total_s = p.enqueued.elapsed().as_secs_f64();
                if outcome.is_ok() {
                    metrics.record_insert_latency(total_s);
                }
                note_slow_write(&p, total_s, timing, fsync_s, batch_len);
                let _ = p.reply.send(outcome);
            }
        }
        Err(e) => {
            let e = e.context(
                "write placed in memory but its WAL commit failed — not acknowledged as durable",
            );
            let msg = format!("{e:#}");
            for (p, outcome) in job.items.into_iter().zip(job.outcomes) {
                note_slow_write(&p, p.enqueued.elapsed().as_secs_f64(), timing, fsync_s, batch_len);
                // ops that already failed at placement keep their own
                // error; the commit failure covers the placed ones
                let _ = p.reply.send(match outcome {
                    Ok(_) => Err(msg.clone()),
                    err => err,
                });
            }
        }
    }
    metrics
        .stages
        .write_reply
        .record_us(obs::elapsed_us(reply_start));
}

/// Emit one structured slow-op record when a write breached
/// `--slow-op-ms`: total end-to-end time plus the per-stage breakdown —
/// the item's own queue wait, and its batch's sketch / placement /
/// fsync-wait durations (those stages are shared by the whole batch).
fn note_slow_write(p: &Pending, total_s: f64, timing: BatchTiming, fsync_s: f64, batch_len: usize) {
    let threshold_us = obs::slow_op_us();
    if threshold_us == 0 || total_s * 1e6 < threshold_us as f64 {
        return;
    }
    let queue_s =
        total_s - timing.sketch_s - timing.place_s - fsync_s;
    obs_log::warn(
        "batcher",
        "slow_op",
        &[
            ("op", obs_log::V::s(p.op.kind())),
            ("trace", obs_log::V::u(p.trace)),
            ("total_ms", obs_log::V::f(total_s * 1e3)),
            ("queue_ms", obs_log::V::f(queue_s.max(0.0) * 1e3)),
            ("sketch_ms", obs_log::V::f(timing.sketch_s * 1e3)),
            ("place_ms", obs_log::V::f(timing.place_s * 1e3)),
            ("fsync_wait_ms", obs_log::V::f(fsync_s * 1e3)),
            ("batch", obs_log::V::u(batch_len as u64)),
        ],
    );
    // Flight-recorder copy: the ring survives log scraping gaps and is
    // dumpable post-hoc over the wire (`events`), so a slow write is
    // findable by trace id even after stderr rotated away.
    crate::obs::journal::record(
        "batcher",
        "slow_op",
        &[
            ("op", obs_log::V::s(p.op.kind())),
            ("trace", obs_log::V::u(p.trace)),
            ("total_ms", obs_log::V::f(total_s * 1e3)),
        ],
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchConfig;
    use crate::util::rng::Xoshiro256;

    fn setup(max_batch: usize, delay_ms: u64) -> (Batcher, Arc<ShardedStore>, Arc<Metrics>) {
        let store = Arc::new(ShardedStore::new(2, 128));
        let metrics = Arc::new(Metrics::new());
        let sk = CabinSketcher::from_config(SketchConfig::new(500, 8, 128, 7));
        let b = Batcher::start(
            BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                queue_cap: 256,
            },
            SketchBackend::Native(sk),
            store.clone(),
            metrics.clone(),
        );
        (b, store, metrics)
    }

    #[test]
    fn inserts_assign_unique_ids() {
        let (mut b, store, _m) = setup(8, 2);
        let mut rng = Xoshiro256::new(1);
        let mut ids = Vec::new();
        for _ in 0..20 {
            let v = CatVector::random(500, 20, 8, &mut rng);
            ids.push(b.submitter.insert(v).unwrap());
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20);
        assert_eq!(store.len(), 20);
        b.shutdown();
    }

    #[test]
    fn batches_form_under_concurrency() {
        let (mut b, _store, metrics) = setup(16, 20);
        let mut rng = Xoshiro256::new(2);
        let vecs: Vec<CatVector> = (0..64).map(|_| CatVector::random(500, 30, 8, &mut rng)).collect();
        std::thread::scope(|s| {
            for chunk in vecs.chunks(8) {
                let sub = b.submitter.clone();
                s.spawn(move || {
                    for v in chunk {
                        sub.insert(v.clone()).unwrap();
                    }
                });
            }
        });
        assert_eq!(metrics.batch_items.load(Ordering::Relaxed), 64);
        // with 8 concurrent producers and 20ms delay, batching must occur
        assert!(
            metrics.mean_batch_size() > 1.5,
            "mean batch {}",
            metrics.mean_batch_size()
        );
        b.shutdown();
    }

    #[test]
    fn sketches_match_native_path() {
        let (mut b, store, _m) = setup(4, 1);
        let mut rng = Xoshiro256::new(3);
        let sk = CabinSketcher::from_config(SketchConfig::new(500, 8, 128, 7));
        let v = CatVector::random(500, 25, 8, &mut rng);
        let id = b.submitter.insert(v.clone()).unwrap();
        let stored = store.get(id).unwrap();
        assert_eq!(stored, sk.sketch(&v));
        b.shutdown();
    }

    #[test]
    fn pipelined_acks_hold_under_a_group_commit_window() {
        // durable store, fsync=always, long-ish window: batches are placed
        // by the batcher thread and acked by the completion thread while
        // later batches sketch — every ack must still arrive, carry a
        // unique id, and be crash-recoverable
        use crate::coordinator::ExecutorConfig;
        use crate::index::IndexConfig;
        use crate::persist::{
            Fingerprint, FsyncPolicy, PersistConfig, PersistCounters, PersistMode,
        };
        use crate::testing::TempDir;
        let dir = TempDir::new("batcher-pipeline");
        let fp = Fingerprint {
            sketch_dim: 128,
            seed: 7,
            num_shards: 2,
            input_dim: 500,
            num_categories: 8,
        };
        let cfg = PersistConfig {
            mode: PersistMode::Wal,
            data_dir: Some(dir.path().to_path_buf()),
            fsync: FsyncPolicy::Always,
            snapshot_every: 0,
            commit_window_us: 2_000,
            wal_max_bytes: 0,
            compact_dead_frames: 0,
        };
        let open = || {
            let (store, _) = ShardedStore::open_durable(
                fp,
                &IndexConfig::default(),
                &cfg,
                Arc::new(PersistCounters::default()),
                &ExecutorConfig::default(),
            )
            .unwrap();
            Arc::new(store)
        };
        let store = open();
        let metrics = Arc::new(Metrics::new());
        let sk = CabinSketcher::from_config(SketchConfig::new(500, 8, 128, 7));
        let mut b = Batcher::start(
            BatcherConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
                queue_cap: 256,
            },
            SketchBackend::Native(sk),
            store.clone(),
            metrics.clone(),
        );
        let mut ids = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..6u64)
                .map(|t| {
                    let sub = b.submitter.clone();
                    s.spawn(move || {
                        let mut rng = Xoshiro256::new(40 + t);
                        (0..5)
                            .map(|_| {
                                sub.insert(CatVector::random(500, 20, 8, &mut rng)).unwrap()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                ids.extend(h.join().unwrap());
            }
        });
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 30, "every insert must ack exactly once");
        assert_eq!(store.len(), 30);
        b.shutdown();
        drop(store);
        // acked ⇒ recoverable, through the pipelined window path too
        let back = open();
        assert_eq!(back.len(), 30);
    }

    #[test]
    fn mixed_mutations_keep_submission_order_and_settle_per_op() {
        // blocking submits serialise: each op acks before the next is
        // queued, so the delete/upsert always observe the earlier inserts
        // (intra-batch op order is covered by the store's own tests)
        let (mut b, store, _m) = setup(64, 1);
        let mut rng = Xoshiro256::new(9);
        let sk = CabinSketcher::from_config(SketchConfig::new(500, 8, 128, 7));
        let vs: Vec<CatVector> = (0..3).map(|_| CatVector::random(500, 20, 8, &mut rng)).collect();
        let replacement = CatVector::random(500, 20, 8, &mut rng);
        let sub = b.submitter.clone();
        let (v0, v1, v2, rep) = (
            vs[0].clone(),
            vs[1].clone(),
            vs[2].clone(),
            replacement.clone(),
        );
        let h = std::thread::spawn(move || {
            let a = sub.insert(v0).unwrap();
            let bb = sub.insert(v1).unwrap();
            let c = sub.insert_with_deadline(v2, u64::MAX).unwrap();
            let del = sub.delete(a).unwrap();
            let up = sub.upsert(bb, rep, 0).unwrap();
            (a, bb, c, del, up)
        });
        let (a, bb, c, del, up) = h.join().unwrap();
        assert_eq!(del, a);
        assert_eq!(up, bb);
        assert_eq!(store.get(a), None, "deleted in the same batch");
        assert_eq!(store.get(bb), Some(sk.sketch(&replacement)));
        assert!(store.get(c).is_some());
        assert_eq!(store.live_len(), 2);
        // a per-op failure (unheld id) errors that op only
        let err = b.submitter.delete(a).unwrap_err();
        assert!(err.to_string().contains("does not hold"), "{err:#}");
        assert!(b.submitter.insert(vs[0].clone()).is_ok());
        b.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (mut b, store, _m) = setup(1000, 10_000); // never flush by policy
        let mut rng = Xoshiro256::new(4);
        let sub = b.submitter.clone();
        let h = std::thread::spawn(move || {
            sub.insert(CatVector::random(500, 10, 8, &mut rng)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        b.shutdown(); // must flush the waiting item
        h.join().unwrap();
        assert_eq!(store.len(), 1);
    }
}
