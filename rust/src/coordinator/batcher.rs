//! Dynamic batcher: the serving-path component that turns a stream of
//! single-vector inserts into sketching batches.
//!
//! Flush policy (vLLM-style): a batch is dispatched when it reaches
//! `max_batch` items OR the oldest queued item has waited `max_delay`.
//! The queue is bounded (`queue_cap`); submitters block when it is full —
//! backpressure propagates to the TCP layer.
//!
//! The backend is pluggable: the XLA engine (fixed-batch AOT artifact,
//! padded) when the corpus configuration matches the artifacts, else the
//! native fused sketcher.

use super::metrics::Metrics;
use super::store::ShardedStore;
use crate::data::CatVector;
use crate::runtime::XlaHandle;
use crate::sketch::{BitVec, CabinSketcher};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 4096,
        }
    }
}

/// Which sketching backend executes a flushed batch.
pub enum SketchBackend {
    Native(CabinSketcher),
    /// XLA artifact path (thread-confined worker); falls back to the
    /// bundled native sketcher for oversize batches or worker errors.
    Xla(XlaHandle, CabinSketcher),
}

impl SketchBackend {
    pub fn sketch_batch(&self, batch: &[CatVector], metrics: &Metrics) -> Vec<BitVec> {
        match self {
            SketchBackend::Native(sk) => {
                metrics.native_batches.fetch_add(1, Ordering::Relaxed);
                batch.iter().map(|p| sk.sketch(p)).collect()
            }
            SketchBackend::Xla(handle, fallback) => {
                if batch.len() <= handle.manifest.m {
                    match handle.cabin_sketch(batch.to_vec()) {
                        Ok(s) => {
                            metrics.xla_batches.fetch_add(1, Ordering::Relaxed);
                            return s;
                        }
                        Err(e) => eprintln!("[batcher] xla failed, native fallback: {e:#}"),
                    }
                }
                metrics.native_batches.fetch_add(1, Ordering::Relaxed);
                batch.iter().map(|p| fallback.sketch(p)).collect()
            }
        }
    }

    pub fn sketcher(&self) -> &CabinSketcher {
        match self {
            SketchBackend::Native(sk) => sk,
            SketchBackend::Xla(_, sk) => sk,
        }
    }
}

/// A submitted insert's reply: the assigned id, or the durability error
/// that prevented the ack (WAL commit failure — the rows may be in memory
/// but were NOT committed, so the client must not be told they are safe).
pub type InsertReply = Result<usize, String>;

struct Pending {
    vec: CatVector,
    enqueued: Instant,
    reply: SyncSender<InsertReply>,
}

/// Handle used by connection threads to submit inserts.
#[derive(Clone)]
pub struct BatchSubmitter {
    tx: SyncSender<Pending>,
}

impl BatchSubmitter {
    /// Blocking submit; returns the assigned global id once the batch the
    /// item landed in has been flushed *and* (on durable stores) its WAL
    /// commit landed. A durability failure comes back as `Err`, not an id.
    pub fn insert(&self, vec: CatVector) -> anyhow::Result<usize> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(Pending {
                vec,
                enqueued: Instant::now(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("batcher stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batcher dropped reply"))?
            .map_err(|msg| anyhow::anyhow!(msg))
    }

    /// Non-blocking submit (used by load generators to observe
    /// backpressure). Err(vec) when the queue is full.
    pub fn try_insert_nowait(&self, vec: CatVector) -> Result<Receiver<InsertReply>, CatVector> {
        let (reply_tx, reply_rx) = sync_channel(1);
        match self.tx.try_send(Pending {
            vec,
            enqueued: Instant::now(),
            reply: reply_tx,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(p)) | Err(TrySendError::Disconnected(p)) => Err(p.vec),
        }
    }
}

/// The batcher worker. Owns the backend and writes into the store.
pub struct Batcher {
    pub submitter: BatchSubmitter,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn start(
        config: BatcherConfig,
        backend: SketchBackend,
        store: Arc<ShardedStore>,
        metrics: Arc<Metrics>,
    ) -> Batcher {
        let (tx, rx) = sync_channel::<Pending>(config.queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("cabin-batcher".into())
            .spawn(move || run_loop(config, backend, store, metrics, rx, stop2))
            .expect("spawn batcher");
        Batcher {
            submitter: BatchSubmitter { tx },
            stop,
            handle: Some(handle),
        }
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn run_loop(
    config: BatcherConfig,
    backend: SketchBackend,
    store: Arc<ShardedStore>,
    metrics: Arc<Metrics>,
    rx: Receiver<Pending>,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<Pending> = Vec::with_capacity(config.max_batch);
    loop {
        if stop.load(Ordering::SeqCst) {
            flush(&backend, &store, &metrics, &mut pending);
            return;
        }
        // Wait for the first item (with timeout so we notice stop).
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(p) => pending.push(p),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    flush(&backend, &store, &metrics, &mut pending);
                    return;
                }
            }
        }
        // Accumulate until size or deadline.
        let deadline = pending[0].enqueued + config.max_delay;
        while pending.len() < config.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => pending.push(p),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        flush(&backend, &store, &metrics, &mut pending);
    }
}

fn flush(
    backend: &SketchBackend,
    store: &ShardedStore,
    metrics: &Metrics,
    pending: &mut Vec<Pending>,
) {
    if pending.is_empty() {
        return;
    }
    let batch: Vec<CatVector> = pending.iter().map(|p| p.vec.clone()).collect();
    let sketches = backend.sketch_batch(&batch, metrics);
    metrics.batches_flushed.fetch_add(1, Ordering::Relaxed);
    metrics
        .batch_items
        .fetch_add(pending.len() as u64, Ordering::Relaxed);
    // Durability gate: a WAL commit failure must surface on every ack of
    // this batch (the rows may be scannable in memory, but telling the
    // client "inserted" would promise crash-durability that was not met).
    match store.try_insert_batch(sketches) {
        Ok(ids) => {
            for (p, id) in pending.drain(..).zip(ids) {
                metrics.record_insert_latency(p.enqueued.elapsed().as_secs_f64());
                let _ = p.reply.send(Ok(id));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for p in pending.drain(..) {
                let _ = p.reply.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchConfig;
    use crate::util::rng::Xoshiro256;

    fn setup(max_batch: usize, delay_ms: u64) -> (Batcher, Arc<ShardedStore>, Arc<Metrics>) {
        let store = Arc::new(ShardedStore::new(2, 128));
        let metrics = Arc::new(Metrics::new());
        let sk = CabinSketcher::from_config(SketchConfig::new(500, 8, 128, 7));
        let b = Batcher::start(
            BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                queue_cap: 256,
            },
            SketchBackend::Native(sk),
            store.clone(),
            metrics.clone(),
        );
        (b, store, metrics)
    }

    #[test]
    fn inserts_assign_unique_ids() {
        let (mut b, store, _m) = setup(8, 2);
        let mut rng = Xoshiro256::new(1);
        let mut ids = Vec::new();
        for _ in 0..20 {
            let v = CatVector::random(500, 20, 8, &mut rng);
            ids.push(b.submitter.insert(v).unwrap());
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20);
        assert_eq!(store.len(), 20);
        b.shutdown();
    }

    #[test]
    fn batches_form_under_concurrency() {
        let (mut b, _store, metrics) = setup(16, 20);
        let mut rng = Xoshiro256::new(2);
        let vecs: Vec<CatVector> = (0..64).map(|_| CatVector::random(500, 30, 8, &mut rng)).collect();
        std::thread::scope(|s| {
            for chunk in vecs.chunks(8) {
                let sub = b.submitter.clone();
                s.spawn(move || {
                    for v in chunk {
                        sub.insert(v.clone()).unwrap();
                    }
                });
            }
        });
        assert_eq!(metrics.batch_items.load(Ordering::Relaxed), 64);
        // with 8 concurrent producers and 20ms delay, batching must occur
        assert!(
            metrics.mean_batch_size() > 1.5,
            "mean batch {}",
            metrics.mean_batch_size()
        );
        b.shutdown();
    }

    #[test]
    fn sketches_match_native_path() {
        let (mut b, store, _m) = setup(4, 1);
        let mut rng = Xoshiro256::new(3);
        let sk = CabinSketcher::from_config(SketchConfig::new(500, 8, 128, 7));
        let v = CatVector::random(500, 25, 8, &mut rng);
        let id = b.submitter.insert(v.clone()).unwrap();
        let stored = store.get(id).unwrap();
        assert_eq!(stored, sk.sketch(&v));
        b.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (mut b, store, _m) = setup(1000, 10_000); // never flush by policy
        let mut rng = Xoshiro256::new(4);
        let sub = b.submitter.clone();
        let h = std::thread::spawn(move || {
            sub.insert(CatVector::random(500, 10, 8, &mut rng)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        b.shutdown(); // must flush the waiting item
        h.join().unwrap();
        assert_eq!(store.len(), 1);
    }
}
