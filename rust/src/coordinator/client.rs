//! Blocking TCP clients for the coordinator.
//!
//! Two layers:
//!
//! - [`Client`] — one socket, one server, no policy. Used by the
//!   examples, the integration tests and the load-generating bench.
//!   An I/O error is the caller's problem.
//! - [`MultiClient`] — the resilient layer for deployments that have a
//!   primary plus replicas. It owns connect/read/write timeouts
//!   ([`ClientConfig`]), retries transient I/O failures with bounded
//!   jittered exponential backoff, follows write redirects when it hits
//!   a read-only replica (parsing the stable `primary at <addr>` prose
//!   documented in `docs/PROTOCOL.md`), spreads reads round-robin over
//!   the replica set, and remembers the highest failover epoch it has
//!   seen so a revived stale primary fences itself on first contact.

use super::protocol::{Hit, Request, Response, StreamRequest, WriteOpts};
use super::stats::Stats;
use crate::data::CatVector;
use crate::util::rng::Xoshiro256;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Socket and retry policy for [`Client::connect_with`] and
/// [`MultiClient`]. The zero-policy [`Client::connect`] path does not
/// consult this at all (no timeouts, no retries), matching its
/// historical behaviour.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// TCP connect budget per endpoint attempt.
    pub connect_timeout: Duration,
    /// Per-read socket timeout (`None` = block forever).
    pub read_timeout: Option<Duration>,
    /// Per-write socket timeout (`None` = block forever).
    pub write_timeout: Option<Duration>,
    /// Extra attempts after the first failure of an operation. Redirects
    /// to a new primary do not consume retries (they are progress, not
    /// failure) but are separately capped to break redirect loops.
    pub retries: u32,
    /// First backoff sleep; attempt `n` waits `base * 2^(n-1)`, jittered
    /// down by up to 50% so synchronized clients do not stampede.
    pub backoff_base: Duration,
    /// Ceiling on any single backoff sleep.
    pub backoff_max: Duration,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_secs(1),
            read_timeout: Some(Duration::from_secs(5)),
            write_timeout: Some(Duration::from_secs(5)),
            retries: 3,
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_secs(1),
        }
    }
}

/// Backoff for the `attempt`-th retry (1-based): exponential from
/// `base`, capped at `max`, then jittered to 50–100% of that span.
fn backoff_delay(cfg: &ClientConfig, attempt: u32, rng: &mut Xoshiro256) -> Duration {
    let base_ms = cfg.backoff_base.as_millis() as u64;
    let max_ms = cfg.backoff_max.as_millis() as u64;
    let exp = attempt.saturating_sub(1).min(16);
    let full = base_ms.saturating_mul(1u64 << exp).min(max_ms).max(1);
    let jittered = full / 2 + rng.gen_range(full / 2 + 1);
    Duration::from_millis(jittered)
}

/// Extract the primary address from a replica's write-rejection prose.
/// The server promises the `primary at <addr>` spelling is stable (see
/// `docs/PROTOCOL.md`); the address token must look like `host:port` so
/// the *fence* error ("a newer primary at epoch N superseded…") is
/// never mistaken for a redirect.
fn parse_redirect(message: &str) -> Option<&str> {
    let rest = &message[message.find("primary at ")? + "primary at ".len()..];
    let addr = rest.split_whitespace().next()?;
    if addr.contains(':') {
        Some(addr)
    } else {
        None
    }
}

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Trace id attached to every request this connection sends (0 =
    /// none: requests stay byte-identical to the trace-less wire and the
    /// server stamps its own id). Set one to correlate this client's ops
    /// across the server's JSONL logs — and, for replicated writes,
    /// across the follower's logs too.
    trace: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Client::from_stream(stream)
    }

    /// Connect with explicit socket budgets: `connect_timeout` bounds
    /// each resolved address attempt, and the read/write timeouts stick
    /// to the socket for the connection's lifetime. A server that
    /// accepts but never answers turns into a timeout `Err` instead of
    /// a hang — the property [`MultiClient`] builds on.
    pub fn connect_with(addr: &str, cfg: &ClientConfig) -> Result<Client> {
        let addrs: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {addr}"))?
            .collect();
        let mut last_err = None;
        for a in &addrs {
            match TcpStream::connect_timeout(a, cfg.connect_timeout) {
                Ok(stream) => {
                    stream.set_read_timeout(cfg.read_timeout)?;
                    stream.set_write_timeout(cfg.write_timeout)?;
                    return Client::from_stream(stream);
                }
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) => Err(e).with_context(|| format!("connect {addr}")),
            None => bail!("{addr} resolved to no addresses"),
        }
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            trace: 0,
        })
    }

    /// Builder form of [`Client::set_trace`]:
    /// `Client::connect(addr)?.with_trace(id)`.
    pub fn with_trace(mut self, trace: u64) -> Client {
        self.trace = trace;
        self
    }

    /// Attach `trace` to every subsequent request (0 clears it). The
    /// server logs ops under this id instead of stamping its own, so one
    /// grep finds this client's story — see `docs/OBSERVABILITY.md`.
    pub fn set_trace(&mut self, trace: u64) {
        self.trace = trace;
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.to_json_line_with(self.trace))?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed connection");
        }
        Response::from_json_line(line.trim())
    }

    /// Insert a vector with per-write options — the one insert entry
    /// point. `WriteOpts::default()` is a plain durable insert;
    /// `WriteOpts::ttl(ms)` adds a relative time-to-live (the primary
    /// stamps the absolute deadline and its background sweep deletes the
    /// row once it passes, with sweep-interval granularity). The `trace`
    /// option is server-internal and ignored on the wire.
    pub fn insert_with(&mut self, vec: CatVector, opts: &WriteOpts) -> Result<usize> {
        let req = match opts.ttl_ms {
            0 => Request::Insert { vec },
            ttl_ms => Request::InsertTtl { vec, ttl_ms },
        };
        match self.call(&req)? {
            Response::Inserted { id, .. } => Ok(id),
            Response::Error { message } => bail!("insert failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Plain insert. Shim for `insert_with(vec, &WriteOpts::default())`;
    /// kept so existing callers compile unchanged.
    pub fn insert(&mut self, vec: CatVector) -> Result<usize> {
        self.insert_with(vec, &WriteOpts::default())
    }

    /// Delete a live id from the corpus (primary only; replicated to
    /// followers like any other write).
    pub fn delete(&mut self, id: usize) -> Result<()> {
        match self.call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Error { message } => bail!("delete failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Replace the vector behind `id` in place (or resurrect a deleted
    /// id) — the one upsert entry point. `opts.ttl_ms == 0` clears any
    /// previous expiry on the id.
    pub fn upsert_with(&mut self, id: usize, vec: CatVector, opts: &WriteOpts) -> Result<()> {
        let ttl_ms = opts.ttl_ms;
        let req = Request::Upsert { id, vec, ttl_ms };
        match self.call(&req)? {
            Response::Upserted { .. } => Ok(()),
            Response::Error { message } => bail!("upsert failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn query(&mut self, vec: CatVector, k: usize) -> Result<Vec<Hit>> {
        match self.call(&Request::Query { vec, k })? {
            Response::Hits { hits } => Ok(hits),
            Response::Error { message } => bail!("query failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Batched top-k: one round-trip, one scatter/gather for all `vecs`.
    pub fn query_batch(&mut self, vecs: Vec<CatVector>, k: usize) -> Result<Vec<Vec<Hit>>> {
        match self.call(&Request::QueryBatch { vecs, k })? {
            Response::HitsBatch { results } => Ok(results),
            Response::Error { message } => bail!("query_batch failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn distance(&mut self, a: usize, b: usize) -> Result<f64> {
        match self.call(&Request::Distance { a, b })? {
            Response::Distance { dist } => Ok(dist),
            Response::Error { message } => bail!("distance failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Raw `stats` fields exactly as the server reported them, in wire
    /// order. Prefer [`Client::typed_stats`] for field access by name —
    /// this form survives for callers that iterate or diff snapshots.
    pub fn stats(&mut self) -> Result<Vec<(String, f64)>> {
        match self.call(&Request::Stats)? {
            Response::Stats { fields } => Ok(fields),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// One `stats` round trip, decoded into the typed [`Stats`] view:
    /// every schema field is a struct member (a typo is a compile error,
    /// not a silent 0.0), and fields this client build does not know
    /// (newer servers, dynamic `stage_*`/`repl_shard_lag_*` families) are
    /// preserved in [`Stats::extra`].
    pub fn typed_stats(&mut self) -> Result<Stats> {
        Ok(Stats::from_fields(self.stats()?))
    }

    /// Fetch one named stats field. A field the server did not report is a
    /// protocol-level `Err` — never a panic — so callers can probe for
    /// version-dependent counters safely.
    ///
    /// Deprecated spelling: prefer [`Client::typed_stats`] (one round trip,
    /// compile-checked names) — string lookups survive for dynamic field
    /// families only. Each call is a full `stats` round trip; to read
    /// several fields from one consistent snapshot, call [`Client::stats`]
    /// once and look fields up with [`super::metrics::stats_field`].
    pub fn stat(&mut self, name: &str) -> Result<f64> {
        let fields = self.stats()?;
        super::metrics::stats_field(&fields, name)
            .ok_or_else(|| anyhow::anyhow!("stats field '{name}' missing from response"))
    }

    /// Fetch the server's Prometheus text exposition (`metrics_text`
    /// stream op: every stats field plus full histogram bucket families).
    /// Works against primaries and followers alike. The reply is a JSON
    /// header line (`{"ok":true,"bytes":N}`) followed by N raw payload
    /// bytes — see `docs/PROTOCOL.md` for the stream framing.
    pub fn metrics_text(&mut self) -> Result<String> {
        writeln!(self.writer, "{}", StreamRequest::MetricsText.to_json_line())?;
        let mut header = String::new();
        let n = self.reader.read_line(&mut header)?;
        if n == 0 {
            bail!("server closed connection");
        }
        let h = crate::util::json::parse(header.trim()).context("metrics_text header")?;
        if h.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            bail!(
                "metrics_text failed: {}",
                h.get("error").and_then(|e| e.as_str()).unwrap_or("unknown")
            );
        }
        let bytes = h.req_usize("bytes")?;
        let mut body = vec![0u8; bytes];
        self.reader.read_exact(&mut body)?;
        String::from_utf8(body).context("metrics_text payload is not UTF-8")
    }

    /// Dump the server's flight-recorder event journal (`events` stream
    /// op): one JSON object per line, oldest first — startup, promote,
    /// fence, slow-op and failure lifecycle events with their seqs and
    /// wall-clock stamps. Same header-then-payload framing as
    /// [`Client::metrics_text`].
    pub fn events(&mut self) -> Result<String> {
        writeln!(self.writer, "{}", StreamRequest::Events.to_json_line())?;
        let mut header = String::new();
        let n = self.reader.read_line(&mut header)?;
        if n == 0 {
            bail!("server closed connection");
        }
        let h = crate::util::json::parse(header.trim()).context("events header")?;
        if h.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            bail!(
                "events failed: {}",
                h.get("error").and_then(|e| e.as_str()).unwrap_or("unknown")
            );
        }
        let bytes = h.req_usize("bytes")?;
        let mut body = vec![0u8; bytes];
        self.reader.read_exact(&mut body)?;
        String::from_utf8(body).context("events payload is not UTF-8")
    }

    /// Fsync every shard WAL on the server (durable servers only) — after
    /// this returns, every acknowledged insert is on disk even under
    /// `--fsync never`.
    pub fn flush(&mut self) -> Result<()> {
        match self.call(&Request::Flush)? {
            Response::Flushed => Ok(()),
            Response::Error { message } => bail!("flush failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Force a snapshot rotation now (durable servers only); returns the
    /// new live generation.
    pub fn snapshot(&mut self) -> Result<u64> {
        match self.call(&Request::Snapshot)? {
            Response::Snapshotted { generation } => Ok(generation),
            Response::Error { message } => bail!("snapshot failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Promote a read-replica to writable (replicas only): stops its
    /// puller and returns the per-shard applied WAL sequences at the
    /// moment replication stopped plus the new failover epoch (0 on
    /// non-durable replicas). Idempotent — promoting an already writable
    /// replica reports its sequences and current epoch again without
    /// bumping anything.
    pub fn promote(&mut self) -> Result<(Vec<u64>, u64)> {
        match self.call(&Request::Promote)? {
            Response::Promoted {
                applied_seqs,
                epoch,
            } => Ok((applied_seqs, epoch)),
            Response::Error { message } => bail!("promote failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fence this server read-only at `max(its own epoch, epoch)` so it
    /// can be safely pointed at a new primary with `--replicate-from`.
    /// Durable servers only; returns the epoch the fence was written at.
    pub fn demote(&mut self, epoch: Option<u64>) -> Result<u64> {
        match self.call(&Request::Demote { epoch })? {
            Response::Demoted { epoch } => Ok(epoch),
            Response::Error { message } => bail!("demote failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Liveness round trip. Returns the server's failover epoch (`None`
    /// on non-durable servers). Passing `epoch` gossips the caller's
    /// highest observed epoch — a durable writable server that learns of
    /// a newer epoch this way fences itself (see `docs/FAILOVER.md`).
    pub fn ping_epoch(&mut self, epoch: Option<u64>) -> Result<Option<u64>> {
        match self.call(&Request::Ping { epoch })? {
            Response::Pong { epoch } => Ok(epoch),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        self.ping_epoch(None).map(|_| ())
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

/// A failover-aware client over one primary and any number of replicas.
///
/// Policy, all driven by [`ClientConfig`]:
///
/// - **Writes** go to the believed primary. A read-only rejection that
///   names a different primary (`primary at <addr>`) re-aims the client
///   and retries immediately — redirects are progress, capped at
///   [`MultiClient::MAX_REDIRECTS`] per call to break loops. Transient
///   I/O failures reconnect and retry with jittered exponential backoff.
/// - **Reads** rotate round-robin across the replica set, falling back
///   to the primary when no replica answers (or none was given).
/// - **Epochs**: every ack and pong carrying an epoch raises the
///   client's high-water mark, and each fresh write connection opens
///   with a `ping` gossiping it — so a revived stale primary fences
///   itself before it can accept a single write from this client.
///
/// Not thread-safe by design (like [`Client`]); build one `MultiClient`
/// per worker thread from the same endpoint list.
pub struct MultiClient {
    cfg: ClientConfig,
    primary: String,
    replicas: Vec<String>,
    next_read: usize,
    last_epoch: u64,
    write_conn: Option<Client>,
    read_conns: Vec<Option<Client>>,
    rng: Xoshiro256,
    /// Trace id inherited by every connection this client opens — which
    /// is what keeps the id stable across retries, reconnects and
    /// redirect hops: the op that finally lands on the new primary logs
    /// under the same trace as the attempt that was redirected.
    trace: u64,
}

impl MultiClient {
    /// Redirect-follow cap per write call: enough for any realistic
    /// promotion chain, small enough to fail fast on a redirect cycle.
    pub const MAX_REDIRECTS: u32 = 4;

    pub fn new(primary: &str, replicas: &[&str]) -> MultiClient {
        MultiClient::with_config(primary, replicas, ClientConfig::default())
    }

    pub fn with_config(primary: &str, replicas: &[&str], cfg: ClientConfig) -> MultiClient {
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x9e3779b97f4a7c15);
        MultiClient {
            cfg,
            primary: primary.to_string(),
            replicas: replicas.iter().map(|r| r.to_string()).collect(),
            next_read: 0,
            last_epoch: 0,
            write_conn: None,
            read_conns: replicas.iter().map(|_| None).collect(),
            rng: Xoshiro256::new(seed),
            trace: 0,
        }
    }

    /// Builder form of [`MultiClient::set_trace`].
    pub fn with_trace(mut self, trace: u64) -> MultiClient {
        self.trace = trace;
        self
    }

    /// Attach `trace` to every subsequent request, surviving retries,
    /// reconnects and redirect hops (0 clears it). Existing connections
    /// pick it up immediately.
    pub fn set_trace(&mut self, trace: u64) {
        self.trace = trace;
        if let Some(c) = &mut self.write_conn {
            c.set_trace(trace);
        }
        for c in self.read_conns.iter_mut().flatten() {
            c.set_trace(trace);
        }
    }

    /// Where this client currently believes writes should go — updated
    /// in place whenever a redirect is followed.
    pub fn primary(&self) -> &str {
        &self.primary
    }

    /// Highest failover epoch observed on any ack or pong (0 = none).
    pub fn last_epoch(&self) -> u64 {
        self.last_epoch
    }

    fn note_epoch(&mut self, resp: &Response) {
        let epoch = match resp {
            Response::Inserted { epoch, .. }
            | Response::Deleted { epoch, .. }
            | Response::Upserted { epoch, .. }
            | Response::Pong { epoch } => *epoch,
            Response::Promoted { epoch, .. } | Response::Demoted { epoch } => Some(*epoch),
            _ => None,
        };
        if let Some(e) = epoch {
            self.last_epoch = self.last_epoch.max(e);
        }
    }

    /// One request against the believed primary, reconnecting and
    /// backing off on I/O failure, following redirects on read-only
    /// rejection. A fresh connection opens with an epoch-gossiping ping.
    fn write_call(&mut self, req: &Request) -> Result<Response> {
        let mut redirects = 0u32;
        let mut attempt = 0u32;
        loop {
            let res = (|| -> Result<Response> {
                if self.write_conn.is_none() {
                    let mut conn =
                        Client::connect_with(&self.primary, &self.cfg)?.with_trace(self.trace);
                    let gossip = match self.last_epoch {
                        0 => None,
                        e => Some(e),
                    };
                    if let Some(e) = conn.ping_epoch(gossip)? {
                        self.last_epoch = self.last_epoch.max(e);
                    }
                    self.write_conn = Some(conn);
                }
                self.write_conn.as_mut().unwrap().call(req)
            })();
            match res {
                Ok(Response::Error { message }) => {
                    if let Some(addr) = parse_redirect(&message) {
                        redirects += 1;
                        if redirects > MultiClient::MAX_REDIRECTS {
                            bail!(
                                "redirect loop: still read-only after \
                                 {redirects} hops ({message})"
                            );
                        }
                        self.primary = addr.to_string();
                        self.write_conn = None;
                        continue;
                    }
                    return Ok(Response::Error { message });
                }
                Ok(resp) => {
                    self.note_epoch(&resp);
                    return Ok(resp);
                }
                Err(e) => {
                    self.write_conn = None;
                    attempt += 1;
                    if attempt > self.cfg.retries {
                        return Err(e.context(format!(
                            "write to {} failed after {attempt} attempts",
                            self.primary
                        )));
                    }
                    std::thread::sleep(backoff_delay(&self.cfg, attempt, &mut self.rng));
                }
            }
        }
    }

    /// One read against the next endpoint in rotation; on failure the
    /// rotation advances, so retries naturally spread over the fleet,
    /// and the primary serves as the read of last resort.
    fn read_call(&mut self, req: &Request) -> Result<Response> {
        if self.replicas.is_empty() {
            return self.write_call(req);
        }
        let mut attempt = 0u32;
        loop {
            let idx = self.next_read % self.replicas.len();
            self.next_read = self.next_read.wrapping_add(1);
            let res = (|| -> Result<Response> {
                if self.read_conns[idx].is_none() {
                    self.read_conns[idx] = Some(
                        Client::connect_with(&self.replicas[idx], &self.cfg)?
                            .with_trace(self.trace),
                    );
                }
                self.read_conns[idx].as_mut().unwrap().call(req)
            })();
            match res {
                Ok(resp) => {
                    self.note_epoch(&resp);
                    return Ok(resp);
                }
                Err(e) => {
                    self.read_conns[idx] = None;
                    attempt += 1;
                    if attempt > self.cfg.retries {
                        return match self.write_call(req) {
                            Ok(resp) => Ok(resp),
                            Err(_) => Err(e.context(format!(
                                "read failed after {attempt} replica attempts"
                            ))),
                        };
                    }
                    std::thread::sleep(backoff_delay(&self.cfg, attempt, &mut self.rng));
                }
            }
        }
    }

    pub fn insert_with(&mut self, vec: CatVector, opts: &WriteOpts) -> Result<usize> {
        let req = match opts.ttl_ms {
            0 => Request::Insert { vec },
            ttl_ms => Request::InsertTtl { vec, ttl_ms },
        };
        match self.write_call(&req)? {
            Response::Inserted { id, .. } => Ok(id),
            Response::Error { message } => bail!("insert failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn insert(&mut self, vec: CatVector) -> Result<usize> {
        self.insert_with(vec, &WriteOpts::default())
    }

    pub fn delete(&mut self, id: usize) -> Result<()> {
        match self.write_call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Error { message } => bail!("delete failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn upsert_with(&mut self, id: usize, vec: CatVector, opts: &WriteOpts) -> Result<()> {
        let req = Request::Upsert {
            id,
            vec,
            ttl_ms: opts.ttl_ms,
        };
        match self.write_call(&req)? {
            Response::Upserted { .. } => Ok(()),
            Response::Error { message } => bail!("upsert failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn query(&mut self, vec: CatVector, k: usize) -> Result<Vec<Hit>> {
        match self.read_call(&Request::Query { vec, k })? {
            Response::Hits { hits } => Ok(hits),
            Response::Error { message } => bail!("query failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn query_batch(&mut self, vecs: Vec<CatVector>, k: usize) -> Result<Vec<Vec<Hit>>> {
        match self.read_call(&Request::QueryBatch { vecs, k })? {
            Response::HitsBatch { results } => Ok(results),
            Response::Error { message } => bail!("query_batch failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<Vec<(String, f64)>> {
        match self.read_call(&Request::Stats)? {
            Response::Stats { fields } => Ok(fields),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn typed_stats(&mut self) -> Result<Stats> {
        Ok(Stats::from_fields(self.stats()?))
    }

    /// Ping the primary, gossiping this client's highest observed epoch.
    pub fn ping(&mut self) -> Result<()> {
        let gossip = match self.last_epoch {
            0 => None,
            e => Some(e),
        };
        match self.write_call(&Request::Ping { epoch: gossip })? {
            Response::Pong { .. } => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn redirect_parse_accepts_only_addr_shaped_targets() {
        // the stable replica rejection prose
        let m = "read-only replica: writes go to the primary at 127.0.0.1:7070 \
                 (or `promote` this replica)";
        assert_eq!(parse_redirect(m), Some("127.0.0.1:7070"));
        // the fence error also says "primary at" — but names an epoch,
        // not an addr, and must never be followed as a redirect
        let f = "write fenced: a newer primary at epoch 9 superseded this server \
                 (own epoch 1); demote and rejoin with --replicate-from";
        assert_eq!(parse_redirect(f), None);
        assert_eq!(parse_redirect("some other error"), None);
        assert_eq!(parse_redirect("primary at "), None);
    }

    #[test]
    fn backoff_is_bounded_and_jittered() {
        let cfg = ClientConfig {
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_millis(400),
            ..ClientConfig::default()
        };
        let mut rng = Xoshiro256::new(7);
        for attempt in 1..=10u32 {
            let d = backoff_delay(&cfg, attempt, &mut rng);
            let full = (100u64 << (attempt - 1).min(16)).min(400);
            assert!(d.as_millis() as u64 >= full / 2, "attempt {attempt}: {d:?}");
            assert!(d.as_millis() as u64 <= full, "attempt {attempt}: {d:?}");
        }
        // attempt 1 never exceeds base, deep attempts never exceed max
        assert!(backoff_delay(&cfg, 1, &mut rng) <= cfg.backoff_base);
        assert!(backoff_delay(&cfg, 99, &mut rng) <= cfg.backoff_max);
    }

    #[test]
    fn multi_client_tracks_epoch_high_water_mark() {
        let mut mc = MultiClient::new("127.0.0.1:1", &[]);
        assert_eq!(mc.last_epoch(), 0);
        mc.note_epoch(&Response::Inserted {
            id: 1,
            epoch: Some(3),
        });
        assert_eq!(mc.last_epoch(), 3);
        mc.note_epoch(&Response::Pong { epoch: Some(2) }); // never regresses
        assert_eq!(mc.last_epoch(), 3);
        mc.note_epoch(&Response::Promoted {
            applied_seqs: vec![],
            epoch: 5,
        });
        assert_eq!(mc.last_epoch(), 5);
        mc.note_epoch(&Response::Flushed); // epoch-free responses are no-ops
        assert_eq!(mc.last_epoch(), 5);
    }
}
