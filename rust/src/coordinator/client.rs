//! Blocking TCP client for the coordinator — used by the examples, the
//! end-to-end integration test and the load-generating bench.

use super::protocol::{Hit, Request, Response, StreamRequest, WriteOpts};
use super::stats::Stats;
use crate::data::CatVector;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.to_json_line())?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed connection");
        }
        Response::from_json_line(line.trim())
    }

    /// Insert a vector with per-write options — the one insert entry
    /// point. `WriteOpts::default()` is a plain durable insert;
    /// `WriteOpts::ttl(ms)` adds a relative time-to-live (the primary
    /// stamps the absolute deadline and its background sweep deletes the
    /// row once it passes, with sweep-interval granularity). The `trace`
    /// option is server-internal and ignored on the wire.
    pub fn insert_with(&mut self, vec: CatVector, opts: &WriteOpts) -> Result<usize> {
        let req = match opts.ttl_ms {
            0 => Request::Insert { vec },
            ttl_ms => Request::InsertTtl { vec, ttl_ms },
        };
        match self.call(&req)? {
            Response::Inserted { id } => Ok(id),
            Response::Error { message } => bail!("insert failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Plain insert. Shim for `insert_with(vec, &WriteOpts::default())`;
    /// kept so existing callers compile unchanged.
    pub fn insert(&mut self, vec: CatVector) -> Result<usize> {
        self.insert_with(vec, &WriteOpts::default())
    }

    /// Deprecated spelling of `insert_with(vec, &WriteOpts::ttl(ttl_ms))`
    /// — prefer that; this shim goes away after one release.
    pub fn insert_ttl(&mut self, vec: CatVector, ttl_ms: u64) -> Result<usize> {
        self.insert_with(vec, &WriteOpts::ttl(ttl_ms))
    }

    /// Delete a live id from the corpus (primary only; replicated to
    /// followers like any other write).
    pub fn delete(&mut self, id: usize) -> Result<()> {
        match self.call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Error { message } => bail!("delete failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Replace the vector behind `id` in place (or resurrect a deleted
    /// id) — the one upsert entry point. `opts.ttl_ms == 0` clears any
    /// previous expiry on the id.
    pub fn upsert_with(&mut self, id: usize, vec: CatVector, opts: &WriteOpts) -> Result<()> {
        let ttl_ms = opts.ttl_ms;
        let req = Request::Upsert { id, vec, ttl_ms };
        match self.call(&req)? {
            Response::Upserted { .. } => Ok(()),
            Response::Error { message } => bail!("upsert failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Deprecated spelling of `upsert_with` with a bare `ttl_ms` — prefer
    /// that; this shim goes away after one release.
    pub fn upsert(&mut self, id: usize, vec: CatVector, ttl_ms: u64) -> Result<()> {
        self.upsert_with(id, vec, &WriteOpts { ttl_ms, trace: 0 })
    }

    pub fn query(&mut self, vec: CatVector, k: usize) -> Result<Vec<Hit>> {
        match self.call(&Request::Query { vec, k })? {
            Response::Hits { hits } => Ok(hits),
            Response::Error { message } => bail!("query failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Batched top-k: one round-trip, one scatter/gather for all `vecs`.
    pub fn query_batch(&mut self, vecs: Vec<CatVector>, k: usize) -> Result<Vec<Vec<Hit>>> {
        match self.call(&Request::QueryBatch { vecs, k })? {
            Response::HitsBatch { results } => Ok(results),
            Response::Error { message } => bail!("query_batch failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn distance(&mut self, a: usize, b: usize) -> Result<f64> {
        match self.call(&Request::Distance { a, b })? {
            Response::Distance { dist } => Ok(dist),
            Response::Error { message } => bail!("distance failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Raw `stats` fields exactly as the server reported them, in wire
    /// order. Prefer [`Client::typed_stats`] for field access by name —
    /// this form survives for callers that iterate or diff snapshots.
    pub fn stats(&mut self) -> Result<Vec<(String, f64)>> {
        match self.call(&Request::Stats)? {
            Response::Stats { fields } => Ok(fields),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// One `stats` round trip, decoded into the typed [`Stats`] view:
    /// every schema field is a struct member (a typo is a compile error,
    /// not a silent 0.0), and fields this client build does not know
    /// (newer servers, dynamic `stage_*`/`repl_shard_lag_*` families) are
    /// preserved in [`Stats::extra`].
    pub fn typed_stats(&mut self) -> Result<Stats> {
        Ok(Stats::from_fields(self.stats()?))
    }

    /// Fetch one named stats field. A field the server did not report is a
    /// protocol-level `Err` — never a panic — so callers can probe for
    /// version-dependent counters safely.
    ///
    /// Deprecated spelling: prefer [`Client::typed_stats`] (one round trip,
    /// compile-checked names) — string lookups survive for dynamic field
    /// families only. Each call is a full `stats` round trip; to read
    /// several fields from one consistent snapshot, call [`Client::stats`]
    /// once and look fields up with [`super::metrics::stats_field`].
    pub fn stat(&mut self, name: &str) -> Result<f64> {
        let fields = self.stats()?;
        super::metrics::stats_field(&fields, name)
            .ok_or_else(|| anyhow::anyhow!("stats field '{name}' missing from response"))
    }

    /// Fetch the server's Prometheus text exposition (`metrics_text`
    /// stream op: every stats field plus full histogram bucket families).
    /// Works against primaries and followers alike. The reply is a JSON
    /// header line (`{"ok":true,"bytes":N}`) followed by N raw payload
    /// bytes — see `docs/PROTOCOL.md` for the stream framing.
    pub fn metrics_text(&mut self) -> Result<String> {
        writeln!(self.writer, "{}", StreamRequest::MetricsText.to_json_line())?;
        let mut header = String::new();
        let n = self.reader.read_line(&mut header)?;
        if n == 0 {
            bail!("server closed connection");
        }
        let h = crate::util::json::parse(header.trim()).context("metrics_text header")?;
        if h.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            bail!(
                "metrics_text failed: {}",
                h.get("error").and_then(|e| e.as_str()).unwrap_or("unknown")
            );
        }
        let bytes = h.req_usize("bytes")?;
        let mut body = vec![0u8; bytes];
        self.reader.read_exact(&mut body)?;
        String::from_utf8(body).context("metrics_text payload is not UTF-8")
    }

    /// Fsync every shard WAL on the server (durable servers only) — after
    /// this returns, every acknowledged insert is on disk even under
    /// `--fsync never`.
    pub fn flush(&mut self) -> Result<()> {
        match self.call(&Request::Flush)? {
            Response::Flushed => Ok(()),
            Response::Error { message } => bail!("flush failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Force a snapshot rotation now (durable servers only); returns the
    /// new live generation.
    pub fn snapshot(&mut self) -> Result<u64> {
        match self.call(&Request::Snapshot)? {
            Response::Snapshotted { generation } => Ok(generation),
            Response::Error { message } => bail!("snapshot failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Promote a read-replica to writable (replicas only): stops its
    /// puller and returns the per-shard applied WAL sequences at the
    /// moment replication stopped. Idempotent — promoting an already
    /// writable replica just reports its sequences again.
    pub fn promote(&mut self) -> Result<Vec<u64>> {
        match self.call(&Request::Promote)? {
            Response::Promoted { applied_seqs } => Ok(applied_seqs),
            Response::Error { message } => bail!("promote failed: {message}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
