//! Service metrics: lock-free counters + lock-free latency histograms.
//!
//! Every latency metric here — the request-level insert/query
//! histograms and the per-stage pipeline histograms in
//! [`crate::obs::Stages`] — records through
//! [`crate::obs::ObsHistogram`]: a relaxed atomic bucket increment,
//! no mutex and no allocation on the hot path, fixed memory forever.
//! (The old design buffered every sample in a `Mutex<Vec<f64>>`; that
//! sampler now lives only in offline bench summaries, reservoir-capped
//! — see [`crate::util::timer::LatencyStats`].)

use crate::obs::{ObsHistogram, Stages};
use crate::persist::PersistCounters;
use crate::replica::ReplCounters;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Number of shards covered by the per-shard executor queue gauges.
/// Shards at or beyond this index still execute normally — they just
/// fold into the aggregate `queue_depth` gauge only.
pub const TRACKED_SHARDS: usize = 64;

/// Shard-executor runtime counters, updated by
/// [`crate::coordinator::executor::ShardExecutor`]. `queue_depth` and
/// `busy_workers` are gauges (current values), the rest are monotone.
/// Arc-shared between [`Metrics`] and the store's executor, mirroring the
/// [`PersistCounters`] pattern.
#[derive(Debug)]
pub struct ExecutorCounters {
    /// Jobs currently sitting in shard work queues (gauge).
    pub queue_depth: AtomicU64,
    /// Shard workers currently executing a job (gauge).
    pub busy_workers: AtomicU64,
    /// Jobs executed since startup.
    pub jobs: AtomicU64,
    /// Scatter/gather rounds served since startup (one per routed query
    /// or query batch).
    pub scatters: AtomicU64,
    /// Jobs that panicked inside a worker (caught; the worker survives).
    /// Surfaced as `executor_job_panics` — any nonzero value means a bug
    /// in a kernel or index path that the runtime papered over.
    pub job_panics: AtomicU64,
    /// Jobs currently queued per shard (gauge, first [`TRACKED_SHARDS`]
    /// shards).
    pub per_shard_depth: [AtomicU64; TRACKED_SHARDS],
    /// High-water mark of each shard's queue depth since startup. A
    /// persistently high mark on one shard while the rest stay near zero
    /// is the hot-shard signal. Surfaced as
    /// `executor_queue_hwm_shard<i>` only once nonzero, so the flat
    /// stats schema stays grow-only on a fresh process.
    pub per_shard_hwm: [AtomicU64; TRACKED_SHARDS],
}

impl Default for ExecutorCounters {
    // Manual impl: `[AtomicU64; 64]` is past the 32-element ceiling of
    // the derived array `Default`.
    fn default() -> Self {
        Self {
            queue_depth: AtomicU64::new(0),
            busy_workers: AtomicU64::new(0),
            jobs: AtomicU64::new(0),
            scatters: AtomicU64::new(0),
            job_panics: AtomicU64::new(0),
            per_shard_depth: std::array::from_fn(|_| AtomicU64::new(0)),
            per_shard_hwm: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl ExecutorCounters {
    /// Note a job enqueued on `shard`: bumps its depth gauge and folds
    /// the new depth into the shard's high-water mark.
    pub fn note_enqueue(&self, shard: usize) {
        if let Some(d) = self.per_shard_depth.get(shard) {
            let depth = d.fetch_add(1, Ordering::Relaxed) + 1;
            self.per_shard_hwm[shard].fetch_max(depth, Ordering::Relaxed);
        }
    }

    /// Note a job picked up off `shard`'s queue.
    pub fn note_dequeue(&self, shard: usize) {
        if let Some(d) = self.per_shard_depth.get(shard) {
            d.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// LSH-index traffic counters, recorded by the router's indexed scan path
/// (`coordinator::router::topk_with`). All lock-free; one instance lives
/// inside [`Metrics`] but the struct is independently constructible for
/// direct router callers and tests.
#[derive(Default)]
pub struct IndexCounters {
    /// Bucket probes issued (exact + multi-probe, summed over bands).
    pub probes: AtomicU64,
    /// Candidate rows generated (post-dedup, pre-rerank).
    pub candidates: AtomicU64,
    /// Candidates actually reranked with the exact Cham estimate.
    pub reranked: AtomicU64,
    /// Shard scans that fell back to the full heap scan — either because
    /// the candidate set could not guarantee `k` hits (recall-side
    /// trigger) or because it covered more than half the shard and a
    /// rerank would cost more than the scan (cost-side trigger).
    pub fallbacks: AtomicU64,
    /// Shard scans answered from the index (no fallback).
    pub indexed_scans: AtomicU64,
}

#[derive(Default)]
pub struct Metrics {
    pub inserts: AtomicU64,
    /// Wire deletes served (not TTL expirations — those count separately).
    pub deletes: AtomicU64,
    /// Wire upserts served (in-place and resurrecting alike).
    pub upserts: AtomicU64,
    /// Rows removed by the background TTL sweep.
    pub ttl_expirations: AtomicU64,
    pub queries: AtomicU64,
    pub query_batches: AtomicU64,
    pub distances: AtomicU64,
    pub heatmaps: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batch_items: AtomicU64,
    pub errors: AtomicU64,
    pub xla_batches: AtomicU64,
    pub native_batches: AtomicU64,
    /// Index traffic. Arc-shared so the router's per-shard executor jobs
    /// (long-lived worker threads, `'static` closures) can record into it
    /// without borrowing `Metrics`.
    pub index: Arc<IndexCounters>,
    /// Shard-executor runtime traffic (queue depth, busy workers, jobs).
    /// Arc-shared with the store's executor, which is what updates it.
    pub executor: Arc<ExecutorCounters>,
    /// Persistence traffic (WAL records/bytes, snapshots, recovery time).
    /// Arc-shared with the store's [`crate::persist::Persistence`] handle,
    /// which is what actually updates it — the snapshot below surfaces the
    /// values as `persist_*` stats fields.
    pub persist: Arc<PersistCounters>,
    /// Replication traffic (`repl_*` stats fields). Arc-shared with the
    /// primary-side shipper and/or the follower's puller runtime —
    /// whichever of the two this server runs (a promoted replica may have
    /// been both).
    pub repl: Arc<ReplCounters>,
    /// Per-stage pipeline histograms (`stage_*` fields). Arc-shared with
    /// the batcher, the store (placement/WAL/fsync stages) and the
    /// router's executor jobs.
    pub stages: Arc<Stages>,
    /// End-to-end insert latency (enqueue → ack released).
    pub insert_hist: ObsHistogram,
    /// End-to-end query latency (request decode → reply built).
    pub query_hist: ObsHistogram,
}

/// Non-panicking lookup in a `(name, value)` stats snapshot. Use this —
/// never `find(..).unwrap()` — anywhere a missing field must surface as an
/// error (or `None`) instead of a panic.
pub fn stats_field(fields: &[(String, f64)], name: &str) -> Option<f64> {
    fields.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one end-to-end insert latency (lock-free).
    pub fn record_insert_latency(&self, secs: f64) {
        self.insert_hist.record_secs(secs);
    }

    /// Record one end-to-end query latency (lock-free).
    pub fn record_query_latency(&self, secs: f64) {
        self.query_hist.record_secs(secs);
    }

    /// Snapshot as flat (name, value) pairs for the Stats response.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = vec![
            ("inserts".into(), self.inserts.load(Ordering::Relaxed) as f64),
            ("deletes".into(), self.deletes.load(Ordering::Relaxed) as f64),
            ("upserts".into(), self.upserts.load(Ordering::Relaxed) as f64),
            (
                "ttl_expirations".into(),
                self.ttl_expirations.load(Ordering::Relaxed) as f64,
            ),
            ("queries".into(), self.queries.load(Ordering::Relaxed) as f64),
            (
                "query_batches".into(),
                self.query_batches.load(Ordering::Relaxed) as f64,
            ),
            (
                "distances".into(),
                self.distances.load(Ordering::Relaxed) as f64,
            ),
            (
                "heatmaps".into(),
                self.heatmaps.load(Ordering::Relaxed) as f64,
            ),
            (
                "batches_flushed".into(),
                self.batches_flushed.load(Ordering::Relaxed) as f64,
            ),
            (
                "batch_items".into(),
                self.batch_items.load(Ordering::Relaxed) as f64,
            ),
            ("errors".into(), self.errors.load(Ordering::Relaxed) as f64),
            (
                "xla_batches".into(),
                self.xla_batches.load(Ordering::Relaxed) as f64,
            ),
            (
                "native_batches".into(),
                self.native_batches.load(Ordering::Relaxed) as f64,
            ),
            (
                "index_probes".into(),
                self.index.probes.load(Ordering::Relaxed) as f64,
            ),
            (
                "index_candidates".into(),
                self.index.candidates.load(Ordering::Relaxed) as f64,
            ),
            (
                "index_reranked".into(),
                self.index.reranked.load(Ordering::Relaxed) as f64,
            ),
            (
                "index_fallbacks".into(),
                self.index.fallbacks.load(Ordering::Relaxed) as f64,
            ),
            (
                "index_indexed_scans".into(),
                self.index.indexed_scans.load(Ordering::Relaxed) as f64,
            ),
            (
                "executor_queue_depth".into(),
                self.executor.queue_depth.load(Ordering::Relaxed) as f64,
            ),
            (
                "executor_busy_workers".into(),
                self.executor.busy_workers.load(Ordering::Relaxed) as f64,
            ),
            (
                "executor_jobs".into(),
                self.executor.jobs.load(Ordering::Relaxed) as f64,
            ),
            (
                "executor_scatters".into(),
                self.executor.scatters.load(Ordering::Relaxed) as f64,
            ),
            (
                "executor_job_panics".into(),
                self.executor.job_panics.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_wal_records".into(),
                self.persist.wal_records.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_wal_bytes".into(),
                self.persist.wal_bytes.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_snapshots".into(),
                self.persist.snapshots.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_recovery_ms".into(),
                self.persist.recovery_ms.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_generation".into(),
                self.persist.generation.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_group_commits".into(),
                self.persist.group_commits.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_wal_dead_frames".into(),
                self.persist.wal_dead_frames.load(Ordering::Relaxed) as f64,
            ),
            (
                "persist_compactions".into(),
                self.persist.compactions.load(Ordering::Relaxed) as f64,
            ),
            // Which scoring-kernel arm the dispatch table selected (gauge;
            // fixed for the process lifetime): 0 = scalar, 1 = avx2,
            // 2 = avx512, 3 = neon — see `crate::sketch::kernels::Isa`.
            (
                "kernel_isa".into(),
                crate::sketch::kernels::active().isa.code(),
            ),
        ];
        // Per-shard executor queue high-water marks: dynamic, grow-only
        // families — a shard's gauge appears only once its queue has ever
        // been nonempty, so the fresh-process golden schema stays fixed.
        for (si, hwm) in self.executor.per_shard_hwm.iter().enumerate() {
            let v = hwm.load(Ordering::Relaxed);
            if v > 0 {
                out.push((format!("executor_queue_hwm_shard{si}"), v as f64));
            }
        }
        out.extend(self.repl.stats_fields());
        // Per-stage pipeline histograms: count, upper-edge quantiles, and
        // cumulative bucket counts at ~1ms/10ms/100ms/1s (each rounded
        // down to the nearest exact histogram bucket edge, so counts are
        // exact — a slight undercount vs the decimal label).
        for (name, hist) in self.stages.named() {
            out.push((format!("stage_{name}_count"), hist.count() as f64));
            out.push((format!("stage_{name}_p50_ms"), hist.p50() * 1e3));
            out.push((format!("stage_{name}_p99_ms"), hist.p99() * 1e3));
            out.push((
                format!("stage_{name}_le_1ms"),
                hist.count_below_us(1_000) as f64,
            ));
            out.push((
                format!("stage_{name}_le_10ms"),
                hist.count_below_us(10_000) as f64,
            ));
            out.push((
                format!("stage_{name}_le_100ms"),
                hist.count_below_us(100_000) as f64,
            ));
            out.push((
                format!("stage_{name}_le_1s"),
                hist.count_below_us(1_000_000) as f64,
            ));
        }
        out.push(("insert_p50_ms".into(), self.insert_hist.p50() * 1e3));
        out.push(("insert_p99_ms".into(), self.insert_hist.p99() * 1e3));
        out.push(("query_p50_ms".into(), self.query_hist.p50() * 1e3));
        out.push(("query_p99_ms".into(), self.query_hist.p99() * 1e3));
        out
    }

    /// Histogram snapshots for the Prometheus exposition: every stage
    /// plus the end-to-end insert/query histograms, as
    /// `(base_name, snapshot)` pairs (see [`crate::obs::prom::render`]).
    pub fn histogram_snapshots(&self) -> Vec<(String, crate::obs::HistogramSnapshot)> {
        let mut out: Vec<(String, crate::obs::HistogramSnapshot)> = self
            .stages
            .named()
            .iter()
            .map(|(name, hist)| (format!("stage_{name}"), hist.snapshot()))
            .collect();
        out.push(("insert_latency".into(), self.insert_hist.snapshot()));
        out.push(("query_latency".into(), self.query_hist.snapshot()));
        out.push((
            "repl_visibility_lag".into(),
            self.repl.visibility_lag.snapshot(),
        ));
        out
    }

    /// Mean flushed batch size — the batching-efficiency signal used by the
    /// coordinator bench.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_flushed.load(Ordering::Relaxed) as f64;
        if b == 0.0 {
            0.0
        } else {
            self.batch_items.load(Ordering::Relaxed) as f64 / b
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        m.inserts.fetch_add(3, Ordering::Relaxed);
        m.batches_flushed.fetch_add(2, Ordering::Relaxed);
        m.batch_items.fetch_add(10, Ordering::Relaxed);
        m.record_insert_latency(0.002);
        let snap = m.snapshot();
        let get = |k: &str| {
            stats_field(&snap, k).unwrap_or_else(|| panic!("stats field '{k}' missing"))
        };
        assert_eq!(get("inserts"), 3.0);
        assert_eq!(m.mean_batch_size(), 5.0);
        assert!(get("insert_p50_ms") > 1.0);
    }

    #[test]
    fn index_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.index.probes.fetch_add(24, Ordering::Relaxed);
        m.index.candidates.fetch_add(7, Ordering::Relaxed);
        m.index.reranked.fetch_add(7, Ordering::Relaxed);
        m.index.fallbacks.fetch_add(1, Ordering::Relaxed);
        m.index.indexed_scans.fetch_add(3, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "index_probes"), Some(24.0));
        assert_eq!(stats_field(&snap, "index_candidates"), Some(7.0));
        assert_eq!(stats_field(&snap, "index_reranked"), Some(7.0));
        assert_eq!(stats_field(&snap, "index_fallbacks"), Some(1.0));
        assert_eq!(stats_field(&snap, "index_indexed_scans"), Some(3.0));
    }

    #[test]
    fn executor_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.executor.queue_depth.store(3, Ordering::Relaxed);
        m.executor.busy_workers.store(2, Ordering::Relaxed);
        m.executor.jobs.fetch_add(40, Ordering::Relaxed);
        m.executor.scatters.fetch_add(10, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "executor_queue_depth"), Some(3.0));
        assert_eq!(stats_field(&snap, "executor_busy_workers"), Some(2.0));
        assert_eq!(stats_field(&snap, "executor_jobs"), Some(40.0));
        assert_eq!(stats_field(&snap, "executor_scatters"), Some(10.0));
    }

    #[test]
    fn persist_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.persist.wal_records.fetch_add(12, Ordering::Relaxed);
        m.persist.wal_bytes.fetch_add(4096, Ordering::Relaxed);
        m.persist.snapshots.fetch_add(2, Ordering::Relaxed);
        m.persist.recovery_ms.store(57, Ordering::Relaxed);
        m.persist.generation.store(2, Ordering::Relaxed);
        m.persist.group_commits.fetch_add(5, Ordering::Relaxed);
        m.persist.wal_dead_frames.fetch_add(6, Ordering::Relaxed);
        m.persist.compactions.fetch_add(1, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "persist_wal_records"), Some(12.0));
        assert_eq!(stats_field(&snap, "persist_wal_bytes"), Some(4096.0));
        assert_eq!(stats_field(&snap, "persist_snapshots"), Some(2.0));
        assert_eq!(stats_field(&snap, "persist_recovery_ms"), Some(57.0));
        assert_eq!(stats_field(&snap, "persist_generation"), Some(2.0));
        assert_eq!(stats_field(&snap, "persist_group_commits"), Some(5.0));
        assert_eq!(stats_field(&snap, "persist_wal_dead_frames"), Some(6.0));
        assert_eq!(stats_field(&snap, "persist_compactions"), Some(1.0));
    }

    #[test]
    fn mutation_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.deletes.fetch_add(4, Ordering::Relaxed);
        m.upserts.fetch_add(2, Ordering::Relaxed);
        m.ttl_expirations.fetch_add(9, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "deletes"), Some(4.0));
        assert_eq!(stats_field(&snap, "upserts"), Some(2.0));
        assert_eq!(stats_field(&snap, "ttl_expirations"), Some(9.0));
    }

    #[test]
    fn repl_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.repl.frames_shipped.fetch_add(11, Ordering::Relaxed);
        m.repl.frames_applied.fetch_add(4, Ordering::Relaxed);
        m.repl.record_shard(0, 4, 7);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "repl_frames_shipped"), Some(11.0));
        assert_eq!(stats_field(&snap, "repl_frames_applied"), Some(4.0));
        assert_eq!(stats_field(&snap, "repl_applied_seq_shard0"), Some(4.0));
        assert_eq!(stats_field(&snap, "repl_lag_shard0"), Some(7.0));
        assert_eq!(stats_field(&snap, "repl_caught_up"), Some(0.0));
    }

    #[test]
    fn per_shard_queue_hwm_surfaces_only_when_nonzero() {
        let m = Metrics::new();
        assert_eq!(stats_field(&m.snapshot(), "executor_queue_hwm_shard3"), None);
        m.executor.note_enqueue(3);
        m.executor.note_enqueue(3);
        m.executor.note_dequeue(3);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "executor_queue_hwm_shard3"), Some(2.0));
        assert_eq!(
            m.executor.per_shard_depth[3].load(Ordering::Relaxed),
            1,
            "dequeue must drop the live depth gauge"
        );
        // Out-of-range shards fold into the aggregate only — no panic.
        m.executor.note_enqueue(TRACKED_SHARDS + 1);
        m.executor.note_dequeue(TRACKED_SHARDS + 1);
    }

    #[test]
    fn visibility_lag_surfaces_in_snapshot() {
        let m = Metrics::new();
        m.repl.record_visibility(1, 40);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "repl_visibility_lag_count"), Some(1.0));
        assert!(stats_field(&snap, "repl_visibility_lag_p99_ms").unwrap() >= 40.0);
        assert_eq!(stats_field(&snap, "repl_visibility_age_ms_shard0"), Some(0.0));
        assert_eq!(stats_field(&snap, "repl_visibility_age_ms_shard1"), Some(40.0));
    }

    #[test]
    fn executor_job_panics_surface_in_snapshot() {
        let m = Metrics::new();
        assert_eq!(
            stats_field(&m.snapshot(), "executor_job_panics"),
            Some(0.0)
        );
        m.executor.job_panics.fetch_add(2, Ordering::Relaxed);
        assert_eq!(
            stats_field(&m.snapshot(), "executor_job_panics"),
            Some(2.0)
        );
    }

    #[test]
    fn kernel_isa_surfaces_in_snapshot() {
        let snap = Metrics::new().snapshot();
        let code = stats_field(&snap, "kernel_isa").expect("kernel_isa missing");
        assert_eq!(code, crate::sketch::kernels::active().isa.code());
    }

    #[test]
    fn stage_histograms_surface_in_snapshot() {
        let m = Metrics::new();
        m.stages.write_fsync.record_secs(0.002);
        m.stages.write_fsync.record_secs(0.0001);
        m.stages.read_queue.record_secs(0.02);
        let snap = m.snapshot();
        assert_eq!(stats_field(&snap, "stage_write_fsync_count"), Some(2.0));
        assert!(stats_field(&snap, "stage_write_fsync_p99_ms").unwrap() >= 2.0);
        assert_eq!(stats_field(&snap, "stage_write_fsync_le_10ms"), Some(2.0));
        assert_eq!(stats_field(&snap, "stage_read_queue_count"), Some(1.0));
        assert_eq!(stats_field(&snap, "stage_read_queue_le_1ms"), Some(0.0));
        assert_eq!(stats_field(&snap, "stage_write_queue_count"), Some(0.0));
    }

    /// Golden stats schema: `Metrics::snapshot` field names must be
    /// unique and stable. `bench_gate` history, client `stats_field`
    /// lookups and dashboards all key on these names — an accidental
    /// rename or duplicate must break loudly here, not corrupt data
    /// silently. If you add a metric, extend this list (append-only for
    /// renames: keep the old name emitting too, or migrate consumers in
    /// the same PR).
    #[test]
    fn stats_schema_is_stable_and_unique() {
        let mut expected: Vec<String> = [
            "inserts",
            "deletes",
            "upserts",
            "ttl_expirations",
            "queries",
            "query_batches",
            "distances",
            "heatmaps",
            "batches_flushed",
            "batch_items",
            "errors",
            "xla_batches",
            "native_batches",
            "index_probes",
            "index_candidates",
            "index_reranked",
            "index_fallbacks",
            "index_indexed_scans",
            "executor_queue_depth",
            "executor_busy_workers",
            "executor_jobs",
            "executor_scatters",
            "executor_job_panics",
            "persist_wal_records",
            "persist_wal_bytes",
            "persist_snapshots",
            "persist_recovery_ms",
            "persist_generation",
            "persist_group_commits",
            "persist_wal_dead_frames",
            "persist_compactions",
            "kernel_isa",
            "repl_snapshots_served",
            "repl_tails_served",
            "repl_frames_shipped",
            "repl_bytes_shipped",
            "repl_frames_applied",
            "repl_bytes_applied",
            "repl_connects",
            "repl_stalls",
            "repl_move_defers",
            "repl_diverged",
            "repl_caught_up",
            "repl_visibility_lag_count",
            "repl_visibility_lag_p50_ms",
            "repl_visibility_lag_p99_ms",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for stage in [
            "write_queue",
            "write_sketch",
            "write_place",
            "write_wal",
            "write_fsync",
            "write_reply",
            "read_queue",
            "read_scan",
            "read_rerank",
            "read_gather",
        ] {
            for suffix in ["count", "p50_ms", "p99_ms", "le_1ms", "le_10ms", "le_100ms", "le_1s"] {
                expected.push(format!("stage_{stage}_{suffix}"));
            }
        }
        for tail in ["insert_p50_ms", "insert_p99_ms", "query_p50_ms", "query_p99_ms"] {
            expected.push(tail.to_string());
        }

        let actual: Vec<String> = Metrics::new()
            .snapshot()
            .into_iter()
            .map(|(n, _)| n)
            .collect();
        assert_eq!(actual, expected, "stats schema drifted");
        let mut dedup = actual.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), actual.len(), "duplicate stats field name");
    }

    #[test]
    fn histogram_snapshots_cover_stages_and_request_latencies() {
        let m = Metrics::new();
        m.record_query_latency(0.001);
        let hists = m.histogram_snapshots();
        assert_eq!(hists.len(), 13); // 10 stages + insert + query + repl visibility
        assert!(hists.iter().any(|(n, _)| n == "stage_write_fsync"));
        let q = hists.iter().find(|(n, _)| n == "query_latency").unwrap();
        assert_eq!(q.1.total, 1);
    }

    #[test]
    fn stats_field_is_total_not_panicking() {
        let fields = vec![("inserts".to_string(), 2.0)];
        assert_eq!(stats_field(&fields, "inserts"), Some(2.0));
        assert_eq!(stats_field(&fields, "no_such_field"), None);
        assert_eq!(stats_field(&[], "anything"), None);
    }

    #[test]
    fn empty_batch_size_zero() {
        assert_eq!(Metrics::new().mean_batch_size(), 0.0);
    }
}
